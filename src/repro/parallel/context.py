"""Parallel execution context threaded through model code.

Models are written as GSPMD (pjit + sharding-constraint) programs; specific
blocks opt into ``shard_map`` sub-programs when the context enables them:

* ``seq_shards > 1``  — prefill attention runs as ring attention over
  ``model_axis`` (sequence parallelism with partitioned KV exchange);
  SSM/RWKV blocks pass recurrent state across sequence shards.
* ``moe_mode='ep'``   — MoE dispatch uses all-to-all expert parallelism over
  ``model_axis`` (partitioned variant when ``a2a_parts > 1``).
* ``n_parts``         — partition count for partitioned collectives (the
  paper's knob; 1 = fused/persistent-style whole messages).
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh | None = None
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str | None = "model"
    # paper technique knobs
    seq_parallel: bool = False  # ring attention / state passing for prefill
    moe_mode: str = "dense"  # dense | ep
    n_parts: int = 1  # partitions per message (1 = fused)
    state_method: str = "ring"  # ring | tree (SSM/RWKV state passing)
    # tensor-parallel MLP mode: 'gspmd' (column/row TP, GSPMD inserts the
    # all-reduce) or 'ring' (sequence-sharded Megatron-SP via the partitioned
    # ring collective-matmuls — half the wire bytes, overlap-friendly)
    tp_mode: str = "gspmd"
    # transport-layer wire knobs for the Message-routed LM comm paths (ring
    # attention KV rotation; MoE dispatch when moe_comm='messages').  Lossy
    # packers (bf16 / scaled-int8) are opt-in here, never auto-selected.
    comm_packer: str = "slice"
    comm_coalesce: bool = True
    # MoE all-to-all backend: 'native' (lax.all_to_all) or 'messages'
    # (ring-shift Message table through repro.core.transport)
    moe_comm: str = "native"
    # numerics
    use_flash: bool = False  # Pallas flash kernel for local attention blocks

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.data_axes

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def batch_spec(self, *trailing: str | None) -> P:
        return P(self.data_axes, *trailing)


LOCAL = ParallelContext(mesh=None, model_axis=None)
