from repro.parallel.context import LOCAL, ParallelContext

__all__ = ["LOCAL", "ParallelContext"]
