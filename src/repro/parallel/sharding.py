"""Sharding rules: parameter/optimizer/batch PartitionSpecs per family.

Rules are name-based over the param tree paths (stable across families since
all modules share the layers.py naming).  Leading stack dimensions (layer
scans) are skipped automatically.  ZeRO-1: optimizer moments additionally
shard their first divisible replicated dim over the data axis, so the update
runs on 1/data_size of each tensor (GSPMD inserts the reduce-scatter /
all-gather pair — the paper's partitioned gradient pipeline applies on top
via bucketing, see train/optimizer.py).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# param-name -> (which dim gets the model axis, counted from the END)
# col-parallel: last dim; row-parallel: second-to-last dim.
_COL_PARALLEL = {
    "wq", "wk", "wv", "wg", "wr", "w_gate", "w_up", "in_proj", "ck", "cr",
    "head", "w_lora_a",
}
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "cv"}
_VOCAB_SHARDED = {"embed", "lm_head"}
_SLOT_SHARDED = {"moe/w_gate", "moe/w_up", "moe/w_down"}  # slot dim = model
_REPLICATED_HINTS = {"norm", "ln", "mu", "bias", "scale", "gate", "u",
                     "conv", "A_log", "D", "dt_bias", "router", "mask_emb",
                     "pre_proj", "vision_proj", "frame_proj", "w_base",
                     "w_lora_b", "q_norm", "k_norm"}


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def _leaf_name(path) -> str:
    for k in reversed(path):
        if hasattr(k, "key"):
            return str(k.key)
    return ""


def _spec_for(path, leaf, model_axis: str, model_size: int,
              fsdp_stacks: tuple | None = None) -> P:
    name = _leaf_name(path)
    pstr = _path_str(path)
    ndim = leaf.ndim
    spec: list[Any] = [None] * ndim

    def fits(dim_idx: int) -> bool:
        return 0 <= dim_idx < ndim and leaf.shape[dim_idx] % model_size == 0

    if any(f"moe/{name}" in s for s in _SLOT_SHARDED) and "moe" in pstr:
        # slot-stacked expert weights: (.., S_slots, d, f) — slot dim = model
        dim = ndim - 3
        if fits(dim):
            spec[dim] = model_axis
            # FSDP option (grok): layer-stack dim over the data axes too
            if fsdp_stacks is not None and dim > 0:
                data_axes, data_size = fsdp_stacks
                if leaf.shape[0] % data_size == 0 and leaf.shape[0] >= data_size:
                    spec[0] = (data_axes if len(data_axes) > 1
                               else data_axes[0])
            return P(*spec)
    if name in _VOCAB_SHARDED:
        if fits(ndim - 2):
            spec[ndim - 2] = model_axis
        return P(*spec)
    if name in _COL_PARALLEL and ndim >= 2:
        if fits(ndim - 1):
            spec[ndim - 1] = model_axis
        return P(*spec)
    if name in _ROW_PARALLEL and ndim >= 2:
        if fits(ndim - 2):
            spec[ndim - 2] = model_axis
        return P(*spec)
    return P(*spec)  # replicated (norms, biases, small projections)


def param_pspecs(params: Any, *, model_axis: str = "model",
                 model_size: int = 1,
                 fsdp_experts: bool = False,
                 data_axes: tuple[str, ...] = ("data",),
                 mesh: Mesh | None = None) -> Any:
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs)."""
    fsdp_stacks = None
    if fsdp_experts and mesh is not None:
        dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
        fsdp_stacks = (data_axes, dsize)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(path, leaf, model_axis, model_size,
                                     fsdp_stacks), params
    )


def zero1_pspecs(params: Any, pspecs: Any, *, data_axes: tuple[str, ...],
                 mesh: Mesh) -> Any:
    """Optimizer-moment specs: param spec + first divisible replicated dim
    sharded over the (flattened) data axes."""
    data_size = int(np.prod([mesh.shape[a] for a in data_axes]))

    def upgrade(leaf, spec: P) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # already data-sharded (e.g. FSDP expert stacks): nothing to add
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    used.add(a)
        if used.intersection(data_axes):
            return P(*entries)
        for i in range(leaf.ndim):
            if entries[i] is None and leaf.shape[i] % data_size == 0 and \
                    leaf.shape[i] >= data_size:
                entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
        return P(*entries)

    return jax.tree.map(upgrade, params, pspecs)


def batch_pspecs(batch: Any, *, data_axes: tuple[str, ...],
                 mesh: Mesh | None = None) -> Any:
    """Batch dim over the data axes (when divisible), else replicated."""
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    dsize = (int(np.prod([mesh.shape[a] for a in data_axes]))
             if mesh is not None else 1)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        if mesh is not None and leaf.shape[0] % dsize != 0:
            return P(*([None] * leaf.ndim))  # e.g. batch-1 long-context cells
        return P(da, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def cache_pspecs(cache: Any, *, data_axes: tuple[str, ...],
                 model_axis: str = "model", model_size: int = 1,
                 mesh: Mesh | None = None) -> Any:
    """KV/state caches: batch dim over data, head/feature dims over model.

    Cache layouts are (L, B, S, Hkv, hd) / (L, B, ...state) / scalars; the
    batch dim is the dim right after the leading stack dims.  Head or channel
    dims take the model axis when divisible.
    """
    da = data_axes if len(data_axes) > 1 else data_axes[0]
    dsize = (int(np.prod([mesh.shape[a] for a in data_axes]))
             if mesh is not None else 1)

    def spec(leaf):
        if leaf.ndim <= 1:
            return P()
        entries: list[Any] = [None] * leaf.ndim
        # find the batch dim: first dim whose size is not a tiny stack dim —
        # heuristic: caches are built as (stack..., B, ...); mark dim index
        # (ndim>=3 -> dim 1 for (L,B,...) layouts, dim 2 for (G,gs,B,...)).
        bdim = 1
        if leaf.ndim >= 5 and leaf.shape[0] <= 16 and leaf.shape[1] <= 16:
            bdim = 2
        if mesh is None or leaf.shape[bdim] % dsize == 0:
            entries[bdim] = da
        # model axis preference: head_dim (last), then heads, then seq —
        # decode writes scatter along seq, so sharding seq would force the
        # partitioner into full rematerialization on every cache update.
        for i in list(range(leaf.ndim - 1, bdim, -1)):
            if leaf.shape[i] % model_size == 0 and leaf.shape[i] >= model_size:
                entries[i] = model_axis
                break
        return P(*entries)

    out = jax.tree.map(spec, cache)
    # scalars (pos) replicated
    return out


def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def shaped_with_sharding(shapes: Any, mesh: Mesh, specs: Any) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, specs,
    )
