"""Elastic stencil grids: rank loss -> re-mesh -> re-plan -> resume.

The paper's persistent plans amortize setup cost over a run's iterations;
this layer is what makes that argument hold *in production*, where the
topology can change under a running exchange.  It connects the fault-
tolerance machinery (:mod:`repro.train.fault_tolerance`) to the stencil
stack (:mod:`repro.launch.stencil`):

* a :class:`FailureInjector` stands in for the missed-heartbeat signal and
  raises :class:`SimulatedFailure` at adversarial points — before a step,
  mid-exchange (dispatch in flight, wait not yet issued), or inside a plan
  build (between pipelined partition rounds, via the trace-time chaos seam
  of :mod:`repro.core.transport`);
* on failure the runner re-forms the mesh on the *surviving* device
  topology, invalidates every cached plan compiled against the dead one
  (:meth:`repro.core.plan.PlanCache.invalidate` — counted), re-derives the
  static ``Message``/``WireLayout`` tables for the new grid (asserting the
  derivation is deterministic: same topology in, identical offset tables
  out), and resumes the domain from the last committed checkpoint;
* re-plan latency (``replan_us`` — pure table math, separate from the
  recompile's ``init_us``) is recorded per event, the same metric the §VI
  sweep now stamps into every BENCH record.

The resumed trajectory is held to the single-device oracle **bitwise** for
exact packers: the per-cell update graph is identical across topologies,
ghost values cross the wire losslessly, and checkpoint restore is exact.
Wire-compressed packers (``bf16``, ``scaled-int8``) re-encode ghosts on
the wire, so a resumed run still matches a same-packer oracle bitwise but
drifts from the exact-wire reference within the packer's documented
``wire_tolerance`` per step (see README's fault-tolerance section).

In-process chaos (the 8-virtual-device test form)::

    runner = ElasticStencilRunner(
        ElasticConfig(n_steps=6), ckpt_dir,
        injector=FailureInjector(fail_at_steps=(3,), phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()          # fails at step 3, re-plans on 2 devices,
    result.final_interior          # ... bitwise == the 1-device oracle

Across real processes, ``tests/distributed_progs/check_elastic_stencil.py``
boots a 2-rank grid of this runner with an injected mid-run failure
(``max_replans=0`` — a real dead rank cannot be dropped from a live
``jax.distributed`` grid, so the whole grid dies and the *relaunch* on the
survivor topology is the re-plan), then resumes from the shared checkpoint
directory and verifies against the oracle.

Phase 2 adds the membership-led elastic stories on top of that relaunch
baseline (see :mod:`repro.launch.membership` and the README's
fault-tolerance section): **rank JOIN** (``joins=``/:meth:`request_join`
grows the mesh mid-run, moving the survivors' LIVE iterate through
:func:`~repro.train.fault_tolerance.reshard_state` — no checkpoint
involved), **in-grid loss recovery** (``recovery_mode="in-grid"``: the
coordinator bumps the membership epoch, survivors drop only epoch-stale
plans and re-initialize in place, staying warm), and **epoch-stamped
plans** (the runner threads its epoch into ``StrategyConfig``, so every
plan key and ``ScheduleInfo.tag()`` carries an ``!e{epoch}`` component
and :meth:`~repro.core.plan.PlanCache.invalidate_stale_epochs` can be
surgical).  A dead coordinator (:class:`CoordinatorLost`) falls back to
the relaunch path under a successor service.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.plan import PlanCache
from repro.core.transport import chaos_scope
from repro.launch.membership import CoordinatorLost, MembershipService
from repro.train.fault_tolerance import (
    FailureInjector,
    SimulatedFailure,
    StragglerMonitor,
    reshard_state,
)

#: how the runner recovers from rank loss.  ``"relaunch"`` is the PR 6
#: path: drop EVERY cached plan, shrink to the survivors, restore the
#: checkpoint (across real processes, the grid dies and relaunches).
#: ``"in-grid"`` is the membership-led path: the coordinator bumps the
#: epoch, only epoch-stale plans are invalidated, survivors barrier and
#: re-initialize in place — processes stay up, unrelated plans stay warm.
RECOVERY_MODES = ("relaunch", "in-grid")


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """One elastic stencil run: geometry, strategy cell, and chaos budget."""

    global_interior: tuple[int, ...] = (16, 8)
    halo: int = 1
    strategy: str = "persistent"
    packer: str = "slice"
    transport: str = "ppermute"
    coalesce: bool = True
    n_parts: int = 1
    n_steps: int = 8
    #: commit a checkpoint every k completed steps (and at the end);
    #: 0 disables checkpointing (oracle runs — nothing to resume)
    checkpoint_every: int = 1
    seed: int = 0
    #: failures survived in-process before the last one propagates; 0 lets
    #: the first failure kill the process (the multi-rank grid mode, where
    #: recovery is a relaunch on the survivor topology, not an in-process
    #: re-mesh)
    max_replans: int = 3
    #: one of :data:`RECOVERY_MODES`
    recovery_mode: str = "relaunch"
    #: membership heartbeat window (in-grid mode only)
    heartbeat_timeout: float = 5.0

    def __post_init__(self):
        assert self.n_steps >= 1, self.n_steps
        assert self.checkpoint_every >= 0, self.checkpoint_every
        assert self.max_replans >= 0, self.max_replans
        assert self.recovery_mode in RECOVERY_MODES, self.recovery_mode


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One (re-)planning of the exchange on a topology."""

    step: int
    n_devices: int
    #: re-deriving the static Message/WireLayout tables (table math only)
    replan_us: float
    #: the trace+lower+compile the topology change also pays
    init_us: float
    #: cached plans dropped because their topology died
    plan_invalidations: int
    #: "initial" | "rank-loss" (relaunch) | "loss-ingrid" | "join" |
    #: "coordinator-lost" (relaunch fallback)
    cause: str = "initial"
    #: membership epoch the new plan is stamped under (0 = formation /
    #: membership-free runs)
    epoch: int = 0


@dataclasses.dataclass
class ElasticResult:
    final_interior: np.ndarray
    steps: int
    #: failures survived (re-meshes performed)
    replans: int
    events: list[ReplanEvent]
    #: step of the last checkpoint the run committed (None: never saved)
    checkpoint_step: int | None
    #: how losses were recovered (config's recovery_mode)
    recovery_mode: str = "relaunch"
    #: total µs moving LIVE state onto grown meshes across all JOINs
    #: (register -> reshard complete; 0.0 when no rank joined)
    join_us: float = 0.0
    #: ranks that kept their process + warm plan cache through the most
    #: recent recovery/join (0 after a relaunch — everyone went cold)
    warm_ranks: int = 0
    #: membership epoch the run finished under
    final_epoch: int = 0
    #: (step, seconds, mean) observations the StragglerMonitor flagged
    straggler_flags: list = dataclasses.field(default_factory=list)
    # final plan-cache counters (the warmth evidence: in-grid recovery
    # keeps inits monotone across a loss instead of resetting the table)
    plan_cache_inits: int = 0
    plan_cache_hits: int = 0
    plan_cache_invalidations: int = 0
    #: the strategy cell, for BENCH stamping
    cell: dict = dataclasses.field(default_factory=dict)

    def bench_record(self) -> dict:
        """One BENCH row for the chaos CI legs — same vocabulary as the
        sweep's :meth:`~repro.stencil.comb.CycleResult.record` where the
        fields overlap, plus the elastic-only columns."""
        return {
            **self.cell,
            "steps": self.steps,
            "replans": self.replans,
            "replan_us": float(sum(e.replan_us for e in self.events)),
            "recovery_mode": self.recovery_mode,
            "join_us": self.join_us,
            "warm_ranks": self.warm_ranks,
            "final_epoch": self.final_epoch,
            "straggler_flags": [list(f) for f in self.straggler_flags],
            "plan_cache_inits": self.plan_cache_inits,
            "plan_cache_hits": self.plan_cache_hits,
            "plan_cache_invalidations": self.plan_cache_invalidations,
            "checkpoint_step": self.checkpoint_step,
        }


def diffusion_update(halo: int = 1) -> Callable:
    """Three-point diffusion along array axis 0 (the decomposed axis).

    Satisfies the overlap/elastic update contract — shift-invariant radius
    ``halo``, writes only the interior, leaves the rim untouched — and its
    per-cell op graph is independent of the decomposition, so trajectories
    are bitwise identical across topologies (the elastic resume oracle).
    """
    from jax import lax

    h = halo

    def update(x):
        s = x.shape[0]
        up = lax.slice_in_dim(x, 0, s - 2 * h, axis=0)
        mid = lax.slice_in_dim(x, h, s - h, axis=0)
        down = lax.slice_in_dim(x, 2 * h, s, axis=0)
        interior = (0.5 * mid + 0.25 * up + 0.25 * down).astype(x.dtype)
        return lax.dynamic_update_slice(
            x, interior, (h,) + (0,) * (x.ndim - 1)
        )

    return update


def initial_interior(config: ElasticConfig) -> np.ndarray:
    """The run's deterministic initial condition (every rank derives it)."""
    rng = np.random.default_rng(config.seed)
    return rng.normal(size=config.global_interior).astype(np.float32)


def _fetch_global_interior(domain, x) -> np.ndarray:
    """Dense global interior of a (possibly multi-process) stored array.

    On a ``jax.distributed`` grid the stored array is not fully
    addressable; a jitted fully-replicated identity gives every rank the
    whole array (the ``_mean_checksum`` trick, without the reduction).
    """
    if getattr(x, "is_fully_addressable", True):
        return domain.to_global_interior(np.asarray(x))
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    rep = jax.jit(
        lambda a: a,
        out_shardings=NamedSharding(domain.mesh, PartitionSpec()),
    )(x)
    stored = np.asarray(rep.addressable_shards[0].data)
    return domain.to_global_interior(stored)


class ElasticStencilRunner:
    """Drive a checkpointed stencil run that survives injected rank loss.

    The runner owns a private :class:`~repro.core.plan.PlanCache` (its
    table of initialized persistent requests) and a device list (its view
    of the live topology).  ``survivor_fn`` models which devices outlive a
    failure — the default keeps the first half, the "lost a pod slice"
    shape; the surviving count must still decompose the domain.
    """

    def __init__(
        self,
        config: ElasticConfig,
        ckpt_dir: str | None,
        *,
        injector: FailureInjector | None = None,
        devices: Sequence | None = None,
        survivor_fn: Callable[[list], list] | None = None,
        update_fn: Callable | None = None,
        membership: MembershipService | None = None,
        straggler: StragglerMonitor | None = None,
        joins: Sequence[tuple[int, Sequence]] = (),
        fail_coordinator_at: int | None = None,
    ):
        import jax

        self.config = config
        self.ckpt_dir = ckpt_dir
        self.injector = injector
        self.devices = list(jax.devices() if devices is None else devices)
        self.survivor_fn = survivor_fn or (
            lambda devs: devs[: max(1, len(devs) // 2)]
        )
        self.update_fn = update_fn or diffusion_update(config.halo)
        #: this runner's table of initialized persistent plans
        self.cache = PlanCache()
        self.events: list[ReplanEvent] = []
        self.checkpoint_step: int | None = None
        #: coordinator-led membership; auto-created for in-grid mode.  The
        #: runner IS rank 0 in the in-process form: it drives the service
        #: the way the grid coordinator does across real processes.
        if membership is None and config.recovery_mode == "in-grid":
            membership = MembershipService(
                heartbeat_timeout=config.heartbeat_timeout)
        self.membership = membership
        #: stable member ids, parallel to ``devices`` (survive shrinks)
        self.members = list(range(len(self.devices)))
        #: membership epoch current plans are stamped under
        self.epoch = 0
        self.straggler = straggler
        #: pending JOINs: (step, new_devices) handled before that step runs
        self._joins = sorted((int(s), list(d)) for s, d in joins)
        self._fail_coordinator_at = fail_coordinator_at
        self.join_us = 0.0
        self.warm_ranks = 0

    # -- topology ------------------------------------------------------------
    def _domain(self):
        from repro.core.compat import make_mesh
        from repro.stencil.domain import Domain

        cfg = self.config
        n = len(self.devices)
        assert cfg.global_interior[0] % n == 0, (
            f"interior {cfg.global_interior} not decomposable over "
            f"{n} surviving devices"
        )
        mesh = make_mesh((n,), ("px",), devices=self.devices)
        return Domain(
            mesh,
            global_interior=cfg.global_interior,
            mesh_axes=("px",) + (None,) * (len(cfg.global_interior) - 1),
            halo=cfg.halo,
        )

    # -- planning ------------------------------------------------------------
    def _plan(self, domain, step: int, cause: str, invalidated: int):
        """Build the exchange driver for ``domain``; record one
        :class:`ReplanEvent` (re-derivation timed + determinism asserted).
        """
        import jax

        from repro.stencil.strategies import StrategyConfig, make_driver

        cfg = self.config
        drv = make_driver(
            StrategyConfig(
                name=cfg.strategy, n_parts=cfg.n_parts, packer=cfg.packer,
                transport=cfg.transport, coalesce=cfg.coalesce,
                plan_cache=self.cache, epoch=self.epoch,
            ),
            domain.mesh, domain.halo_spec,
            ndim=len(cfg.global_interior), update_fn=self.update_fn,
        )
        example = jax.ShapeDtypeStruct(
            domain.stored_global, np.dtype(domain.dtype),
            sharding=domain.sharding(),
        )
        # static re-planning: re-derive the Message tables + WireLayout
        # offsets for this topology, timed — and derived twice, because the
        # whole elastic story rests on the derivation being a deterministic
        # pure function of the topology (same mesh in, same offsets out).
        t0 = time.perf_counter()
        tables = drv.replan_tables(example)
        replan_us = (time.perf_counter() - t0) * 1e6
        again = drv.replan_tables(example)
        assert tables == again, (
            "static re-planning is not deterministic on this topology"
        )
        probe = None
        if self.injector is not None:
            injector = self.injector

            def probe(point: str) -> None:
                # fires at trace time inside the delivery choreography —
                # i.e. DURING the plan build ("group" entry / between
                # pipelined partition "round"s)
                injector.check(step, phase=f"plan-build:{point}")

        t0 = time.perf_counter()
        with chaos_scope(probe):
            drv.init(example)
        init_us = (time.perf_counter() - t0) * 1e6
        event = ReplanEvent(
            step=step, n_devices=len(self.devices), replan_us=replan_us,
            init_us=init_us, plan_invalidations=invalidated, cause=cause,
            epoch=self.epoch,
        )
        self.events.append(event)
        return drv

    # -- state ---------------------------------------------------------------
    def _checkpoint(self, interior: np.ndarray, step: int) -> None:
        if self.ckpt_dir is None:
            return
        import jax

        from repro.train import checkpoint

        if jax.process_index() == 0:
            checkpoint.save(
                {"interior": interior, "step": np.int64(step)},
                self.ckpt_dir, step,
            )
        self.checkpoint_step = step

    def _restore_or_init(self) -> tuple[np.ndarray, int]:
        """Last committed checkpoint, or the deterministic initial state.

        Restores structure-free (``like=None``): a replacement process
        never held the pre-failure state object, only the directory.
        """
        from repro.train import checkpoint

        if (self.ckpt_dir is not None
                and checkpoint.latest_step(self.ckpt_dir) is not None):
            state, step = checkpoint.restore(self.ckpt_dir)
            return np.asarray(state["interior"]), int(state["step"])
        return initial_interior(self.config), 0

    # -- membership ----------------------------------------------------------
    def _form_membership(self) -> None:
        """Register every current member and seal the founding set."""
        if self.membership is None:
            return
        for m in self.members:
            self.membership.register(m)
        self.epoch = self.membership.seal().epoch

    def _heartbeat_all(self, step: int) -> None:
        """Every live rank beats (the in-process stand-in for per-rank
        heartbeat threads).  A dead coordinator surfaces here as
        :class:`CoordinatorLost` — the relaunch-fallback trigger."""
        if self.membership is None:
            return
        for m in self.members:
            self.membership.heartbeat(m, step=step)

    # -- JOIN ----------------------------------------------------------------
    def request_join(self, devices: Sequence, at_step: int = 0) -> None:
        """Admit ``devices`` as a joining rank before ``at_step`` runs."""
        self._joins.append((int(at_step), list(devices)))
        self._joins.sort(key=lambda j: j[0])

    def _handle_join(self, domain, drv, x, step: int):
        """Grow the mesh around a registering rank, moving LIVE state.

        The survivors' current iterate — not a checkpoint — crosses to the
        grown topology: dense global interior off the old mesh, stored
        (ghosted) layout for the new decomposition, then
        :func:`~repro.train.fault_tolerance.reshard_state` places it under
        the grown mesh's sharding.  ``join_us`` times that whole move.
        Chaos checks inside run under the injector's ``"join"`` phase
        scope, which cannot leak into steady-state steps.
        """
        import contextlib

        import jax

        _, new_devices = self._joins.pop(0)
        scope = (self.injector.phase_scope("join")
                 if self.injector is not None else contextlib.nullcontext())
        with scope:
            self._check(step)  # chaos window: the JOIN itself can die
            t0 = time.perf_counter()
            live = _fetch_global_interior(domain, x)
            drv.free()
            survivors = len(self.members)
            next_id = max(self.members, default=-1) + 1
            joiners = list(range(next_id, next_id + len(new_devices)))
            if self.membership is not None:
                for j in joiners:
                    view = self.membership.register(j)  # epoch bump: "join"
                self.epoch = view.epoch
            else:
                self.epoch += 1
            # plans stamped with pre-join epochs can never deliver into the
            # grown mesh; everything else the survivors warmed stays put
            stale = self.cache.invalidate_stale_epochs(self.epoch)
            self.devices = self.devices + list(new_devices)
            self.members = self.members + joiners
            new_domain = self._domain()
            x = reshard_state(
                new_domain.stored_from_interior(live),
                new_domain.mesh, new_domain.pspec(),
            )
            jax.block_until_ready(x)
            self.join_us += (time.perf_counter() - t0) * 1e6
            if self.membership is not None:
                for m in self.members:
                    self.membership.ack(m, self.epoch)
                assert self.membership.barrier_complete(self.epoch)
            self.warm_ranks = survivors
            new_drv = self._plan(
                new_domain, step, cause="join", invalidated=stale)
        return x, new_domain, new_drv

    # -- LOSS recovery -------------------------------------------------------
    def _recover_loss(self, pending: int) -> tuple[str, int]:
        """Shrink to the survivors after a detected rank loss.

        In-grid mode is coordinator-led: evict the dead ranks, adopt the
        bumped epoch, drop ONLY epoch-stale plans, and barrier every
        survivor on the new epoch before anyone touches the re-formed
        mesh.  If the coordinator turns out to be dead too, fall back to
        the relaunch path.  Relaunch mode is PR 6 unchanged: every plan
        dropped, everyone cold.
        """
        survivors = list(self.survivor_fn(self.devices))
        assert survivors, "no surviving devices"
        lost = [m for m, d in zip(self.members, self.devices)
                if d not in survivors]
        if (self.config.recovery_mode == "in-grid"
                and self.membership is not None):
            try:
                view = self.membership.mark_lost(*lost)  # epoch bump: "loss"
                self.epoch = view.epoch
                pending += self.cache.invalidate_stale_epochs(self.epoch)
                keep = [m for m in self.members if m not in lost]
                for m in keep:
                    self.membership.ack(m, self.epoch)
                assert self.membership.barrier_complete(self.epoch)
                self.members = keep
                self.devices = survivors
                self.warm_ranks = len(keep)
                return "loss-ingrid", pending
            except CoordinatorLost:
                return self._coordinator_fallback(
                    pending, survivors=survivors, lost=lost)
        # the dead topology's plans are garbage: drop them all (the
        # counter feeds the next ReplanEvent) and go cold
        pending += self.cache.invalidate()
        self.members = [m for m in self.members if m not in lost]
        self.devices = survivors
        self.warm_ranks = 0
        return "rank-loss", pending

    def _coordinator_fallback(self, pending: int, *, survivors=None,
                              lost=()) -> tuple[str, int]:
        """The coordinator died: in-grid recovery is impossible, so take
        the PR 6 relaunch path (full invalidation, everyone cold) and
        re-form membership under a successor coordinator whose epoch
        starts past every plan the old generation stamped."""
        pending += self.cache.invalidate()
        if survivors is not None:
            self.members = [m for m in self.members if m not in lost]
            self.devices = survivors
        self.warm_ranks = 0
        self.epoch += 1
        if self.membership is not None:
            self.membership = MembershipService(
                heartbeat_timeout=self.config.heartbeat_timeout,
                start_epoch=self.epoch,
            )
            self._form_membership()
        return "coordinator-lost", pending

    # -- the run loop --------------------------------------------------------
    def _check(self, step: int, phase: str | None = None) -> None:
        if self.injector is not None:
            self.injector.check(step, phase=phase)

    def run(self) -> ElasticResult:
        cfg = self.config
        replans = 0
        pending_invalidated = 0
        cause = "initial"
        interior, step = self._restore_or_init()
        self._form_membership()
        while True:
            drv = None
            try:
                domain = self._domain()
                # plan-build chaos can fire inside _plan's init trace
                drv = self._plan(
                    domain, step, cause=cause,
                    invalidated=pending_invalidated,
                )
                pending_invalidated = 0
                x = domain.from_global_interior(interior)
                while step < cfg.n_steps:
                    if self._joins and self._joins[0][0] <= step:
                        x, domain, drv = self._handle_join(
                            domain, drv, x, step)
                    if (self._fail_coordinator_at is not None
                            and step >= self._fail_coordinator_at
                            and self.membership is not None):
                        self._fail_coordinator_at = None
                        self.membership.fail()  # chaos: coordinator dies
                    self._check(step, "pre-step")
                    t0 = time.perf_counter()
                    y = drv.step(x)  # exchange+update dispatched (async)
                    self._check(step, "mid-exchange")
                    x = drv.wait(y)
                    if self.straggler is not None:
                        self.straggler.observe(
                            step, time.perf_counter() - t0)
                    step += 1
                    self._heartbeat_all(step)
                    if cfg.checkpoint_every and (
                            step % cfg.checkpoint_every == 0
                            or step == cfg.n_steps):
                        interior = _fetch_global_interior(domain, x)
                        self._checkpoint(interior, step)
                final = _fetch_global_interior(domain, x)
                stats = self.cache.stats
                return ElasticResult(
                    final_interior=final, steps=step, replans=replans,
                    events=list(self.events),
                    checkpoint_step=self.checkpoint_step,
                    recovery_mode=cfg.recovery_mode,
                    join_us=self.join_us,
                    warm_ranks=self.warm_ranks,
                    final_epoch=self.epoch,
                    straggler_flags=(
                        list(self.straggler.flagged)
                        if self.straggler is not None else []),
                    plan_cache_inits=stats.inits,
                    plan_cache_hits=stats.cache_hits,
                    plan_cache_invalidations=stats.invalidations,
                    cell={
                        "strategy": cfg.strategy, "packer": cfg.packer,
                        "transport": cfg.transport,
                        "coalesce": cfg.coalesce, "n_parts": cfg.n_parts,
                    },
                )
            except SimulatedFailure:
                replans += 1
                if replans > cfg.max_replans:
                    raise
                cause, pending_invalidated = self._recover_loss(
                    pending_invalidated)
                # resume from the last committed checkpoint (JOINs move
                # live state instead and never come through here)
                interior, step = self._restore_or_init()
            except CoordinatorLost:
                replans += 1
                if replans > cfg.max_replans:
                    raise
                cause, pending_invalidated = self._coordinator_fallback(
                    pending_invalidated)
                interior, step = self._restore_or_init()
            finally:
                if drv is not None:
                    drv.free()

    @property
    def plan_stats(self):
        return self.cache.stats


def main(argv: Sequence[str] | None = None) -> None:
    """Demo CLI: run one in-process chaos cycle and report the events."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", default="16,8")
    ap.add_argument("--strategy", default="persistent")
    ap.add_argument("--packer", default="slice")
    ap.add_argument("--n-parts", type=int, default=1)
    ap.add_argument("--n-steps", type=int, default=8)
    ap.add_argument("--fail-step", type=int, default=None,
                    help="inject a mid-exchange failure at this step "
                         "(default: n_steps // 2)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: a temp dir)")
    args = ap.parse_args(argv)

    import tempfile

    import jax

    size = tuple(int(s) for s in args.size.split(","))
    fail_at = args.fail_step if args.fail_step is not None else args.n_steps // 2
    cfg = ElasticConfig(
        global_interior=size, strategy=args.strategy, packer=args.packer,
        n_parts=args.n_parts, n_steps=args.n_steps,
    )
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="elastic_ckpt_")
    runner = ElasticStencilRunner(
        cfg, ckpt,
        injector=FailureInjector(fail_at_steps=(fail_at,),
                                 phases=("mid-exchange",)),
    )
    result = runner.run()
    for e in result.events:
        print(f"plan[{e.cause}] step={e.step} devices={e.n_devices} "
              f"replan_us={e.replan_us:.0f} init_us={e.init_us:.0f} "
              f"invalidated={e.plan_invalidations}")
    oracle = ElasticStencilRunner(
        dataclasses.replace(cfg, checkpoint_every=0), None,
        devices=jax.devices()[:1],
    ).run()
    from repro.core.transport import get_packer

    rtol, atol = get_packer(cfg.packer).wire_tolerance(np.float32)
    if (rtol, atol) == (0.0, 0.0):
        match = np.array_equal(result.final_interior, oracle.final_interior)
        kind = "bitwise"
    else:
        # lossy wire: topologies legitimately drift within the per-step
        # wire tolerance (scale-aware atol — see tests/stencil/test_elastic)
        scale = float(np.abs(oracle.final_interior).max())
        match = np.allclose(
            result.final_interior, oracle.final_interior,
            rtol=cfg.n_steps * rtol,
            atol=cfg.n_steps * max(atol, rtol * scale),
        )
        kind = "tolerance-aware"
    print(f"{result.steps} steps, {result.replans} re-plans; "
          f"{kind} vs 1-device oracle: {match}")
    if not match:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
