"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices *before* any
jax initialization; tests and benches see the default device count).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)"
        )
    return make_mesh(shape, axes, devices=devices[:n])


def data_axes_of(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)
