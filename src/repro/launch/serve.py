"""Serving launcher: batched generation with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --reduced \\
        --requests 8 --slots 4 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    if not model.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode serving")
    params = model.init(jax.random.key(args.seed))
    engine = ServingEngine(model, params, max_slots=args.slots,
                           max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    uids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(3, 12)))
        uids.append(engine.submit(prompt.tolist(), max_new_tokens=args.max_new))
    results = engine.run()
    dt = time.perf_counter() - t0
    for uid in uids:
        print(f"req {uid}: {results[uid]}")
    st = engine.stats
    print(f"{st.tokens_generated} tokens in {dt:.2f}s "
          f"({st.tokens_generated/dt:.1f} tok/s), "
          f"{st.prefills} prefills, {st.decode_steps} decode steps, "
          f"plans: {st.plan_inits} inits / {st.plan_hits} cache hits")


if __name__ == "__main__":
    main()
