"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \\
        --steps 50 --batch 4 --seq 64 --checkpoint-dir /tmp/ckpt

On this CPU container use ``--reduced`` (tiny same-family config).  On a real
cluster, drop ``--reduced``, point ``--mesh production`` at a 256-chip slice
(jax.distributed is initialized automatically when JAX_COORDINATOR is set),
and the full config trains with the shardings proven by the dry-run.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import os

import jax

from repro.configs import SHAPES, get_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.models import build_model
from repro.parallel.context import LOCAL, ParallelContext
from repro.train.fault_tolerance import FailureInjector
from repro.train.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU smoke)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", choices=["local", "production"], default="local")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (fault-tolerance demo)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    ctx = LOCAL
    if args.mesh == "production":
        from repro.launch.mesh import data_axes_of, make_production_mesh

        mesh = make_production_mesh()
        ctx = ParallelContext(mesh=mesh, data_axes=data_axes_of(mesh),
                              moe_mode="ep" if cfg.family == "moe" else "dense")

    run_cfg = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", args.seq, args.batch, "train"),
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=max(args.steps, 10)),
        steps=args.steps,
        seed=args.seed,
        log_every=args.log_every,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    injector = FailureInjector(
        fail_at_steps=(args.fail_at,) if args.fail_at >= 0 else ())
    model = build_model(cfg)
    result = Trainer(model, run_cfg, ctx=ctx, injector=injector).run()
    print(f"trained {len(result.losses)} steps: "
          f"loss {result.losses[0]:.4f} -> {result.losses[-1]:.4f}, "
          f"restarts={result.restarts}, stragglers={result.straggler_flags}")


if __name__ == "__main__":
    main()
