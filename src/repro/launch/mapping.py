"""Topology-aware process-to-node mapping (Hunold et al., PAPERS.md).

On a multi-node grid, *which ranks share a node* decides whether a halo
message crosses the wire at all: the default row-major assignment of ranks
to mesh coordinates strings each node's ranks along one mesh row, so every
face exchange along the other axes is inter-node.  A **blocked** mapping
places each node's ranks on a compact sub-block of the mesh, turning the
heaviest face exchanges into intra-node (shared-memory) copies; **recursive
bisection** generalizes that to mesh shapes a block grid cannot tile.

A :class:`Mapping` does NOT change the exchange schedule — the
:class:`~repro.core.transport.Message` tables are a pure function of the
mesh *shape* (tests/core/test_replan_purity.py) — it only permutes which
device (equivalently, which rank) sits at each mesh coordinate.  The seam
is the explicit device list handed to ``jax.make_mesh``: callers permute
``devices`` through :meth:`Mapping.permute_devices` *before* building the
mesh (``repro.launch.stencil.global_stencil_mesh``, the §VI sweep's
per-mapping meshes), and every schedule, packer, and transport rides
unchanged.

The registry follows the strategy/packer pattern
(:mod:`repro.stencil.strategies`, :mod:`repro.core.transport`): register
once, and the mapping is selectable by name everywhere — ``StrategyConfig
(mapping=...)`` stamps it into persistent plan keys, the sweep records it
per BENCH row, and ``--mapping`` sweeps it.

Conventions used throughout:

* mesh coordinates enumerate **row-major** over ``mesh_shape`` (the order
  ``itertools.product(*map(range, mesh_shape))`` yields, matching how
  ``jax.make_mesh`` consumes an explicit device list);
* ``placement[flat_coord]`` is the **rank** (index into the original,
  node-contiguous device list) placed at that coordinate;
* ranks are node-contiguous: node id = ``rank // node_size`` (real grids
  list each process's devices consecutively in ``jax.devices()``; modeled
  in-process "nodes" adopt the same rule).
"""

from __future__ import annotations

import abc
import dataclasses
import itertools
import math
from typing import ClassVar, Sequence


def _flat(coords: Sequence[int], shape: Sequence[int]) -> int:
    """Row-major linearization (``lax.ppermute``'s multi-axis rule)."""
    idx = 0
    for c, k in zip(coords, shape):
        idx = idx * k + c
    return idx


def _prime_factors(n: int) -> list[int]:
    out, p = [], 2
    while p * p <= n:
        while n % p == 0:
            out.append(p)
            n //= p
        p += 1
    if n > 1:
        out.append(n)
    return out


class Mapping(abc.ABC):
    """One rank-placement policy: mesh coordinate -> rank.

    Subclasses implement :meth:`placement`; :meth:`permute_devices` and
    :meth:`node_of` derive from it.  Placements must be permutations of
    ``range(prod(mesh_shape))`` (asserted) and pure functions of
    ``(mesh_shape, node_size)`` — every rank of a grid derives the same
    placement independently, exactly as the re-plan purity contract
    requires.
    """

    #: registry key; subclasses must override.
    name: ClassVar[str] = ""

    @abc.abstractmethod
    def placement(
        self, mesh_shape: Sequence[int], node_size: int
    ) -> tuple[int, ...]:
        """``placement[flat_coord] = rank`` for every row-major coordinate.

        ``node_size`` is the number of ranks per node (devices per process
        on a real grid); mappings that cannot honor it for this shape must
        degrade to a valid placement, never fail.
        """

    def permute_devices(
        self, devices: Sequence, mesh_shape: Sequence[int], node_size: int
    ) -> list:
        """The device list to hand ``make_mesh`` so that mesh coordinate
        ``c`` holds ``devices[placement[flat(c)]]`` (``jax.make_mesh``
        preserves an explicitly passed device order)."""
        placement = self.placement(mesh_shape, node_size)
        assert len(placement) == len(devices), (placement, len(devices))
        return [devices[r] for r in placement]

    def node_of(
        self, mesh_shape: Sequence[int], node_size: int
    ) -> tuple[int, ...]:
        """Node id at each row-major mesh coordinate (ranks are
        node-contiguous) — the vector the hop-locality classifier consumes
        (:func:`repro.core.transport.schedule_locality`)."""
        assert node_size >= 1, node_size
        return tuple(r // node_size for r in self.placement(mesh_shape,
                                                            node_size))

    def _check(self, placement: Sequence[int], n: int) -> tuple[int, ...]:
        assert sorted(placement) == list(range(n)), (
            f"{self.name}: placement is not a permutation of {n} ranks: "
            f"{placement}"
        )
        return tuple(placement)


class RowMajorMapping(Mapping):
    """The historical default: rank *i* at the *i*-th row-major coordinate
    (``launch_grid``'s implicit assignment — nodes string along mesh rows)."""

    name = "row-major"

    def placement(self, mesh_shape, node_size):
        return tuple(range(math.prod(mesh_shape)))


class BlockedMapping(Mapping):
    """Each node's ranks tile one compact ``node_size``-cell sub-block.

    ``node_size`` is factored into per-axis block dims by assigning its
    prime factors greedily to the axis with the largest remaining quotient
    ``mesh_shape[a] / dims[a]`` among the axes the factor divides — the
    near-cubic blocks of Hunold et al.  Blocks tile the mesh row-major;
    ranks fill each block row-major, so node ``b`` owns exactly block ``b``
    and every within-block face neighbor is intra-node.  When ``node_size``
    cannot tile the shape (a factor divides no axis) or is degenerate
    (``<= 1`` or ``>= prod(shape)``), the placement degrades to row-major;
    a 1-D mesh degrades the same way (contiguous ranks are already blocks).
    """

    name = "blocked"

    def block_dims(
        self, mesh_shape: Sequence[int], node_size: int
    ) -> tuple[int, ...] | None:
        """Per-axis block extents tiling the mesh, or ``None`` when
        ``node_size`` does not factor over this shape."""
        n = math.prod(mesh_shape)
        if node_size <= 1 or node_size >= n or n % node_size != 0:
            return None
        dims = [1] * len(mesh_shape)
        for p in sorted(_prime_factors(node_size), reverse=True):
            best, best_q = None, 0
            for a, k in enumerate(mesh_shape):
                q = k // dims[a]
                if q % p == 0 and q > best_q:
                    best, best_q = a, q
            if best is None:
                return None  # factor tiles no axis: shape not blockable
            dims[best] *= p
        return tuple(dims)

    def placement(self, mesh_shape, node_size):
        n = math.prod(mesh_shape)
        dims = self.block_dims(mesh_shape, node_size)
        if dims is None:
            return RowMajorMapping().placement(mesh_shape, node_size)
        blocks = tuple(k // d for k, d in zip(mesh_shape, dims))
        out = []
        for coords in itertools.product(*map(range, mesh_shape)):
            block = [c // d for c, d in zip(coords, dims)]
            within = [c % d for c, d in zip(coords, dims)]
            out.append(
                _flat(block, blocks) * node_size + _flat(within, dims)
            )
        return self._check(out, n)


class RecursiveBisectionMapping(Mapping):
    """Recursively bisect the mesh box, assigning contiguous rank ranges.

    Each step splits the current coordinate box along its longest axis into
    two halves (sizes ``ceil``/``floor``) and hands each half the
    proportional contiguous slice of its rank range — so nearby ranks (and
    therefore whole nodes, ranks being node-contiguous) land on compact
    sub-boxes even when no block grid tiles the shape.  ``node_size`` only
    enters through the rank numbering; the recursion itself is shape-driven
    (the graph-partitioning form of Hunold et al.'s bisection mapping).
    """

    name = "recursive-bisection"

    def placement(self, mesh_shape, node_size):
        n = math.prod(mesh_shape)
        out = [0] * n

        def assign(box: list[tuple[int, int]], rank0: int) -> None:
            cells = math.prod(hi - lo for lo, hi in box)
            if cells == 1:
                coords = [lo for lo, _ in box]
                out[_flat(coords, mesh_shape)] = rank0
                return
            axis = max(range(len(box)),
                       key=lambda a: box[a][1] - box[a][0])
            lo, hi = box[axis]
            mid = lo + (hi - lo + 1) // 2
            left = list(box)
            left[axis] = (lo, mid)
            right = list(box)
            right[axis] = (mid, hi)
            left_cells = math.prod(h - l for l, h in left)
            assign(left, rank0)
            assign(right, rank0 + left_cells)

        assign([(0, k) for k in mesh_shape], 0)
        return self._check(out, n)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_MAPPINGS: dict[str, Mapping] = {}
#: short CLI aliases -> canonical registry names
ALIASES = {"rb": "recursive-bisection"}


def register_mapping(mapping: Mapping) -> Mapping:
    """Add a mapping instance to the registry under ``mapping.name``."""
    if not mapping.name:
        raise ValueError(f"{type(mapping).__name__} must carry a name")
    if mapping.name in _MAPPINGS:
        raise ValueError(f"mapping {mapping.name!r} already registered")
    _MAPPINGS[mapping.name] = mapping
    return mapping


def available_mappings() -> tuple[str, ...]:
    """Registered canonical mapping names, registration order."""
    return tuple(_MAPPINGS)


def canonical_mapping(name: str) -> str:
    """Resolve aliases (``"rb"``) to the canonical registry name; unknown
    names fail with the registered list (mirrors get_packer)."""
    name = ALIASES.get(name, name)
    if name not in _MAPPINGS:
        raise KeyError(
            f"unknown mapping {name!r}; registered: "
            f"{', '.join(_MAPPINGS) or '(none)'} "
            f"(aliases: {', '.join(f'{a}={c}' for a, c in ALIASES.items())})"
        )
    return name


def get_mapping(name: str) -> Mapping:
    return _MAPPINGS[canonical_mapping(name)]


register_mapping(RowMajorMapping())
register_mapping(BlockedMapping())
register_mapping(RecursiveBisectionMapping())


# ---------------------------------------------------------------------------
# node-id derivation for live meshes
# ---------------------------------------------------------------------------


def default_node_size(n_devices: int, processes: int = 1) -> int:
    """The sweep's auto rule for ranks-per-node: the real devices-per-process
    count on a multi-process grid; a modeled two-node split of the device
    list when everything runs in one process (so in-process CI still has an
    inter-node boundary to classify against)."""
    assert n_devices >= 1 and processes >= 1, (n_devices, processes)
    if processes > 1 and n_devices % processes == 0:
        return n_devices // processes
    return max(1, n_devices // 2)


def mesh_node_ids(mesh, node_size: int = 0) -> tuple[int, ...]:
    """Node id at each row-major coordinate of a LIVE mesh.

    On a real multi-process mesh the node id is the owning process
    (``device.process_index``); a single-process mesh models nodes as
    ``node_size`` consecutive device ids (``device.id // node_size``).
    This reads the mesh's *actual* device assignment, so it reflects
    whatever mapping permuted the device list — the ground truth the
    static :meth:`Mapping.node_of` vectors are tested against.
    """
    devices = list(mesh.devices.flat)
    if any(d.process_index != devices[0].process_index for d in devices):
        return tuple(d.process_index for d in devices)
    if node_size <= 0:
        node_size = default_node_size(len(devices))
    return tuple(d.id // node_size for d in devices)
