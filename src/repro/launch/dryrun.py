import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on the
production mesh and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--force]

Per cell this builds the real step function:
  train_4k           -> train_step (fwd + bwd + AdamW, microbatched per config)
  prefill_32k        -> serve_step = model.prefill (cache build)
  decode_32k/long_500k -> serve_step = model.decode_step (1 token vs cache)

with in/out shardings from ``repro.parallel.sharding`` and inputs as
ShapeDtypeStructs (zero allocation).  Results are cached incrementally in
results/dryrun/<cell>.json; reduced-depth (L=1, L=2) variants are also
compiled for the roofline's scan-trip-count correction (DESIGN.md §6).

(No ``from __future__`` import here: the XLA_FLAGS lines above must stay the
very first statements of the file.)
"""

import argparse
import dataclasses
import json
import time
import traceback

import zstandard

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, OptimizerConfig, ShapeConfig
from repro.core.compat import set_mesh
from repro.core.hlo_analysis import analyze_hlo
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.models import batch_spec, build_model
from repro.parallel import sharding as shd
from repro.parallel.context import ParallelContext
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def make_context(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 overrides: dict | None = None) -> ParallelContext:
    o = overrides or {}
    return ParallelContext(
        mesh=mesh,
        data_axes=data_axes_of(mesh),
        model_axis="model",
        seq_parallel=o.get(
            "seq_parallel",
            shape.kind == "prefill" and cfg.partitioned_collectives
            and cfg.family in ("dense", "moe", "vlm", "audio")),
        moe_mode=o.get("moe_mode", "ep" if cfg.family == "moe" else "dense"),
        n_parts=o.get("n_parts", cfg.halo_n_parts
                      if cfg.partitioned_collectives else 1),
        state_method=o.get("state_method", "ring"),
        tp_mode=o.get("tp_mode", "gspmd"),
    )


def _microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    if shape.kind != "train" or cfg.train_microbatches <= 1:
        return 1
    dsize = int(np.prod([mesh.shape[a] for a in data_axes_of(mesh)]))
    mb = min(cfg.train_microbatches, max(1, shape.global_batch // dsize))
    while shape.global_batch % mb or (shape.global_batch // mb) % dsize:
        mb -= 1
    return max(1, mb)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               overrides: dict | None = None):
    """Returns (step_fn, abstract_args, in_shardings, donate_argnums)."""
    model = build_model(cfg)
    ctx = make_context(cfg, shape, mesh, overrides)
    da = ctx.data_axes
    msize = mesh.shape["model"]

    if shape.kind == "train":
        opt_cfg = OptimizerConfig()
        mb = _microbatches(cfg, shape, mesh)
        step = make_train_step(model, opt_cfg, ctx, microbatches=mb)
        params_sh = model.init_shape()
        state_sh = {"params": params_sh,
                    "opt": jax.eval_shape(
                        lambda: init_opt_state(params_sh, opt_cfg,
                                               cfg.opt_state_dtype))}
        pkw = dict(model_axis="model", model_size=msize,
                   fsdp_experts=cfg.fsdp_experts, data_axes=da, mesh=mesh)
        pspec = shd.param_pspecs(params_sh, **pkw)
        mspec = shd.zero1_pspecs(
            state_sh["opt"]["m"],
            shd.param_pspecs(state_sh["opt"]["m"], **pkw),
            data_axes=da, mesh=mesh)
        state_spec = {"params": pspec,
                      "opt": {"m": mspec, "v": mspec, "step": P()}}
        bspec_tree = batch_spec(cfg, shape)
        bspec = shd.batch_pspecs(bspec_tree, data_axes=da, mesh=mesh)
        args = (
            shd.shaped_with_sharding(state_sh, mesh, state_spec),
            shd.shaped_with_sharding(bspec_tree, mesh, bspec),
        )
        return step, args, (0,)

    model_obj = model
    if shape.kind == "prefill" and cfg.is_encoder_only:
        # encoder-only: the inference-prefill cell is a full encode pass
        bspec_tree = batch_spec(cfg, shape)
        bspec_tree.pop("labels", None)
        bspec_tree.pop("mask", None)
        params_sh = model.init_shape()
        pspec = shd.param_pspecs(params_sh, model_axis="model",
                                 model_size=msize,
                                 fsdp_experts=cfg.fsdp_experts,
                                 data_axes=da, mesh=mesh)
        bspec = shd.batch_pspecs(bspec_tree, data_axes=da, mesh=mesh)

        def encode_step(params, batch):
            return model_obj.logits(params, batch, ctx=ctx)

        args = (
            shd.shaped_with_sharding(params_sh, mesh, pspec),
            shd.shaped_with_sharding(bspec_tree, mesh, bspec),
        )
        return encode_step, args, ()

    if shape.kind == "prefill":
        bspec_tree = batch_spec(cfg, shape)
        cache_sh = model.cache_spec(shape.global_batch, shape.seq_len)

        def serve_step(params, batch, cache):
            return model_obj.prefill(params, batch, cache, ctx=ctx)

    else:  # decode
        bspec_tree = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32)}
        cache_sh = model.cache_spec(shape.global_batch, shape.seq_len)

        def serve_step(params, batch, cache):
            return model_obj.decode_step(params, batch["tokens"], cache,
                                         ctx=ctx)

    params_sh = model.init_shape()
    pspec = shd.param_pspecs(params_sh, model_axis="model", model_size=msize,
                             fsdp_experts=cfg.fsdp_experts, data_axes=da,
                             mesh=mesh)
    bspec = shd.batch_pspecs(bspec_tree, data_axes=da, mesh=mesh)
    cspec = shd.cache_pspecs(cache_sh, data_axes=da, model_axis="model",
                             model_size=msize, mesh=mesh)
    args = (
        shd.shaped_with_sharding(params_sh, mesh, pspec),
        shd.shaped_with_sharding(bspec_tree, mesh, bspec),
        shd.shaped_with_sharding(cache_sh, mesh, cspec),
    )
    return serve_step, args, (2,)


# ---------------------------------------------------------------------------
# depth-reduced variants (roofline trip-count correction)
# ---------------------------------------------------------------------------


def reduced_depth(cfg: ModelConfig, units: int) -> tuple[ModelConfig, int]:
    """A config with ``units`` scan iterations; returns (cfg, full_units)."""
    if cfg.family == "hybrid":
        g = cfg.attn_every
        full = cfg.n_layers // g  # groups (tail ~ scaled by analyzer)
        return cfg.with_updates(n_layers=units * g), full
    if cfg.family == "vlm":
        per = cfg.n_layers // cfg.n_cross_layers
        full = cfg.n_cross_layers
        return cfg.with_updates(n_layers=units * per, n_cross_layers=units), full
    return cfg.with_updates(n_layers=units), cfg.n_layers


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------


def _save_hlo(text: str, path: str) -> None:
    with open(path, "wb") as f:
        f.write(zstandard.ZstdCompressor(level=6).compress(text.encode()))


def _load_hlo(path: str) -> str:
    with open(path, "rb") as f:
        return zstandard.ZstdDecompressor().decompress(f.read()).decode()


def _stats_dict(text: str, trip_default: int) -> dict:
    stats = analyze_hlo(text, default_group=1, default_trip=trip_default)
    return {
        "flops": stats.flops,
        "bytes": stats.bytes,
        "wire_bytes": stats.wire_bytes,
        "wire_by_op": {k: float(v) for k, v in stats.by_op_bytes.items()},
        "coll_counts": dict(stats.by_op_counts),
        "n_loops": stats.n_loops,
        "trip_counts": stats.trip_counts[:64],
    }


def _analyze(compiled, cfg: ModelConfig, trip_default: int) -> dict:
    ma = compiled.memory_analysis()
    from repro.core.compat import cost_analysis_dict

    ca = cost_analysis_dict(compiled)
    text = compiled.as_text()
    stats = analyze_hlo(text, default_group=1, default_trip=trip_default)
    return {
        # loop-aware totals (DESIGN.md §6); xla_* are the raw cost_analysis
        # numbers (loop bodies counted once) kept for cross-reference.
        "flops": stats.flops,
        "bytes": stats.bytes,
        "xla_flops": float(ca.get("flops", 0.0)),
        "xla_bytes": float(ca.get("bytes accessed", 0.0)),
        "wire_bytes": stats.wire_bytes,
        "wire_by_op": {k: float(v) for k, v in stats.by_op_bytes.items()},
        "coll_counts": dict(stats.by_op_counts),
        "n_loops": stats.n_loops,
        "trip_counts": stats.trip_counts[:64],
        "memory": {
            "argument": ma.argument_size_in_bytes,
            "output": ma.output_size_in_bytes,
            "temp": ma.temp_size_in_bytes,
            "peak": ma.peak_memory_in_bytes,
            "alias": ma.alias_size_in_bytes,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, depth_variants: bool = False,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    # "cfg.<field>=<val>" overrides patch the model config (perf experiments)
    if overrides:
        patches = {k[4:]: v for k, v in overrides.items()
                   if k.startswith("cfg.")}
        if patches:
            cfg = cfg.with_updates(**patches)
        overrides = {k: v for k, v in overrides.items()
                     if not k.startswith("cfg.")}
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    result: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.size,
        "overrides": overrides or {},
        "microbatches": _microbatches(cfg, shape, mesh),
    }
    t0 = time.time()
    step, args, donate = build_cell(cfg, shape, mesh, overrides)
    with set_mesh(mesh):
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 1)
    trip = reduced_depth(cfg, 1)[1]
    result["full"] = _analyze(compiled, cfg, trip)
    hlo_path = cell_path(arch, shape_name, multi_pod, tag) + ".hlo.zst"
    _save_hlo(compiled.as_text(), hlo_path)
    hbm = 16e9
    need = result["full"]["memory"]["peak"] or (
        result["full"]["memory"]["argument"] + result["full"]["memory"]["temp"]
        + result["full"]["memory"]["output"])
    result["fits_16gb"] = bool(need <= hbm)
    del compiled, lowered

    if depth_variants:
        # L=1 / L=2 compiles for the scan flop/byte correction
        for units in (1, 2):
            cfg_u, full_units = reduced_depth(cfg, units)
            step_u, args_u, donate_u = build_cell(cfg_u, shape, mesh, overrides)
            with set_mesh(mesh):
                comp_u = jax.jit(step_u, donate_argnums=donate_u).lower(
                    *args_u).compile()
            result[f"depth{units}"] = _analyze(comp_u, cfg_u, units)
            del comp_u
        result["scan_units_full"] = reduced_depth(cfg, 1)[1]
    return result


def cell_path(arch: str, shape_name: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "multi" if multi_pod else "single"
    suffix = f".{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}.{shape_name}.{mesh}{suffix}.json")


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            cells.append((arch, shape.name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--depth-variants", action="store_true",
                    help="also compile L=1/L=2 variants (debug cross-check)")
    ap.add_argument("--tag", default="", help="result-file suffix for perf "
                    "experiments (e.g. hillclimb variants)")
    ap.add_argument("--set", action="append", default=[],
                    help="context override k=v (seq_parallel, n_parts, "
                    "moe_mode, state_method)")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute analysis from stored HLO (no compile)")
    args = ap.parse_args()

    if args.reanalyze:
        import glob as _glob

        for jpath in sorted(_glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
            hpath = jpath + ".hlo.zst"
            if not os.path.exists(hpath):
                continue
            with open(jpath) as f:
                res = json.load(f)
            cfg = get_config(res["arch"])
            trip = reduced_depth(cfg, 1)[1]
            res["full"].update(_stats_dict(_load_hlo(hpath), trip))
            with open(jpath, "w") as f:
                json.dump(res, f, indent=1)
            print(f"reanalyzed {os.path.basename(jpath)}", flush=True)
        return

    overrides: dict = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = (v == "true" if v in ("true", "false") else
                        int(v) if v.isdigit() else v)

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    os.makedirs(RESULTS_DIR, exist_ok=True)

    ok = fail = skip = 0
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            multi = mesh_kind == "multi"
            path = cell_path(arch, shape_name, multi, args.tag)
            if os.path.exists(path) and not args.force:
                skip += 1
                continue
            label = f"{arch} x {shape_name} x {mesh_kind}"
            try:
                res = run_cell(arch, shape_name, multi, overrides or None,
                               depth_variants=args.depth_variants and not multi,
                               tag=args.tag)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                m = res["full"]["memory"]
                print(f"PASS {label}: compile={res['compile_s']}s "
                      f"peak={m['peak']/1e9:.2f}GB args={m['argument']/1e9:.2f}GB "
                      f"fits={res['fits_16gb']} "
                      f"flops={res['full']['flops']:.3e} "
                      f"wire={res['full']['wire_bytes']/1e9:.3f}GB", flush=True)
                ok += 1
            except Exception as e:
                fail += 1
                print(f"FAIL {label}: {type(e).__name__}: {e}", flush=True)
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
    print(f"done: {ok} pass, {fail} fail, {skip} cached", flush=True)
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
