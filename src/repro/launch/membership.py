"""Coordinator-led membership for elastic grids: JOIN, loss, epochs.

PR 6's elastic runner survives rank loss by tearing the whole
``jax.distributed`` grid down and relaunching it — every surviving rank
pays plan re-initialization from nothing, which is exactly the
amortization the source paper says persistent communication exists to
protect.  This module is the phase-2 piece: a tiny membership service the
coordinator (rank 0) runs, which lets the grid re-form *around* the
survivors instead of *instead of* them.

Three ideas, mirrored from how pMR keeps persistent connection state
alive across reconfiguration:

``epoch``
    A monotone counter naming one stable composition of the grid.
    Formation is epoch 0; every JOIN and every detected loss bumps it.
    The epoch is stamped into :class:`~repro.core.halo.HaloSpec` /
    :class:`~repro.core.transport.ScheduleInfo` (``tag()`` suffix
    ``!e<epoch>``) and therefore into every persistent plan key, so a
    plan compiled against a dead composition can never be a cache hit —
    and :meth:`~repro.core.plan.PlanCache.invalidate_stale_epochs` can
    drop exactly those plans while every other warmed plan stays
    resident.

JOIN
    A new worker registers mid-run.  The coordinator admits it, bumps
    the epoch, and announces the new member set; survivors grow the mesh
    and move *live* state onto it via
    :func:`repro.train.fault_tolerance.reshard_state` — no checkpoint
    restore, no process relaunch.

in-grid LOSS recovery
    Workers heartbeat each step.  A rank that misses the heartbeat
    window is declared lost, the epoch bumps, and the survivors run a
    coordinator-led barrier (:meth:`MembershipService.ack`) before
    re-initializing on the shrunken member set — processes stay up,
    caches stay warm.  Only when the *coordinator itself* dies
    (:class:`CoordinatorLost`) does recovery fall back to the PR 6
    relaunch path.

The service state machine is transport-free (drive it in-process with a
fake clock in tests); :class:`MembershipServer` / :class:`MembershipClient`
put it behind a JSON-per-line TCP socket advertised through the
``REPRO_MEMBERSHIP`` env var, riding the same ``REPRO_*`` env protocol
:mod:`repro.launch.stencil` already uses to form grids.
"""

from __future__ import annotations

import dataclasses
import json
import os
import socket
import socketserver
import threading
import time
from typing import Callable

from repro.train.fault_tolerance import EpochBump, HeartbeatLedger

__all__ = [
    "MEMBERSHIP_VAR",
    "CoordinatorLost",
    "MemberView",
    "MembershipService",
    "MembershipServer",
    "MembershipClient",
    "membership_env",
    "serve_from_env",
    "client_from_env",
]

#: env var carrying the coordinator's membership endpoint ("host:port"),
#: stamped next to REPRO_COORDINATOR by :func:`repro.launch.stencil.worker_env`
MEMBERSHIP_VAR = "REPRO_MEMBERSHIP"


class CoordinatorLost(RuntimeError):
    """The membership coordinator is unreachable or has declared itself
    dead.  In-grid recovery is impossible without it — callers fall back
    to the PR 6 relaunch path."""


@dataclasses.dataclass(frozen=True)
class MemberView:
    """One stable composition of the grid, as the coordinator announces it.

    ``cause`` records why this epoch exists: ``"form"`` (initial seal),
    ``"join"`` (a rank registered mid-run), or ``"loss"`` (missed
    heartbeats).  Everything a worker needs to re-form — who is in, and
    under which epoch its new plans must be stamped — is here.
    """

    epoch: int
    members: tuple[int, ...]
    cause: str = "form"

    def to_wire(self) -> dict:
        return {"epoch": self.epoch, "members": list(self.members),
                "cause": self.cause}

    @staticmethod
    def from_wire(d: dict) -> "MemberView":
        return MemberView(epoch=int(d["epoch"]),
                          members=tuple(int(r) for r in d["members"]),
                          cause=str(d["cause"]))


class MembershipService:
    """The coordinator-side state machine (transport-free).

    Lifecycle: workers :meth:`register` during formation, the coordinator
    :meth:`seal`\\ s the founding set at epoch 0, then workers
    :meth:`heartbeat` every step.  After the seal, :meth:`register` is a
    JOIN (epoch bump, ``cause="join"``); :meth:`detect_losses` +
    :meth:`mark_lost` is the loss path (epoch bump, ``cause="loss"``).
    Each bump opens a barrier: survivors :meth:`ack` the new epoch and
    poll :meth:`barrier_complete` before touching the re-formed mesh, so
    no rank runs ahead into a composition its peers have not adopted.

    ``clock`` is injectable so heartbeat-timeout tests never sleep.
    """

    def __init__(self, *, heartbeat_timeout: float = 5.0,
                 clock: Callable[[], float] = time.monotonic,
                 start_epoch: int = 0):
        self._lock = threading.Lock()
        self._clock = clock
        self.heartbeat_timeout = float(heartbeat_timeout)
        self._ledger = HeartbeatLedger(timeout=heartbeat_timeout)
        # a replacement coordinator (after CoordinatorLost -> relaunch)
        # seeds start_epoch past its predecessor's last bump, keeping plan
        # staleness monotone across the coordinator generation change
        self._epoch = EpochBump(epoch=start_epoch, cause="form")
        self._sealed = False
        self._alive = True
        self._acked: set[int] = set()

    # -- introspection ------------------------------------------------------
    @property
    def alive(self) -> bool:
        return self._alive

    @property
    def view(self) -> MemberView:
        with self._lock:
            return self._view_locked()

    def _view_locked(self) -> MemberView:
        return MemberView(epoch=self._epoch.epoch,
                          members=self._ledger.ranks,
                          cause=self._epoch.cause)

    def _check_alive(self) -> None:
        if not self._alive:
            raise CoordinatorLost("membership coordinator is down")

    # -- formation & JOIN ---------------------------------------------------
    def register(self, rank: int) -> MemberView:
        """Admit ``rank``.  Before :meth:`seal` this is formation (no
        epoch bump); after it, it is a JOIN and the epoch advances."""
        self._check_alive()
        with self._lock:
            joined_late = self._sealed and rank not in self._ledger
            self._ledger.beat(rank, self._clock())
            if joined_late:
                self._bump_locked("join")
            return self._view_locked()

    def seal(self) -> MemberView:
        """Formation complete: the current member set is epoch 0."""
        self._check_alive()
        with self._lock:
            self._sealed = True
            return self._view_locked()

    # -- heartbeats & LOSS --------------------------------------------------
    def heartbeat(self, rank: int, step: int | None = None) -> MemberView:
        """Record a beat and return the current view — the worker learns
        of any epoch bump from the return value, no push channel needed."""
        self._check_alive()
        with self._lock:
            if rank in self._ledger:
                self._ledger.beat(rank, self._clock(), step=step)
            return self._view_locked()

    def detect_losses(self) -> tuple[int, ...]:
        """Ranks whose last beat is older than the heartbeat window."""
        self._check_alive()
        now = self._clock()
        with self._lock:
            return self._ledger.missing(now)

    def mark_lost(self, *ranks: int) -> MemberView:
        """Evict ``ranks`` and bump the epoch (``cause="loss"``)."""
        self._check_alive()
        with self._lock:
            evicted = False
            for r in ranks:
                evicted = self._ledger.evict(r) or evicted
            if evicted:
                self._bump_locked("loss")
            return self._view_locked()

    def _bump_locked(self, cause: str) -> None:
        self._epoch = EpochBump(epoch=self._epoch.epoch + 1, cause=cause)
        self._acked.clear()  # each epoch opens a fresh barrier

    # -- coordinator-led barrier -------------------------------------------
    def ack(self, rank: int, epoch: int) -> MemberView:
        """Survivor ``rank`` has adopted ``epoch`` (stale plans dropped,
        mesh re-formed).  Acks for a superseded epoch are ignored."""
        self._check_alive()
        with self._lock:
            if epoch == self._epoch.epoch and rank in self._ledger:
                self._acked.add(rank)
            return self._view_locked()

    def barrier_complete(self, epoch: int) -> bool:
        """True once every current member has acked ``epoch``."""
        self._check_alive()
        with self._lock:
            return (epoch == self._epoch.epoch
                    and self._acked >= set(self._ledger.ranks))

    # -- chaos --------------------------------------------------------------
    def fail(self) -> None:
        """Kill the coordinator (chaos hook): every subsequent call
        raises :class:`CoordinatorLost`, which is the relaunch-fallback
        trigger."""
        self._alive = False


# ---------------------------------------------------------------------------
# TCP wire: JSON-per-line request/response over the REPRO_* env protocol
# ---------------------------------------------------------------------------

_OPS = {
    "register": lambda svc, req: svc.register(int(req["rank"])).to_wire(),
    "seal": lambda svc, req: svc.seal().to_wire(),
    "heartbeat": lambda svc, req: svc.heartbeat(
        int(req["rank"]), req.get("step")).to_wire(),
    "view": lambda svc, req: svc.view.to_wire(),
    "detect": lambda svc, req: {"lost": list(svc.detect_losses())},
    "mark_lost": lambda svc, req: svc.mark_lost(
        *[int(r) for r in req["ranks"]]).to_wire(),
    "ack": lambda svc, req: svc.ack(
        int(req["rank"]), int(req["epoch"])).to_wire(),
    "barrier": lambda svc, req: {
        "complete": svc.barrier_complete(int(req["epoch"]))},
}


class _Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        line = self.rfile.readline()
        if not line:
            return
        svc = self.server.service  # type: ignore[attr-defined]
        req = json.loads(line.decode("utf-8"))
        try:
            body = _OPS[req["op"]](svc, req)
            resp = {"ok": True, **body}
        except CoordinatorLost as e:
            resp = {"ok": False, "error": "coordinator-lost", "detail": str(e)}
        except Exception as e:  # malformed request: report, don't kill server
            resp = {"ok": False, "error": type(e).__name__, "detail": str(e)}
        self.wfile.write((json.dumps(resp) + "\n").encode("utf-8"))


class MembershipServer:
    """Threaded TCP front for one :class:`MembershipService`.

    One request per connection (connect, one JSON line each way, close) —
    stateless on the wire, so a worker that dies mid-request leaves no
    half-open session behind, and the client needs no reconnect logic.
    """

    def __init__(self, service: MembershipService,
                 host: str = "127.0.0.1", port: int = 0):
        self.service = service
        self._srv = socketserver.ThreadingTCPServer(
            (host, port), _Handler, bind_and_activate=True)
        self._srv.daemon_threads = True
        self._srv.service = service  # type: ignore[attr-defined]
        self._thread = threading.Thread(
            target=self._srv.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True)
        self._thread.start()

    @property
    def address(self) -> str:
        host, port = self._srv.server_address[:2]
        return f"{host}:{port}"

    def close(self) -> None:
        self._srv.shutdown()
        self._srv.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MembershipServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class MembershipClient:
    """Worker-side stub.  Any transport failure — refused connection,
    timeout, torn socket, or the server answering ``coordinator-lost`` —
    surfaces as :class:`CoordinatorLost`: from a worker's point of view
    they are the same event, and all of them route to relaunch fallback."""

    def __init__(self, address: str, *, timeout: float = 5.0):
        host, _, port = address.rpartition(":")
        self.host, self.port = host, int(port)
        self.timeout = float(timeout)

    def _call(self, **req) -> dict:
        try:
            with socket.create_connection(
                    (self.host, self.port), timeout=self.timeout) as sock:
                sock.sendall((json.dumps(req) + "\n").encode("utf-8"))
                with sock.makefile("rb") as f:
                    line = f.readline()
        except OSError as e:
            raise CoordinatorLost(
                f"membership endpoint {self.host}:{self.port}: {e}") from e
        if not line:
            raise CoordinatorLost("membership coordinator closed connection")
        resp = json.loads(line.decode("utf-8"))
        if not resp.get("ok"):
            if resp.get("error") == "coordinator-lost":
                raise CoordinatorLost(resp.get("detail", "coordinator down"))
            raise RuntimeError(
                f"membership op {req['op']!r} failed: {resp}")
        return resp

    def register(self, rank: int) -> MemberView:
        return MemberView.from_wire(self._call(op="register", rank=rank))

    def seal(self) -> MemberView:
        return MemberView.from_wire(self._call(op="seal"))

    def heartbeat(self, rank: int, step: int | None = None) -> MemberView:
        return MemberView.from_wire(
            self._call(op="heartbeat", rank=rank, step=step))

    def view(self) -> MemberView:
        return MemberView.from_wire(self._call(op="view"))

    def detect_losses(self) -> tuple[int, ...]:
        return tuple(int(r) for r in self._call(op="detect")["lost"])

    def mark_lost(self, *ranks: int) -> MemberView:
        return MemberView.from_wire(
            self._call(op="mark_lost", ranks=list(ranks)))

    def ack(self, rank: int, epoch: int) -> MemberView:
        return MemberView.from_wire(
            self._call(op="ack", rank=rank, epoch=epoch))

    def barrier_complete(self, epoch: int) -> bool:
        return bool(self._call(op="barrier", epoch=epoch)["complete"])


def membership_env(address: str,
                   base: dict[str, str] | None = None) -> dict[str, str]:
    """Env block advertising the coordinator's membership endpoint —
    merged into :func:`repro.launch.stencil.worker_env` output so grid
    workers find the service the same way they find the jax coordinator."""
    env = dict(base or {})
    env[MEMBERSHIP_VAR] = address
    return env


def serve_from_env(service: MembershipService,
                   env: dict[str, str] | None = None
                   ) -> MembershipServer | None:
    """Bind the advertised membership endpoint (the rank-0 side).

    :func:`repro.launch.stencil.launch_grid` picks the port and stamps
    ``REPRO_MEMBERSHIP`` into every rank's env; the rank-0 program calls
    this to actually host the service there.  ``None`` when the grid was
    launched without membership.
    """
    addr = (env if env is not None else os.environ).get(MEMBERSHIP_VAR)
    if not addr:
        return None
    host, _, port = addr.rpartition(":")
    return MembershipServer(service, host=host, port=int(port))


def client_from_env(env: dict[str, str] | None = None,
                    *, timeout: float = 5.0) -> MembershipClient | None:
    """A client for the advertised endpoint, or ``None`` when the grid
    was launched without a membership service (every pre-phase-2 path)."""
    addr = (env if env is not None else os.environ).get(MEMBERSHIP_VAR)
    return MembershipClient(addr, timeout=timeout) if addr else None
