"""Multi-process stencil launcher — the real backend behind the ``multihost``
transport seam.

    PYTHONPATH=src python -m repro.launch.stencil --processes 2 \\
        --strategies all --packers slice,bf16 --size 16,8

Boots N worker processes under ``jax.distributed.initialize`` (the first
rank hosts the coordinator service, the paper's ``mpirun -np N`` analogue),
each pinning its own ``--devices-per-process`` virtual CPU devices, then
builds ONE global mesh spanning every process and runs the requested
strategy x packer cells through the ``multihost`` transport.  Every cell is
verified shard-by-shard against the single-process reference roll
(:func:`repro.stencil.domain.reference_exchange`) before it is timed with
:func:`repro.stencil.comb.comb_measure`, so a cell that moves wrong bytes
across the process boundary can never report a speedup.

The launch pattern mirrors ``repro.launch.train``: the coordinator address
travels in env vars (here ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
``REPRO_PROCESS_ID``, set by :func:`worker_env`), and a worker calls
:func:`maybe_initialize_from_env` *before its first jax device query* —
anything launched through :func:`launch_grid` (this CLI, the sweep's
``--processes`` fan-out, ``tests/distributed_progs/check_multihost.py``)
joins the same grid protocol.  On a real cluster the same worker code runs
under the site launcher by exporting the three variables per rank.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import re
import socket
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Mapping, Sequence

#: env vars carrying the grid coordinates to worker processes
COORDINATOR_VAR = "REPRO_COORDINATOR"
NUM_PROCESSES_VAR = "REPRO_NUM_PROCESSES"
PROCESS_ID_VAR = "REPRO_PROCESS_ID"
#: bound (seconds) on a worker's connect to the rank-0 coordinator — a
#: worker whose coordinator died before binding exits instead of blocking
#: in ``jax.distributed`` init forever
CONNECT_TIMEOUT_VAR = "REPRO_CONNECT_TIMEOUT"

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def pick_coordinator_port() -> int:
    """A free TCP port for the rank-0 coordinator service.

    Inherently racy (TOCTOU): the port is bound, released, and only later
    re-bound by ``jax.distributed`` inside the rank-0 worker — under
    parallel CI jobs another process can steal it in between.  The race
    cannot be closed from here (the coordinator must bind it in a *child*
    process), so :func:`launch_grid` treats a coordinator bind failure as
    retryable and relaunches with a fresh port (bounded attempts).
    """
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


#: stderr signatures of the coordinator losing the picked port to the
#: TOCTOU race above — and nothing else: injected chaos failures, assertion
#: deaths, or OOMs must never be retried into silence.
_PORT_RACE_SIGNATURES = (
    "address already in use",
    "eaddrinuse",
    "failed to bind",
    "errno 98",
)


def is_port_race_failure(errs: Sequence[str],
                         returncodes: Sequence[int]) -> bool:
    """Did this grid die because the coordinator port was stolen?"""
    return any(
        rc != 0 and any(sig in err.lower() for sig in _PORT_RACE_SIGNATURES)
        for err, rc in zip(errs, returncodes)
    )


def worker_env(
    *,
    local_devices: int,
    coordinator: str | None = None,
    num_processes: int = 1,
    process_id: int = 0,
    base: Mapping[str, str] | None = None,
    connect_timeout: float | None = None,
    membership: str | None = None,
) -> dict[str, str]:
    """The environment one worker process boots with.

    Pins exactly ``local_devices`` virtual CPU devices (replacing any
    device-count pin inherited from the parent — the launcher may itself
    run under the 8-device test env — while preserving other XLA flags)
    and prepends this checkout's ``src`` to ``PYTHONPATH`` so spawned
    workers resolve the same ``repro``.  With ``coordinator`` set the grid
    coordinates are stamped too; without it this is the plain
    single-process worker env (what the sweep's historical device-count
    fan-out boots).
    """
    env = dict(os.environ if base is None else base)
    flags = re.sub(rf"{_DEVICE_FLAG}=\d+", "", env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = f"{flags} {_DEVICE_FLAG}={local_devices}".strip()
    from repro.launch.membership import MEMBERSHIP_VAR

    if coordinator is not None:
        env[COORDINATOR_VAR] = coordinator
        env[NUM_PROCESSES_VAR] = str(num_processes)
        env[PROCESS_ID_VAR] = str(process_id)
    else:
        for var in (COORDINATOR_VAR, NUM_PROCESSES_VAR, PROCESS_ID_VAR):
            env.pop(var, None)  # never inherit stale grid coordinates
    # connect bound + membership endpoint follow the same rule: stamped
    # when this launch provides them, scrubbed otherwise
    if connect_timeout is not None and coordinator is not None:
        env[CONNECT_TIMEOUT_VAR] = str(connect_timeout)
    else:
        env.pop(CONNECT_TIMEOUT_VAR, None)
    if membership is not None:
        env[MEMBERSHIP_VAR] = membership
    else:
        env.pop(MEMBERSHIP_VAR, None)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def maybe_initialize_from_env() -> int:
    """Join the process grid named by the ``REPRO_*`` env vars; return rank.

    No-op (rank 0 of a 1-process world) when the variables are absent, so
    worker entry points stay runnable standalone.  Must be called before
    the process's first jax device query: ``jax.distributed.initialize``
    cannot attach once the backend client exists.  CPU cross-process
    collectives are switched on through
    :func:`repro.core.compat.enable_cpu_collectives`.
    """
    coordinator = os.environ.get(COORDINATOR_VAR)
    if not coordinator:
        return 0
    from repro.core import compat

    compat.enable_cpu_collectives()
    import jax

    num_processes = int(os.environ[NUM_PROCESSES_VAR])
    process_id = int(os.environ[PROCESS_ID_VAR])
    connect_timeout = os.environ.get(CONNECT_TIMEOUT_VAR)
    compat.distributed_initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
        timeout=float(connect_timeout) if connect_timeout else None,
    )
    assert jax.process_count() == num_processes, (
        jax.process_count(), num_processes,
    )
    return process_id


@dataclasses.dataclass(frozen=True)
class GridResult:
    """Per-rank outcome of a ``check=False`` grid launch.

    The chaos tests launch grids that are *expected* to die mid-run (an
    injected rank loss); they need the returncodes and streams of every
    rank instead of the raise-on-failure contract.
    """

    outs: tuple[str, ...]
    errs: tuple[str, ...]
    returncodes: tuple[int, ...]

    @property
    def ok(self) -> bool:
        return all(rc == 0 for rc in self.returncodes)

    @property
    def failed_ranks(self) -> tuple[int, ...]:
        return tuple(r for r, rc in enumerate(self.returncodes) if rc != 0)


def _launch_grid_once(
    argv: Sequence[str],
    *,
    processes: int,
    local_devices: int,
    timeout: float,
    env: Mapping[str, str] | None,
    reap_grace: float = 10.0,
    membership: bool = False,
) -> GridResult:
    """One grid attempt against a freshly picked coordinator port.

    A rank exiting nonzero dooms the whole SPMD grid, so the wait is a
    poll: once the first failure lands, the remaining ranks get
    ``reap_grace`` seconds to die on their own (collective errors
    propagate), then any still-running rank is reaped and reported in
    :attr:`GridResult.failed_ranks`.  Without the reap, a worker whose
    coordinator died before binding blocks in ``jax.distributed`` init
    for the full grid ``timeout`` — the zombie-grid CI hang.  The
    worker-side half of the same fix is the ``REPRO_CONNECT_TIMEOUT``
    bound stamped into every rank's env.

    With ``membership`` a port for the rank-0 membership service
    (:mod:`repro.launch.membership`) is picked here and advertised to
    every rank through ``REPRO_MEMBERSHIP``; the rank-0 program binds it
    via :func:`repro.launch.membership.serve_from_env`.
    """
    coordinator = f"127.0.0.1:{pick_coordinator_port()}"
    membership_addr = (
        f"127.0.0.1:{pick_coordinator_port()}" if membership else None
    )
    procs, files = [], []
    deadline = time.monotonic() + timeout
    reap_at = None  # set when the first rank dies nonzero
    try:
        for rank in range(processes):
            # spool each rank's streams to temp files: every rank drains
            # concurrently (a chatty rank can never fill a pipe and stall
            # the collectives of the whole grid)
            out_f = tempfile.TemporaryFile(mode="w+")
            err_f = tempfile.TemporaryFile(mode="w+")
            files.append((out_f, err_f))
            procs.append(subprocess.Popen(
                list(argv),
                env=worker_env(
                    coordinator=coordinator, num_processes=processes,
                    process_id=rank, local_devices=local_devices, base=env,
                    connect_timeout=timeout, membership=membership_addr,
                ),
                stdout=out_f, stderr=err_f, text=True,
            ))
        while any(p.poll() is None for p in procs):
            now = time.monotonic()
            if now >= deadline:  # ONE shared wall-clock budget
                raise RuntimeError(
                    f"grid did not complete within {timeout:.0f}s "
                    f"({sum(p.poll() is None for p in procs)} of "
                    f"{processes} ranks still running)"
                )
            if reap_at is None and any(
                    p.poll() is not None and p.returncode != 0
                    for p in procs):
                reap_at = min(now + reap_grace, deadline)
            if reap_at is not None and now >= reap_at:
                for p in procs:  # reap the blocked zombies
                    if p.poll() is None:
                        p.kill()
                        p.wait()
                break
            time.sleep(0.05)
    finally:
        for p in procs:  # one rank dying must not strand the others
            if p.poll() is None:
                p.kill()
                p.wait()
        outs, errs = [], []
        for out_f, err_f in files:
            out_f.seek(0)
            err_f.seek(0)
            outs.append(out_f.read())
            errs.append(err_f.read())
            out_f.close()
            err_f.close()
    return GridResult(
        outs=tuple(outs), errs=tuple(errs),
        returncodes=tuple(p.returncode for p in procs),
    )


def launch_grid(
    argv: Sequence[str],
    *,
    processes: int,
    local_devices: int = 2,
    timeout: float = 900.0,
    env: Mapping[str, str] | None = None,
    check: bool = True,
    attempts: int = 3,
    reap_grace: float = 10.0,
    membership: bool = False,
) -> str | GridResult:
    """Run ``argv`` as an N-process ``jax.distributed`` grid; return rank
    0's stdout.

    All ranks execute the same SPMD program; by convention only rank 0
    prints results (the others' stdout is discarded).  Any rank exiting
    nonzero fails the whole grid with that rank's stderr tail — mirroring
    ``run_sweep``'s single-subprocess error contract.  With ``check=False``
    no rank failure raises: the full :class:`GridResult` (every rank's
    stdout/stderr/returncode) is returned instead, for callers that
    *expect* the grid to die — the fault-injection chaos checks.

    Coordinator setup retries: :func:`pick_coordinator_port` is racy by
    construction, so a grid whose failure stderr matches a port-bind
    signature (:func:`is_port_race_failure`) is relaunched with a fresh
    port, up to ``attempts`` total tries.  Only bind failures retry —
    chaos-injected deaths and real program failures surface immediately
    (and reach ``check=False`` callers as their :class:`GridResult`).
    The wall-clock ``timeout`` applies per attempt.
    """
    assert processes >= 1, processes
    assert attempts >= 1, attempts
    for attempt in range(1, attempts + 1):
        result = _launch_grid_once(
            argv, processes=processes, local_devices=local_devices,
            timeout=timeout, env=env, reap_grace=reap_grace,
            membership=membership,
        )
        if result.ok or not (
            attempt < attempts
            and is_port_race_failure(result.errs, result.returncodes)
        ):
            break
        print(
            f"# launch_grid: coordinator port stolen (attempt {attempt} of "
            f"{attempts}); retrying with a fresh port",
            file=sys.stderr,
        )
    if not check:
        return result
    if not result.ok:
        detail = "\n".join(
            f"--- rank {r} (exit {result.returncodes[r]}) ---\n"
            f"{result.errs[r][-4000:]}"
            for r in result.failed_ranks
        )
        raise RuntimeError(
            f"grid ranks {list(result.failed_ranks)} of {processes} "
            f"failed:\n{detail}"
        )
    return result.outs[0]


# ---------------------------------------------------------------------------
# worker-side cell runner (verify + measure on the global mesh)
# ---------------------------------------------------------------------------


def global_stencil_mesh(
    n_devices: int | None = None,
    *,
    mapping: str = "row-major",
    node_size: int = 0,
):
    """A 1-axis mesh over the grid's *global* device list.

    After ``jax.distributed.initialize`` every process sees the same
    ``jax.devices()`` ordering, so each rank independently builds an
    identical mesh spanning all processes.  ``mapping`` permutes rank
    placement onto mesh coordinates through the registered
    :class:`repro.launch.mapping.Mapping` BEFORE the mesh is built (the
    placement is deterministic, so every rank still derives the same mesh);
    ``node_size`` is the ranks-per-node the mapping blocks around
    (0 = auto: devices per process on a real grid).  ``mapping="auto"``
    resolves to the registered mapping minimizing inter-node neighbor
    sends on this topology (:func:`repro.core.autotune.choose_mapping`) —
    mapping is the one autotuned axis that must resolve *before* the mesh
    exists, since a built mesh cannot be re-placed.
    """
    import jax

    from repro.core.compat import make_mesh
    from repro.launch.mapping import default_node_size, get_mapping

    devices = jax.devices()
    n = n_devices or len(devices)
    assert n <= len(devices), (n, len(devices))
    if node_size <= 0:
        node_size = default_node_size(n, jax.process_count())
    if mapping == "auto":
        from repro.core.autotune import choose_mapping

        mapping = choose_mapping((n,), node_size)
    placed = get_mapping(mapping).permute_devices(
        devices[:n], (n,), node_size
    )
    return make_mesh((n,), ("px",), devices=placed)


def verify_strategy_cell(
    domain,
    *,
    strategy: str,
    packer: str = "slice",
    transport: str = "multihost",
    n_parts: int = 3,
    seed: int = 7,
    coalesce: bool = True,
    mapping: str = "row-major",
) -> None:
    """One correctness cell: exchange on the (possibly multi-process) mesh,
    then compare every *addressable* shard against the reference roll.

    Exact packers are held to bitwise equality — the bytes that crossed the
    process boundary must be the bytes the single-process oracle predicts;
    wire-compressed packers are held to their own documented
    :meth:`~repro.core.transport.Packer.wire_tolerance`.
    """
    import numpy as np

    from repro.core.transport import get_packer
    from repro.stencil.domain import reference_exchange
    from repro.stencil.strategies import StrategyConfig, make_driver

    rng = np.random.default_rng(seed)
    interior = rng.normal(size=domain.global_interior).astype(domain.dtype)
    want = reference_exchange(domain, interior)
    drv = make_driver(
        StrategyConfig(
            name=strategy, n_parts=n_parts, packer=packer,
            transport=transport, coalesce=coalesce, mapping=mapping,
        ),
        domain.mesh, domain.halo_spec, ndim=len(domain.global_interior),
    )
    try:
        got = drv.wait(drv.step(domain.from_global_interior(interior)))
    finally:
        drv.free()
    rtol, atol = get_packer(packer).wire_tolerance(domain.dtype)
    for shard in got.addressable_shards:
        data = np.asarray(shard.data)
        ref = want[shard.index]
        msg = (f"{strategy}@{packer}/{transport} n_parts={n_parts} "
               f"coalesce={coalesce} "
               f"shard={shard.index} (rank {shard.device.process_index})")
        if rtol == 0.0 and atol == 0.0:
            np.testing.assert_array_equal(data, ref, err_msg=msg)
        else:
            np.testing.assert_allclose(data, ref, rtol=rtol, atol=atol,
                                       err_msg=msg)


def run_cell(
    *,
    size: tuple[int, ...],
    strategies: Sequence[str],
    packers: Sequence[str],
    transport: str = "multihost",
    halo: int = 1,
    n_parts: int = 3,
    n_cycles: int = 10,
    repeats: int = 1,
    seed: int = 0,
    mapping: str = "row-major",
    emit: Callable[[str], Any] = print,
) -> list[dict]:
    """Verify + measure the strategy x packer cells on the global mesh.

    Returns the flat BENCH-style records of :func:`repro.stencil.comb.
    comb_measure` (one per cell) — callers decide what rank prints.
    """
    import jax

    from repro.stencil.comb import comb_measure
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import StrategyConfig, get_strategy

    if mapping == "auto":
        # resolve BEFORE any StrategyConfig sees it: the placement axis is
        # fixed at mesh construction, so it cannot stay symbolic downstream
        from repro.core.autotune import choose_mapping
        from repro.launch.mapping import default_node_size

        n_all = len(jax.devices())
        mapping = choose_mapping(
            (n_all,), default_node_size(n_all, jax.process_count())
        )
        emit(f"# mapping=auto resolved to {mapping}")
    mesh = global_stencil_mesh(mapping=mapping)
    n = len(mesh.devices.flat)
    assert size[0] % n == 0 and size[0] // n >= 3 * halo, (size, n)
    domain = Domain(
        mesh, global_interior=tuple(size),
        mesh_axes=("px",) + (None,) * (len(size) - 1), halo=halo,
    )
    configs = []
    for packer in packers:
        for s in strategies:
            if s == "auto":
                parts = 1  # the tuner owns the partition-count axis
            else:
                parts = n_parts if get_strategy(s).uses_partitions else 1
            verify_strategy_cell(
                domain, strategy=s, packer=packer, transport=transport,
                n_parts=parts, mapping=mapping,
            )
            emit(f"VERIFIED {s}@{packer}/{transport} on {n} devices "
                 f"across {jax.process_count()} processes")
            configs.append(StrategyConfig(
                name=s, n_parts=parts, packer=packer, transport=transport,
                mapping=mapping,
            ))
    results = comb_measure(
        domain, strategies=tuple(configs),
        n_cycles=n_cycles, repeats=repeats, seed=seed,
    )
    records = []
    for label, res in results.items():
        rec = {
            "label": label,
            "n_devices": n,
            "process_count": jax.process_count(),
            "is_multihost": jax.process_count() > 1,
            "global_interior": list(size),
            **res.record(),
        }
        records.append(rec)
        emit(f"{label}: {res.us_per_cycle:.1f} us/cycle "
             f"(init {res.init_us:.0f} us)")
    return records


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--processes", type=int, default=2,
                    help="process-grid size (ranks under jax.distributed)")
    ap.add_argument("--devices-per-process", type=int, default=2,
                    help="virtual CPU devices each rank pins")
    ap.add_argument("--strategies", default="all",
                    help="comma list of registered strategies, 'all', or "
                         "'auto' (repro.core.autotune picks the strategy "
                         "per cell)")
    ap.add_argument("--packers", default="slice",
                    help="comma list of registered packers, or 'all'")
    ap.add_argument("--transport", default="multihost",
                    help="registered transport every cell routes through")
    ap.add_argument("--mapping", default="row-major",
                    help="registered process-to-node mapping permuting rank "
                         "placement onto the mesh (row-major|blocked|rb), "
                         "or 'auto' to pick the one minimizing inter-node "
                         "neighbor sends on this topology")
    ap.add_argument("--size", default="16,8",
                    help="global interior shape, comma-separated")
    ap.add_argument("--halo", type=int, default=1)
    ap.add_argument("--n-parts", type=int, default=3)
    ap.add_argument("--n-cycles", type=int, default=10)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="per-rank wall-clock limit (seconds)")
    args = ap.parse_args(argv)

    from repro.launch.mapping import canonical_mapping

    if args.mapping != "auto":
        try:  # fail in the launcher, not N spawned ranks deep
            canonical_mapping(args.mapping)
        except KeyError as e:
            ap.error(str(e))

    if COORDINATOR_VAR not in os.environ:
        # launcher: re-run this same CLI as an N-rank grid
        out = launch_grid(
            [sys.executable, "-m", "repro.launch.stencil", *sys.argv[1:]]
            if argv is None else
            [sys.executable, "-m", "repro.launch.stencil", *argv],
            processes=args.processes,
            local_devices=args.devices_per_process,
            timeout=args.timeout,
        )
        print(out, end="")
        return

    # worker: join the grid, then run the cells; only rank 0 reports
    rank = maybe_initialize_from_env()
    from repro.core.transport import available_packers
    from repro.stencil.strategies import available_strategies

    strategies = (available_strategies() if args.strategies == "all"
                  else tuple(args.strategies.split(",")))
    packers = (available_packers() if args.packers == "all"
               else tuple(args.packers.split(",")))
    size = tuple(int(s) for s in args.size.split(","))
    emit = print if rank == 0 else (lambda *_: None)
    records = run_cell(
        size=size, strategies=strategies, packers=packers,
        transport=args.transport, halo=args.halo, n_parts=args.n_parts,
        n_cycles=args.n_cycles, repeats=args.repeats, seed=args.seed,
        mapping=args.mapping, emit=emit,
    )
    emit(f"# {len(records)} multihost cells OK")


if __name__ == "__main__":
    main()
