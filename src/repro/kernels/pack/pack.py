"""Halo pack/unpack Pallas TPU kernel.

The paper packs boundary slabs into contiguous buffers with OpenMP threads
before communication.  The TPU analogue is a VMEM-tiled strided-to-contiguous
copy, with two fusions the CPU version cannot do for free:

* dtype conversion on the fly (e.g. f32 mesh -> bf16 wire format, halving
  halo bytes on the wire — a gradient-compression-style optimization), and
* optional scaling (for compressed-wire formats).

The kernel operates on a 2-D view (lead, lane) of the slab; ``ops.py`` builds
that view, splits partitions, and re-inserts unpacked ghosts.  Grid tiles are
(block_lead, block_lane) VMEM blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params


def _copy_convert_kernel(x_ref, o_ref, *, scale: float):
    x = x_ref[...]
    if scale != 1.0:
        x = x.astype(jnp.float32) * scale
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "scale", "block_lead", "block_lane", "interpret"),
)
def pack_2d(
    slab: jax.Array,  # (lead, lane) view of a boundary slab
    *,
    out_dtype=None,
    scale: float = 1.0,
    block_lead: int = 256,
    block_lane: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Tiled contiguous copy (+convert/scale) of a 2-D slab view."""
    lead, lane = slab.shape
    out_dtype = out_dtype or slab.dtype
    bl = min(block_lead, lead)
    bn = min(block_lane, lane)
    # pad to tile multiples (the paper's equal-partition padding, §II-B)
    pl_lead = -lead % bl
    pl_lane = -lane % bn
    padded = slab
    if pl_lead or pl_lane:
        padded = jnp.pad(slab, ((0, pl_lead), (0, pl_lane)))
    grid = (padded.shape[0] // bl, padded.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_copy_convert_kernel, scale=scale),
        grid=grid,
        in_specs=[pl.BlockSpec((bl, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bl, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, out_dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(padded)
    if pl_lead or pl_lane:
        out = out[:lead, :lane]
    return out


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "scale", "block_lead", "block_lane", "interpret"),
)
def unpack_2d(
    buf: jax.Array,
    *,
    out_dtype=None,
    scale: float = 1.0,
    block_lead: int = 256,
    block_lane: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`pack_2d` (convert back, inverse scale)."""
    return pack_2d(
        buf,
        out_dtype=out_dtype,
        scale=1.0 / scale if scale != 1.0 else 1.0,
        block_lead=block_lead,
        block_lane=block_lane,
        interpret=interpret,
    )
