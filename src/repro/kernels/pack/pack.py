"""Halo pack/unpack Pallas TPU kernel.

The paper packs boundary slabs into contiguous buffers with OpenMP threads
before communication.  The TPU analogue is a VMEM-tiled strided-to-contiguous
copy, with two fusions the CPU version cannot do for free:

* dtype conversion on the fly (e.g. f32 mesh -> bf16 wire format, halving
  halo bytes on the wire — a gradient-compression-style optimization), and
* optional scaling (for compressed-wire formats).

The kernel operates on a 2-D view (lead, lane) of the slab; ``ops.py`` builds
that view, splits partitions, and re-inserts unpacked ghosts.  Grid tiles are
(block_lead, block_lane) VMEM blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params


def _copy_convert_kernel(x_ref, o_ref, *, scale: float):
    x = x_ref[...]
    if scale != 1.0:
        x = x.astype(jnp.float32) * scale
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "scale", "block_lead", "block_lane", "interpret"),
)
def pack_2d(
    slab: jax.Array,  # (lead, lane) view of a boundary slab
    *,
    out_dtype=None,
    scale: float = 1.0,
    block_lead: int = 256,
    block_lane: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Tiled contiguous copy (+convert/scale) of a 2-D slab view."""
    lead, lane = slab.shape
    out_dtype = out_dtype or slab.dtype
    bl = min(block_lead, lead)
    bn = min(block_lane, lane)
    # pad to tile multiples (the paper's equal-partition padding, §II-B)
    pl_lead = -lead % bl
    pl_lane = -lane % bn
    padded = slab
    if pl_lead or pl_lane:
        padded = jnp.pad(slab, ((0, pl_lead), (0, pl_lane)))
    grid = (padded.shape[0] // bl, padded.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_copy_convert_kernel, scale=scale),
        grid=grid,
        in_specs=[pl.BlockSpec((bl, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bl, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, out_dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(padded)
    if pl_lead or pl_lane:
        out = out[:lead, :lane]
    return out


def _gather_pack_kernel(x_ref, o_ref, *, segments, scale: float):
    # static unroll over the layout's offset table: ONE launch fills the
    # whole coalesced buffer (the fused analogue of Comb's combined pack)
    for offset, start, shape in segments:
        window = tuple(pl.dslice(b, n) for b, n in zip(start, shape))
        vals = x_ref[window]
        if scale != 1.0:
            vals = vals.astype(jnp.float32) * scale
        n = 1
        for d in shape:
            n *= d
        o_ref[pl.dslice(offset, n)] = vals.reshape(-1).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("segments", "total", "out_dtype", "scale", "interpret"),
)
def gather_pack_1d(
    x: jax.Array,
    *,
    segments: tuple[tuple[int, tuple[int, ...], tuple[int, ...]], ...],
    total: int,
    out_dtype=None,
    scale: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    """Fused gather-pack: copy every ``(offset, start, shape)`` window of
    ``x`` into a contiguous 1-D wire buffer in one kernel launch (with the
    same on-the-fly convert/scale fusions as :func:`pack_2d`).

    Untiled: the whole block is one VMEM operand so arbitrary windows can
    be gathered in a single launch — callers must bound the block size
    (``ops.GATHER_VMEM_BUDGET_BYTES``); halo blocks beyond it go through
    the jnp gather, which XLA tiles."""
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_gather_pack_kernel, segments=segments, scale=scale),
        out_shape=jax.ShapeDtypeStruct((total,), out_dtype),
        interpret=interpret,
    )(x)


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "scale", "block_lead", "block_lane", "interpret"),
)
def unpack_2d(
    buf: jax.Array,
    *,
    out_dtype=None,
    scale: float = 1.0,
    block_lead: int = 256,
    block_lane: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`pack_2d` (convert back, inverse scale)."""
    return pack_2d(
        buf,
        out_dtype=out_dtype,
        scale=1.0 / scale if scale != 1.0 else 1.0,
        block_lead=block_lead,
        block_lane=block_lane,
        interpret=interpret,
    )
