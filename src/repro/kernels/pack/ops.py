"""Public jit'd wrappers composing slice -> pack kernel -> (exchange) -> unpack.

``pack_slab`` / ``unpack_slab`` are what the transport layer's ``pallas``
packer uses (:class:`repro.core.transport.PallasPacker`): they carry any N-D
slab the halo schedules emit — full-extent sequential faces, the fused
schedule's ``3^D - 1`` face/edge/corner blocks, and clipped partitions —
through the 2-D (lead, lane) kernel view.  ``pack_face`` / ``unpack_face``
are the face-level forms (slice by axis/side baked in).  On non-TPU backends
every wrapper falls back to the jnp oracle so CPU tests and smoke runs
exercise identical semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pack.pack import pack_2d, unpack_2d
from repro.kernels.pack import ref as _ref


def _to_2d(slab: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    shape = slab.shape
    if slab.ndim == 1:
        return slab.reshape(1, -1), shape
    return slab.reshape(-1, shape[-1]), shape


def pack_slab(
    slab: jax.Array,
    *,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Pack an N-D slab (face, edge, corner, or partition block) into a
    contiguous 2-D wire buffer via the tiled copy kernel."""
    flat, _ = _to_2d(slab)
    if force_kernel or jax.default_backend() == "tpu":
        return pack_2d(flat, out_dtype=out_dtype, scale=scale,
                       interpret=interpret)
    return _ref.pack_2d_ref(flat, out_dtype=out_dtype, scale=scale)


def unpack_slab(
    buf: jax.Array,
    shape: tuple[int, ...],
    *,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`pack_slab`: wire buffer back to the slab ``shape``."""
    if force_kernel or jax.default_backend() == "tpu":
        vals = unpack_2d(buf, out_dtype=out_dtype, scale=scale,
                         interpret=interpret)
    else:
        vals = _ref.unpack_2d_ref(buf, out_dtype=out_dtype, scale=scale)
    return vals.reshape(shape)


def pack_face(
    x: jax.Array,
    array_axis: int,
    side: str,  # 'low' | 'high'
    halo: int,
    *,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Pack one interior boundary face into a contiguous (possibly
    wire-compressed) 2-D buffer."""
    size = x.shape[array_axis]
    if side == "low":
        slab = jax.lax.slice_in_dim(x, halo, 2 * halo, axis=array_axis)
    elif side == "high":
        slab = jax.lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=array_axis)
    else:
        raise ValueError(side)
    flat, _ = _to_2d(slab)
    if force_kernel or jax.default_backend() == "tpu":
        return pack_2d(flat, out_dtype=out_dtype, scale=scale, interpret=interpret)
    return _ref.pack_2d_ref(flat, out_dtype=out_dtype, scale=scale)


def unpack_face(
    x: jax.Array,
    buf: jax.Array,
    array_axis: int,
    side: str,  # ghost side to fill: 'low' | 'high'
    halo: int,
    *,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Unpack a received contiguous buffer into the ghost rim of ``x``."""
    size = x.shape[array_axis]
    ghost_shape = list(x.shape)
    ghost_shape[array_axis] = halo
    if force_kernel or jax.default_backend() == "tpu":
        vals = unpack_2d(buf, out_dtype=x.dtype, scale=scale, interpret=interpret)
    else:
        vals = _ref.unpack_2d_ref(buf, out_dtype=x.dtype, scale=scale)
    ghost = vals.reshape(ghost_shape)
    starts = [0] * x.ndim
    starts[array_axis] = 0 if side == "low" else size - halo
    return jax.lax.dynamic_update_slice(x, ghost, tuple(starts))
