"""Public jit'd wrappers composing slice -> pack kernel -> (exchange) -> unpack.

``pack_slab`` / ``unpack_slab`` are what the transport layer's ``pallas``
packer uses (:class:`repro.core.transport.PallasPacker`): they carry any N-D
slab the halo schedules emit — full-extent sequential faces, the fused
schedule's ``3^D - 1`` face/edge/corner blocks, and clipped partitions —
through the 2-D (lead, lane) kernel view.  ``pack_face`` / ``unpack_face``
are the face-level forms (slice by axis/side baked in).  On non-TPU backends
every wrapper falls back to the jnp oracle so CPU tests and smoke runs
exercise identical semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pack.pack import gather_pack_1d, pack_2d, unpack_2d
from repro.kernels.pack import ref as _ref


def _to_2d(slab: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    shape = slab.shape
    if slab.ndim == 1:
        return slab.reshape(1, -1), shape
    return slab.reshape(-1, shape[-1]), shape


def pack_slab(
    slab: jax.Array,
    *,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Pack an N-D slab (face, edge, corner, or partition block) into a
    contiguous 2-D wire buffer via the tiled copy kernel."""
    flat, _ = _to_2d(slab)
    if force_kernel or jax.default_backend() == "tpu":
        return pack_2d(flat, out_dtype=out_dtype, scale=scale,
                       interpret=interpret)
    return _ref.pack_2d_ref(flat, out_dtype=out_dtype, scale=scale)


def unpack_slab(
    buf: jax.Array,
    shape: tuple[int, ...],
    *,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Inverse of :func:`pack_slab`: wire buffer back to the slab ``shape``."""
    if force_kernel or jax.default_backend() == "tpu":
        vals = unpack_2d(buf, out_dtype=out_dtype, scale=scale,
                         interpret=interpret)
    else:
        vals = _ref.unpack_2d_ref(buf, out_dtype=out_dtype, scale=scale)
    return vals.reshape(shape)


#: the gather kernel is untiled (the whole local block rides in VMEM, so
#: every window is gatherable in one launch); blocks beyond this budget
#: fall back to the jnp gather, which XLA tiles itself.  ~16 MB VMEM per
#: core, minus headroom for the output buffer and double-buffering.
GATHER_VMEM_BUDGET_BYTES = 4 * 1024 * 1024


def gather_pack(
    x: jax.Array,
    segments,
    *,
    total: int,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Fill one coalesced wire buffer in a single fused launch.

    ``segments`` is a static offset table — ``WireSegment``-like values (or
    ``(offset, src_start, shape)`` tuples) tiling ``[0, total)`` in order —
    of every slab bound for one neighbor
    (:meth:`repro.core.transport.Packer.pack_coalesced`).  One kernel launch
    gathers all windows instead of one tiled copy per slab; off-TPU (and
    for blocks too large for the untiled kernel's VMEM residency,
    :data:`GATHER_VMEM_BUDGET_BYTES`) the jnp oracle keeps identical
    semantics.
    """
    segs = tuple(
        (int(s[0]), tuple(int(v) for v in s[1]), tuple(int(v) for v in s[2]))
        if isinstance(s, tuple)
        else (int(s.offset), tuple(int(v) for v in s.src_start),
              tuple(int(v) for v in s.shape))
        for s in segments
    )
    fits_vmem = x.size * x.dtype.itemsize <= GATHER_VMEM_BUDGET_BYTES
    if force_kernel or (jax.default_backend() == "tpu" and fits_vmem):
        return gather_pack_1d(x, segments=segs, total=total,
                              out_dtype=out_dtype, scale=scale,
                              interpret=interpret)
    return _ref.gather_pack_ref(x, segs, total=total, out_dtype=out_dtype,
                                scale=scale)


def pack_face(
    x: jax.Array,
    array_axis: int,
    side: str,  # 'low' | 'high'
    halo: int,
    *,
    out_dtype=None,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Pack one interior boundary face into a contiguous (possibly
    wire-compressed) 2-D buffer."""
    size = x.shape[array_axis]
    if side == "low":
        slab = jax.lax.slice_in_dim(x, halo, 2 * halo, axis=array_axis)
    elif side == "high":
        slab = jax.lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=array_axis)
    else:
        raise ValueError(side)
    flat, _ = _to_2d(slab)
    if force_kernel or jax.default_backend() == "tpu":
        return pack_2d(flat, out_dtype=out_dtype, scale=scale, interpret=interpret)
    return _ref.pack_2d_ref(flat, out_dtype=out_dtype, scale=scale)


def unpack_face(
    x: jax.Array,
    buf: jax.Array,
    array_axis: int,
    side: str,  # ghost side to fill: 'low' | 'high'
    halo: int,
    *,
    scale: float = 1.0,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Unpack a received contiguous buffer into the ghost rim of ``x``."""
    size = x.shape[array_axis]
    ghost_shape = list(x.shape)
    ghost_shape[array_axis] = halo
    if force_kernel or jax.default_backend() == "tpu":
        vals = unpack_2d(buf, out_dtype=x.dtype, scale=scale, interpret=interpret)
    else:
        vals = _ref.unpack_2d_ref(buf, out_dtype=x.dtype, scale=scale)
    ghost = vals.reshape(ghost_shape)
    starts = [0] * x.ndim
    starts[array_axis] = 0 if side == "low" else size - halo
    return jax.lax.dynamic_update_slice(x, ghost, tuple(starts))
