"""Pure-jnp oracle for the pack/unpack kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_2d_ref(slab: jax.Array, *, out_dtype=None, scale: float = 1.0) -> jax.Array:
    out_dtype = out_dtype or slab.dtype
    x = slab
    if scale != 1.0:
        x = x.astype(jnp.float32) * scale
    return x.astype(out_dtype)


def unpack_2d_ref(buf: jax.Array, *, out_dtype=None, scale: float = 1.0) -> jax.Array:
    return pack_2d_ref(buf, out_dtype=out_dtype, scale=(1.0 / scale if scale != 1.0 else 1.0))


def pack_slab_ref(
    slab: jax.Array, *, out_dtype=None, scale: float = 1.0
) -> jax.Array:
    """N-D slab -> contiguous 2-D wire buffer (jnp oracle of ``pack_slab``)."""
    flat = slab.reshape(-1, slab.shape[-1]) if slab.ndim > 1 else slab.reshape(1, -1)
    return pack_2d_ref(flat, out_dtype=out_dtype, scale=scale)


def unpack_slab_ref(
    buf: jax.Array, shape, *, out_dtype=None, scale: float = 1.0
) -> jax.Array:
    """Wire buffer -> slab of ``shape`` (jnp oracle of ``unpack_slab``)."""
    return unpack_2d_ref(buf, out_dtype=out_dtype, scale=scale).reshape(shape)


def gather_pack_ref(
    x: jax.Array,
    segments,
    *,
    total: int,
    out_dtype=None,
    scale: float = 1.0,
) -> jax.Array:
    """jnp oracle of the fused gather-pack: every ``(offset, start, shape)``
    window of ``x`` laid end-to-end in one 1-D wire buffer."""
    out_dtype = out_dtype or x.dtype
    bufs = []
    covered = 0
    for offset, start, shape in segments:
        assert offset == covered, "segments must tile the buffer in order"
        limits = [s + n for s, n in zip(start, shape)]
        slab = jax.lax.slice(x, list(start), limits).reshape(-1)
        if scale != 1.0:
            slab = slab.astype(jnp.float32) * scale
        bufs.append(slab.astype(out_dtype))
        covered += bufs[-1].size
    assert covered == total, (covered, total)
    return bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs)


def pack_face_ref(
    x: jax.Array, array_axis: int, side: str, halo: int,
    *, out_dtype=None, scale: float = 1.0,
) -> jax.Array:
    """Slice the interior boundary slab and pack it contiguously (jnp)."""
    size = x.shape[array_axis]
    if side == "low":
        slab = jax.lax.slice_in_dim(x, halo, 2 * halo, axis=array_axis)
    elif side == "high":
        slab = jax.lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=array_axis)
    else:
        raise ValueError(side)
    flat = slab.reshape(-1, slab.shape[-1]) if slab.ndim > 1 else slab.reshape(1, -1)
    return pack_2d_ref(flat, out_dtype=out_dtype, scale=scale)
