from repro.kernels.pack.pack import gather_pack_1d, pack_2d, unpack_2d
from repro.kernels.pack.ops import (
    gather_pack, pack_face, unpack_face, pack_slab, unpack_slab,
)
from repro.kernels.pack.ref import (
    gather_pack_ref,
    pack_2d_ref, unpack_2d_ref, pack_face_ref, pack_slab_ref, unpack_slab_ref,
)

__all__ = [
    "pack_2d", "unpack_2d", "pack_face", "unpack_face",
    "pack_slab", "unpack_slab", "gather_pack", "gather_pack_1d",
    "pack_2d_ref", "unpack_2d_ref", "pack_face_ref",
    "pack_slab_ref", "unpack_slab_ref", "gather_pack_ref",
]
