from repro.kernels.pack.pack import pack_2d, unpack_2d
from repro.kernels.pack.ops import pack_face, unpack_face
from repro.kernels.pack.ref import pack_2d_ref, unpack_2d_ref, pack_face_ref

__all__ = [
    "pack_2d", "unpack_2d", "pack_face", "unpack_face",
    "pack_2d_ref", "unpack_2d_ref", "pack_face_ref",
]
