from repro.kernels.pack.pack import pack_2d, unpack_2d
from repro.kernels.pack.ops import pack_face, unpack_face, pack_slab, unpack_slab
from repro.kernels.pack.ref import (
    pack_2d_ref, unpack_2d_ref, pack_face_ref, pack_slab_ref, unpack_slab_ref,
)

__all__ = [
    "pack_2d", "unpack_2d", "pack_face", "unpack_face",
    "pack_slab", "unpack_slab",
    "pack_2d_ref", "unpack_2d_ref", "pack_face_ref",
    "pack_slab_ref", "unpack_slab_ref",
]
