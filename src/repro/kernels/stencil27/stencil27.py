"""27-point stencil update Pallas TPU kernel.

The local compute phase of the paper's workload: every interior cell is
replaced by a weighted sum of its 3x3x3 neighborhood.  The kernel tiles the
*output* interior over a 3-D grid; the ghosted input block stays resident in
VMEM (one subdomain per TPU core after sharding — Comb-scale subdomains of
~64-128^3 f32 fit comfortably) and each tile accumulates its 27 shifted
reads with ``dynamic_slice`` from the VMEM ref.

A production variant for subdomains larger than VMEM would stream Z-slabs
HBM->VMEM with double-buffered async copies; the tiling/accumulation structure
below is unchanged by that.  Weights are a (3,3,3) VMEM-resident constant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params


def _stencil_kernel(x_ref, w_ref, o_ref, *, tz: int, ty: int, tx: int, halo: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)
    acc = jnp.zeros((tz, ty, tx), jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # 27 shifted reads of the ghosted block; offsets are compile-time constants
    # relative to the tile origin, so each becomes a strided VMEM load.
    for dz in range(2 * halo + 1):
        for dy in range(2 * halo + 1):
            for dx in range(2 * halo + 1):
                sub = jax.lax.dynamic_slice(
                    x_ref[...],
                    (i * tz + dz, j * ty + dy, k * tx + dx),
                    (tz, ty, tx),
                )
                acc = acc + w[dz, dy, dx] * sub.astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("tile", "interpret")
)
def stencil27(
    x: jax.Array,  # (Z+2h, Y+2h, X+2h) ghosted block
    w: jax.Array,  # (3, 3, 3) weights
    *,
    tile: tuple[int, int, int] = (8, 8, 128),
    interpret: bool = False,
) -> jax.Array:
    """Apply the 27-point stencil to the interior; returns (Z, Y, X)."""
    halo = 1
    assert w.shape == (3, 3, 3), w.shape
    zi, yi, xi = (s - 2 * halo for s in x.shape)
    tz = min(tile[0], zi)
    ty = min(tile[1], yi)
    tx = min(tile[2], xi)
    assert zi % tz == 0 and yi % ty == 0 and xi % tx == 0, (x.shape, tile)
    grid = (zi // tz, yi // ty, xi // tx)
    kernel = functools.partial(_stencil_kernel, tz=tz, ty=ty, tx=tx, halo=halo)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # whole ghosted block resident in VMEM (see module docstring)
            pl.BlockSpec(x.shape, lambda i, j, k: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i, j, k: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tz, ty, tx), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((zi, yi, xi), x.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
