from repro.kernels.stencil27.stencil27 import stencil27
from repro.kernels.stencil27.ops import stencil_update
from repro.kernels.stencil27.ref import stencil27_ref, jacobi_weights

__all__ = ["stencil27", "stencil_update", "stencil27_ref", "jacobi_weights"]
