"""Pure-jnp oracle for the 27-point stencil kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil27_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: ghosted (Z+2, Y+2, X+2); w: (3,3,3).  Returns interior (Z, Y, X)."""
    halo = 1
    zi, yi, xi = (s - 2 * halo for s in x.shape)
    acc = jnp.zeros((zi, yi, xi), jnp.float32)
    for dz in range(3):
        for dy in range(3):
            for dx in range(3):
                acc = acc + w[dz, dy, dx].astype(jnp.float32) * jax.lax.dynamic_slice(
                    x, (dz, dy, dx), (zi, yi, xi)
                ).astype(jnp.float32)
    return acc.astype(x.dtype)


def jacobi_weights(dtype=jnp.float32) -> jax.Array:
    """27-point Jacobi smoothing weights (normalized box kernel)."""
    w = jnp.ones((3, 3, 3), dtype)
    return w / jnp.sum(w)
