"""Public jit'd wrapper for the 27-point stencil update."""

from __future__ import annotations

import jax

from repro.kernels.stencil27.stencil27 import stencil27
from repro.kernels.stencil27.ref import stencil27_ref, jacobi_weights


def stencil_update(
    x: jax.Array,
    w: jax.Array,
    *,
    tile: tuple[int, int, int] = (8, 8, 128),
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """27-point stencil on a ghosted block; Pallas on TPU, jnp oracle on CPU."""
    if force_kernel or jax.default_backend() == "tpu":
        return stencil27(x, w, tile=tile, interpret=interpret)
    return stencil27_ref(x, w)
