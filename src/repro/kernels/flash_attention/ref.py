"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf.astype(jnp.float32))
    s = s * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf.astype(jnp.float32))
    return out.astype(q.dtype)
