"""Blocked (flash) attention Pallas TPU kernel.

Online-softmax attention tiled for VMEM: the grid iterates
(batch, q_head, q_block, kv_block) with the kv dimension innermost
("arbitrary" semantics); running max / denominator / accumulator live in VMEM
scratch and persist across kv steps.  GQA is handled with zero copies by
indexing the KV head as ``q_head // group`` in the BlockSpec index maps.

Block shapes are MXU-aligned by default (q/kv blocks of 128, head_dim lanes);
the m/l scratch carries the per-row statistics broadcast across a 128-lane
tile, the standard TPU layout trick.  Validated in ``interpret=True`` mode
against ``ref.py`` (see tests/kernels/test_flash.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bkv, d)
    v_ref,  # (1, 1, bkv, d)
    o_ref,  # (1, 1, bq, d)
    m_scr,  # (bq, 128) f32
    l_scr,  # (bq, 128) f32
    acc_scr,  # (bq, d) f32
    *,
    scale: float,
    causal: bool,
    bq: int,
    bkv: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bkv)

        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            mask = q_pos >= k_pos
            s = jnp.where(mask, s, _NEG_INF)
        else:
            mask = None

        m_prev = m_scr[:, :1]  # (bq, 1)
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        if mask is not None:
            p = jnp.where(mask, p, 0.0)  # guard fully-masked blocks
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    if causal:
        # skip kv blocks strictly in the future of this q block
        @pl.when(ki * bkv <= qi * bq + bq - 1)
        def _run():
            _body()
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, bq, skv, bkv)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    grid = (b, hq, sq // bq, skv // bkv)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bkv=bkv
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, bkv, d), lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
