"""Public jit'd wrappers for the flash attention kernel.

``attention(q, k, v)`` takes the model-layout tensors (B, S, H, D) and
dispatches to the Pallas kernel (TPU) or the jnp oracle (CPU and odd shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _use_kernel(sq: int, skv: int, d: int, block_q: int, block_kv: int) -> bool:
    if jax.default_backend() != "tpu":
        return False
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    return sq % bq == 0 and skv % bkv == 0 and d % 128 == 0


def attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Multi-head attention with model-layout (B, S, H, D) tensors."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if force_kernel or _use_kernel(q.shape[1], k.shape[1], q.shape[-1], block_q, block_kv):
        out = flash_attention(
            qt, kt, vt, causal=causal, scale=scale,
            block_q=block_q, block_kv=block_kv, interpret=interpret,
        )
    else:
        out = attention_ref(qt, kt, vt, causal=causal, scale=scale)
    return jnp.swapaxes(out, 1, 2)
