from repro.kernels.wkv.wkv import wkv_chunked
from repro.kernels.wkv.ops import wkv
from repro.kernels.wkv.ref import wkv_chunked_ref

__all__ = ["wkv_chunked", "wkv", "wkv_chunked_ref"]
