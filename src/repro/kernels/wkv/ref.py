"""Pure-jnp oracle for the WKV chunk-scan kernel (delegates to the validated
chunked implementation in repro.models.rwkv)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rwkv import wkv_scan


def wkv_chunked_ref(r, k, v, lw, u, *, chunk: int = 16) -> jax.Array:
    """r,k,v,lw: (BH, T, hd); u: (BH, 1, hd).  Returns (BH, T, hd).

    Internal math in f32 (matching the kernel), output in the input dtype."""
    out_dtype = r.dtype
    r, k, v, lw, u = (x.astype(jnp.float32) for x in (r, k, v, lw, u))
    bh, T, hd = r.shape
    # models.rwkv.wkv_scan wants (B, T, H, hd) + u (H, hd); use B=bh, H=1
    def to4(x):
        return x.reshape(bh, T, 1, hd)

    ys = []
    for i in range(bh):  # per-row u (oracle clarity over speed)
        y, _ = wkv_scan(to4(r)[i:i + 1], to4(k)[i:i + 1], to4(v)[i:i + 1],
                        to4(lw)[i:i + 1], u[i, 0][None, :], chunk=chunk)
        ys.append(y[0, :, 0])
    return jnp.stack(ys).astype(out_dtype)
