"""Public wrapper: model-layout WKV with Pallas fast path on TPU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wkv.wkv import wkv_chunked
from repro.kernels.wkv.ref import wkv_chunked_ref


def wkv(
    r: jax.Array,  # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    lw: jax.Array,
    u: jax.Array,  # (H, hd)
    *,
    chunk: int = 16,
    force_kernel: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Model-layout WKV; (B, T, H, hd) -> (B, T, H, hd)."""
    B, T, H, hd = r.shape
    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, hd)

    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    if force_kernel or jax.default_backend() == "tpu":
        y = wkv_chunked(flat(r), flat(k), flat(v), flat(lw), uf, chunk=chunk,
                        interpret=interpret)
    else:
        y = wkv_chunked_ref(flat(r), flat(k), flat(v), flat(lw), uf,
                            chunk=chunk)
    return y.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
