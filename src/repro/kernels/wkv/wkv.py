"""RWKV-6 WKV chunk-scan Pallas TPU kernel.

The rwkv6 train cell is memory-bound on the chunked WKV's pairwise decay
tensor (c, c, hd), which the pure-jnp path materializes to HBM per chunk
(EXPERIMENTS.md §Roofline: 23.6 s memory term).  This kernel keeps the
pairwise tensor, the chunk state, and all intermediates resident in VMEM:

  grid = (B*H, T/c), sequence dimension innermost ("arbitrary" semantics);
  the (hd, hd) recurrent state lives in VMEM scratch and persists across the
  chunk sweep of each (batch, head) row, exactly like the flash kernel's
  running softmax statistics.

Math identical to ``repro.models.rwkv._wkv_chunk`` (the ref oracle):
  S_t = diag(w_t) S_{t-1} + k_t v_t^T;   y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
with the numerically safe pairwise exponent cum[t-1] - cum[s] <= 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *, c: int,
                hd: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)  # (c, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # log decay, < 0
    u = u_ref[0].astype(jnp.float32)  # (1, hd) bonus
    S = s_scr[...]  # (hd, hd)

    cum = jnp.cumsum(lw, axis=0)  # (c, hd)
    cum_prev = cum - lw

    # state term: y_t += (r_t * exp(cum_{t-1})) . S
    r_dec = r * jnp.exp(cum_prev)
    y = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk pairwise term (exponent <= 0, masked strictly-lower)
    pair = cum_prev[:, None, :] - cum[None, :, :]  # (t, s, hd)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    D = jnp.where(mask[..., None], jnp.exp(jnp.minimum(pair, 0.0)), 0.0)
    A = jnp.einsum("ti,si,tsi->ts", r, k, D)  # (c, c)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # bonus (diagonal) term
    y = y + jnp.sum(r * u * k, axis=1, keepdims=True) * v

    o_ref[0, ...] = y.astype(o_ref.dtype)

    # chunk state update: S' = diag(exp(cum_T)) S + sum_s exp(cum_T-cum_s) k_s v_s^T
    total = cum[-1]  # (hd,)
    k_dec = k * jnp.exp(total[None, :] - cum)
    s_scr[...] = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked(
    r: jax.Array,  # (BH, T, hd)
    k: jax.Array,
    v: jax.Array,
    lw: jax.Array,  # (BH, T, hd) log decays (< 0)
    u: jax.Array,  # (BH, 1, hd) bonus (broadcast per head-row)
    *,
    chunk: int = 16,
    interpret: bool = False,
) -> jax.Array:
    bh, T, hd = r.shape
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    grid = (bh, T // c)
    kernel = functools.partial(_wkv_kernel, c=c, hd=hd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, c, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, c, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, c, hd), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, t: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, hd), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, T, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, lw, u)
