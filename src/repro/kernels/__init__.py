# Pallas TPU kernels for the compute hot-spots:
#   pack            — halo pack/unpack (strided->contiguous + wire convert)
#   stencil27       — 27-point stencil interior update
#   flash_attention — blocked online-softmax attention (LM prefill / ring step)
#   wkv             — RWKV-6 chunk scan with VMEM-resident recurrent state
# Each package ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with CPU fallback), and ref.py (pure-jnp oracle used by tests).
