"""``hypothesis`` with a deterministic fallback when it is not installed.

The property tests use a small slice of the hypothesis API::

    from repro.testing import given, settings, st

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(0, 8), mode=st.sampled_from(["a", "b"]))
    def test_prop(n, mode): ...

With hypothesis installed (``requirements-dev.txt``, CI) these re-export the
real thing — full shrinking, example database, the works.  On the pinned
runtime environment (no ``hypothesis``) the fallback below runs each property
over ``max_examples`` *deterministically seeded* pseudo-random draws instead
of failing collection.  No shrinking, no database — but the properties still
execute and still catch regressions, and the seed is derived from the test
name so failures reproduce.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Sequence

try:  # pragma: no cover - exercised implicitly by which env runs the suite
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A value source: ``draw(rng)`` yields one example."""

        def __init__(self, draw: Callable[[random.Random], Any], desc: str):
            self._draw = draw
            self.desc = desc

        def draw(self, rng: random.Random) -> Any:
            return self._draw(rng)

        def __repr__(self) -> str:
            return f"st.{self.desc}"

    class _Strategies:
        """The subset of ``hypothesis.strategies`` the test-suite uses."""

        @staticmethod
        def sampled_from(elements: Sequence[Any]) -> _Strategy:
            elements = list(elements)
            assert elements, "sampled_from of empty sequence"
            return _Strategy(
                lambda rng: elements[rng.randrange(len(elements))],
                f"sampled_from({elements!r})",
            )

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: rng.randint(min_value, max_value),
                f"integers({min_value}, {max_value})",
            )

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")

        @staticmethod
        def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value),
                f"floats({min_value}, {max_value})",
            )

    st = _Strategies()

    _DEFAULT_MAX_EXAMPLES = 10

    def given(**strategies: _Strategy) -> Callable:
        """Deterministic stand-in: run the test over seeded random draws."""

        def deco(fn: Callable) -> Callable:
            @functools.wraps(fn)
            def wrapper() -> None:
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode()
                )
                rng = random.Random(seed)
                for i in range(n):
                    kwargs = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"property falsified on example {i + 1}/{n} "
                            f"(fallback rng, seed={seed}): {kwargs!r}"
                        ) from e

            # hide the property kwargs from pytest's fixture resolution
            # (real hypothesis does the same on its wrapper).
            wrapper.__signature__ = inspect.Signature()  # type: ignore[attr-defined]
            del wrapper.__wrapped__  # keep pytest off the inner signature
            wrapper._max_examples = _DEFAULT_MAX_EXAMPLES  # type: ignore
            return wrapper

        return deco

    def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw) -> Callable:
        """Only ``max_examples`` is honored; pacing knobs are meaningless
        without the real engine and are accepted-and-ignored."""

        def deco(fn: Callable) -> Callable:
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn

        return deco
