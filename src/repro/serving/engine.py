"""Batched serving engine with continuous batching and persistent step plans.

Slots hold independent requests; prefill fills a slot's cache region, decode
advances every active slot one token per step.  Both step functions execute
through the framework's persistent-plan cache (compile once, bare dispatch
per iteration — the paper's persistent lifecycle).  When a slot finishes
(EOS / max_tokens), the next queued request takes it over without stalling
the running batch (continuous batching).

The decode batch is fixed-size: empty slots decode padding tokens whose
outputs are ignored — the standard shape-stable TPU serving pattern.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import PlanCache
from repro.models.api import Model
from repro.parallel.context import LOCAL, ParallelContext


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    plan_inits: int = 0
    plan_hits: int = 0


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, max_slots: int = 4,
                 max_len: int = 256, ctx: ParallelContext = LOCAL,
                 greedy: bool = True):
        assert model.has_decode, f"{model.cfg.name} is encoder-only"
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.ctx = ctx
        self.plans = PlanCache()
        self.stats = EngineStats()
        self._queue: deque[Request] = deque()
        self._slots: list[Request | None] = [None] * max_slots
        # one shared batched cache; per-slot position bookkeeping
        self._cache = model.init_cache(max_slots, max_len)
        self._positions = np.zeros(max_slots, np.int64)
        self._uid = 0
        # per-leaf batch (slot) axis of the cache tree: the axis whose extent
        # tracks the cache batch size.  Derived abstractly (no allocation) so
        # _write_slot never has to guess from a size-1 axis — which fails for
        # max_slots == 1, where every axis matches and prefill wrote nothing.
        s1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
        s2 = jax.eval_shape(lambda: model.init_cache(2, max_len))
        self._slot_axes = jax.tree.map(
            lambda a, b: next(
                (ax for ax, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y), None),
            s1, s2)
        # the wire knobs are invisible to abstract shapes, so stamp them into
        # every plan key: packer/coalesce/n_parts/moe_comm changes must MISS
        self._comm_key = ("comm", ctx.comm_packer, ctx.comm_coalesce,
                          ctx.n_parts, ctx.moe_comm)

        # the step closures are created ONCE: the plan key includes the
        # function identity, so a fresh closure per call would defeat the
        # cache and re-init a plan for every request
        def decode_fn(params, token, cache):
            return model.decode_step(params, token, cache, ctx=ctx)

        def prefill_fn(params, batch, cache):
            return model.prefill(params, batch, cache, ctx=ctx)

        def prefill_bucketed_fn(params, batch, cache, true_len):
            return model.prefill(params, batch, cache, ctx=ctx,
                                 true_len=true_len)

        self._decode_fn = decode_fn
        self._prefill_fn = prefill_fn
        self._prefill_bucketed_fn = prefill_bucketed_fn

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: list[int] | np.ndarray, max_new_tokens: int = 16,
               eos_id: int = -1) -> int:
        req = Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens,
                      eos_id)
        self._uid += 1
        self._queue.append(req)
        return req.uid

    def run(self) -> dict[int, list[int]]:
        """Serve until queue and slots drain; returns uid -> generated tokens."""
        finished: dict[int, list[int]] = {}
        while self._queue or any(s is not None for s in self._slots):
            self._fill_slots(finished)
            self._decode_once(finished)
        return finished

    # -- internals ------------------------------------------------------------
    def _fill_slots(self, finished: dict[int, list[int]]) -> None:
        for i, slot in enumerate(self._slots):
            if slot is not None:
                continue
            # a request can finish AT prefill (max_new_tokens <= 1, or the
            # first sampled token is EOS) — it never occupies a decode slot,
            # and the freed slot immediately takes the next queued request.
            while self._queue:
                req = self._queue.popleft()
                self._prefill_slot(i, req)
                if (req.max_new_tokens <= 1
                        or req.tokens_out[-1] == req.eos_id):
                    req.done = True
                    finished[req.uid] = req.tokens_out[: req.max_new_tokens]
                    continue
                self._slots[i] = req
                break

    def _prefill_bucket(self, plen: int) -> int | None:
        """Padded prompt length, or None for exact-length prefill.

        Only the dense transformer prefills bucketed: capacity-based MoE
        routing and the VLM cross-attention scan are sequence-length-
        sensitive, so padding would change real-token outputs there.
        """
        if self.model.cfg.family != "dense":
            return None
        return min(_next_pow2(plen), self.max_len)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-slot prefill into the shared batched cache.

        Uses a per-slot cache of batch 1, then writes the KV rows into the
        batched cache at ``slot``.  Dense prompts right-pad to power-of-two
        buckets with the true length passed as a TRACED plan argument, so
        every prompt length in a bucket shares one persistent plan
        (plan_inits stays flat across lengths); other families prefill at
        the exact length (one plan per distinct length).
        """
        prompt = np.asarray(req.prompt, np.int32)[None]
        plen = prompt.shape[1]
        bucket = self._prefill_bucket(plen)
        cache1 = self.model.init_cache(1, self.max_len)

        batch = {"tokens": jnp.asarray(prompt)}
        if self.model.cfg.family == "vlm":
            batch["vision_emb"] = jnp.zeros(
                (1, self.model.cfg.vision_tokens, self.model.cfg.d_vision),
                jnp.bfloat16)
        if bucket is None:
            prefill_fn = self._prefill_fn
            args = (self.params, batch, cache1)
        else:
            batch["tokens"] = jnp.asarray(np.pad(
                prompt, ((0, 0), (0, bucket - plen))))
            true_len = jnp.full((1,), plen, jnp.int32)
            prefill_fn = self._prefill_bucketed_fn
            args = (self.params, batch, cache1, true_len)
        plan = self.plans.get_or_init(prefill_fn, args,
                                      extra_key=self._comm_key)
        logits, cache1 = plan.start(*args)
        self.stats.prefills += 1
        self._cache = _write_slot(self._cache, cache1, slot, self._slot_axes)
        self._positions[slot] = plen
        last = int(np.argmax(np.asarray(logits)[0, -1]))
        req.tokens_out.append(last)

    def _decode_once(self, finished: dict[int, list[int]]) -> None:
        if not any(s is not None for s in self._slots):
            return
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i, req in enumerate(self._slots):
            if req is not None:
                tokens[i, 0] = req.tokens_out[-1]
        # shared cache decode: cache["pos"] is (B,) per-slot, written at
        # prefill time (continuous batching needs no uniform position).
        plan = self.plans.get_or_init(
            self._decode_fn, (self.params, jnp.asarray(tokens), self._cache),
            extra_key=self._comm_key)
        logits, self._cache = plan.start(self.params, jnp.asarray(tokens),
                                         self._cache)
        self.stats.decode_steps += 1
        logits = np.asarray(logits)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            nxt = int(np.argmax(logits[i, 0]))
            req.tokens_out.append(nxt)
            self.stats.tokens_generated += 1
            self._positions[i] += 1
            # >=, counting the prefill token: max_new_tokens=N runs exactly
            # N-1 decode steps for N sampled tokens — nothing truncated away
            if (len(req.tokens_out) >= req.max_new_tokens
                    or nxt == req.eos_id
                    or self._positions[i] >= self.max_len - 1):
                req.done = True
                finished[req.uid] = req.tokens_out
                self._slots[i] = None
        self.stats.plan_inits = self.plans.stats.inits
        self.stats.plan_hits = self.plans.stats.cache_hits


def _next_pow2(n: int) -> int:
    p = 8
    while p < n:
        p *= 2
    return p


def _write_slot(batched_cache: dict, cache1: dict, slot: int,
                slot_axes: dict) -> dict:
    """Copy a batch-1 cache into row ``slot`` of the batched cache.

    ``slot_axes`` carries each leaf's batch axis (from comparing abstract
    batch-1 and batch-2 cache shapes at engine construction); leaves with no
    batch axis are slot-independent and pass through unchanged.
    """
    def write(dst, src, axis):
        if axis is None:
            return dst
        idx = [0] * dst.ndim
        idx[axis] = slot
        return jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), tuple(idx))
    return jax.tree.map(write, batched_cache, cache1, slot_axes)
