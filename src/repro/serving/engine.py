"""Batched serving engine with continuous batching and persistent step plans.

Slots hold independent requests; prefill fills a slot's cache region, decode
advances every active slot one token per step.  Both step functions execute
through the framework's persistent-plan cache (compile once, bare dispatch
per iteration — the paper's persistent lifecycle).  When a slot finishes
(EOS / max_tokens), the next queued request takes it over without stalling
the running batch (continuous batching).

The decode batch is fixed-size: empty slots decode padding tokens whose
outputs are ignored — the standard shape-stable TPU serving pattern.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import PlanCache
from repro.models.api import Model
from repro.parallel.context import LOCAL, ParallelContext


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never stops early
    tokens_out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    plan_inits: int = 0
    plan_hits: int = 0


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, max_slots: int = 4,
                 max_len: int = 256, ctx: ParallelContext = LOCAL,
                 greedy: bool = True):
        assert model.has_decode, f"{model.cfg.name} is encoder-only"
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.ctx = ctx
        self.plans = PlanCache()
        self.stats = EngineStats()
        self._queue: deque[Request] = deque()
        self._slots: list[Request | None] = [None] * max_slots
        # one shared batched cache; per-slot position bookkeeping
        self._cache = model.init_cache(max_slots, max_len)
        self._positions = np.zeros(max_slots, np.int64)
        self._uid = 0

        def decode_fn(params, token, cache):
            return model.decode_step(params, token, cache, ctx=ctx)

        self._decode_fn = decode_fn

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: list[int] | np.ndarray, max_new_tokens: int = 16,
               eos_id: int = -1) -> int:
        req = Request(self._uid, np.asarray(prompt, np.int32), max_new_tokens,
                      eos_id)
        self._uid += 1
        self._queue.append(req)
        return req.uid

    def run(self) -> dict[int, list[int]]:
        """Serve until queue and slots drain; returns uid -> generated tokens."""
        finished: dict[int, list[int]] = {}
        while self._queue or any(s is not None for s in self._slots):
            self._fill_slots()
            self._decode_once(finished)
        return finished

    # -- internals ------------------------------------------------------------
    def _fill_slots(self) -> None:
        for i, slot in enumerate(self._slots):
            if slot is None and self._queue:
                req = self._queue.popleft()
                self._prefill_slot(i, req)
                self._slots[i] = req

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-slot prefill into the shared batched cache.

        Uses a per-slot cache of batch 1, then writes the KV rows into the
        batched cache at ``slot``.  Prefill runs at the exact prompt length
        (one persistent plan per distinct length; a production deployment
        would right-pad to power-of-two buckets and pass the true last
        position — same plan-cache machinery, coarser keys).
        """
        prompt = np.asarray(req.prompt, np.int32)[None]
        cache1 = self.model.init_cache(1, self.max_len)

        def prefill_fn(params, batch, cache):
            return self.model.prefill(params, batch, cache, ctx=self.ctx)

        batch = {"tokens": jnp.asarray(prompt)}
        if self.model.cfg.family == "vlm":
            batch["vision_emb"] = jnp.zeros(
                (1, self.model.cfg.vision_tokens, self.model.cfg.d_vision),
                jnp.bfloat16)
        plan = self.plans.get_or_init(prefill_fn, (self.params, batch, cache1))
        logits, cache1 = plan.start(self.params, batch, cache1)
        self.stats.prefills += 1
        # write slot rows; note: bucket-padded positions beyond the prompt are
        # junk but masked by the causal pos bookkeeping (pos = len(prompt)).
        self._cache = _write_slot(self._cache, cache1, slot)
        self._positions[slot] = len(req.prompt)
        last = int(np.argmax(np.asarray(logits)[0, -1]))
        req.tokens_out.append(last)

    def _decode_once(self, finished: dict[int, list[int]]) -> None:
        if not any(s is not None for s in self._slots):
            return
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i, req in enumerate(self._slots):
            if req is not None:
                tokens[i, 0] = req.tokens_out[-1]
        # shared cache decode: pos must be uniform across slots -> use per-slot
        # positions via the max; real engines track per-slot pos in the cache.
        # we decode with cache["pos"] already advanced per-slot at write time.
        plan = self.plans.get_or_init(
            self._decode_fn, (self.params, jnp.asarray(tokens), self._cache))
        logits, self._cache = plan.start(self.params, jnp.asarray(tokens),
                                         self._cache)
        self.stats.decode_steps += 1
        logits = np.asarray(logits)
        for i, req in enumerate(self._slots):
            if req is None:
                continue
            nxt = int(np.argmax(logits[i, 0]))
            req.tokens_out.append(nxt)
            self.stats.tokens_generated += 1
            self._positions[i] += 1
            if (len(req.tokens_out) > req.max_new_tokens
                    or nxt == req.eos_id
                    or self._positions[i] >= self.max_len - 1):
                req.done = True
                finished[req.uid] = req.tokens_out[: req.max_new_tokens]
                self._slots[i] = None
        self.stats.plan_inits = self.plans.stats.inits
        self.stats.plan_hits = self.plans.stats.cache_hits


def _next_pow2(n: int) -> int:
    p = 8
    while p < n:
        p *= 2
    return p


def _write_slot(batched_cache: dict, cache1: dict, slot: int) -> dict:
    """Copy a batch-1 cache into row ``slot`` of the batched cache."""
    def write(dst, src):
        if dst.ndim == 0:
            return jnp.maximum(dst, src)  # pos: keep max over slots
        # find the batch dim (size-1 in src where dst differs)
        for axis in range(dst.ndim):
            if src.shape[axis] == 1 and dst.shape[axis] != 1:
                idx = [0] * dst.ndim
                idx[axis] = slot
                return jax.lax.dynamic_update_slice(
                    dst, src.astype(dst.dtype), tuple(idx))
        return dst
    return jax.tree.map(write, batched_cache, cache1)
