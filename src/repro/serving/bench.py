"""Continuous-batching serve benchmark: tokens/sec over the transport layer.

Runs the :class:`~repro.serving.engine.ServingEngine` end to end on the
8-virtual-device grid with the ring-attention KV rotation routed through
``Message`` tables (``repro.core.transport``), one cell per
(packer, coalesce) wire configuration, and emits ``BENCH_lm_serve.json``
records in the same schema family the stencil sweep produces — tokens/sec
next to the static wire accounting (message_bytes / wire_bytes /
collective_count from the same tables that drive delivery) and the
plan-cache amortization counters.

    PYTHONPATH=src python -m repro.serving.bench --out BENCH_lm_serve.json
    PYTHONPATH=src python -m repro.serving.bench --check BENCH_lm_serve.json

``--check`` is the CI guard: every deterministic field (wire bytes,
collective counts, plan inits/hits, token counts) must match the committed
baseline exactly; only the wall-clock fields are runner-speed-dependent and
are merely required to be positive.  An ``auto`` cell re-runs the best
exact-packer cell from the committed trace with ``selected_by`` provenance
(the autotuner's trace tier applied to the serve path).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Any, Sequence

SCHEMA_VERSION = 1
BENCH_NAME = "lm_serve"

#: deterministic record fields --check compares exactly (everything except
#: wall-clock); tests/benchmarks/test_lm_serve.py validates the full set
STATIC_KEYS = (
    "bench", "schema_version", "strategy", "arch", "n_devices", "n_parts",
    "packer", "transport", "coalesce", "mapping", "seq_bucket",
    "message_bytes", "wire_bytes", "collective_count",
    "tokens_generated", "decode_steps", "prefills",
    "plan_cache_inits", "plan_cache_hits", "selected_by",
)
RECORD_KEYS = STATIC_KEYS + ("tokens_per_sec", "us_per_cycle")

#: the swept wire cells: exact baseline, coalesced exact, compressed wire
CELLS: tuple[tuple[str, bool], ...] = (
    ("slice", False), ("slice", True), ("bf16", True),
)


def ring_comm_stats(
    *,
    seq_bucket: int,
    ring: int,
    n_layers: int,
    n_kv_heads: int,
    head_dim: int,
    dtype_bytes: int,
    packer: str,
    coalesce: bool,
    n_parts: int,
    batch: int = 1,
) -> dict[str, int]:
    """Static per-prefill wire accounting from the SAME Message tables that
    drive delivery (``ring_size`` explicit — no live mesh needed)."""
    import math

    import jax.numpy as jnp

    from repro.core.ring import ring_kv_messages
    from repro.core.transport import get_packer, scheduled_collective_count

    skv = seq_bucket // ring
    kv_shape = (2, batch, skv, n_kv_heads, head_dim)
    msgs = ring_kv_messages(kv_shape, "model", ring, n_parts=n_parts)
    hops = ring - 1  # rotations per ring pass
    per_hop = scheduled_collective_count([msgs], coalesce=coalesce)
    elems = sum(math.prod(m.shape) for m in msgs)
    wire_itemsize = get_packer(packer).wire_itemsize(jnp.float32)
    return {
        "collective_count": per_hop * hops * n_layers,
        "message_bytes": elems * dtype_bytes * hops * n_layers,
        "wire_bytes": elems * wire_itemsize * hops * n_layers,
    }


def serve_once(
    *,
    packer: str = "slice",
    coalesce: bool = True,
    n_parts: int = 1,
    arch: str = "stablelm-1.6b",
    width: int = 64,
    layers: int = 2,
    vocab: int = 512,
    requests: int = 6,
    slots: int = 2,
    max_new: int = 8,
    max_len: int = 128,
    seed: int = 0,
    selected_by: str = "",
) -> dict[str, Any]:
    """One serve cell: build the tiny dense model, serve the request mix on
    the (1, 8) mesh with ring-attention prefill through the Message path,
    and return the BENCH record."""
    import jax
    import numpy as np

    from repro.core.compat import make_mesh, set_mesh
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel.context import ParallelContext
    from repro.serving.engine import ServingEngine, _next_pow2

    ring = 8
    cfg = get_config(arch).reduced().with_updates(
        d_model=width, n_layers=layers, vocab_size=vocab, d_ff=width * 3,
        n_heads=max(4, width // 32), n_kv_heads=max(4, width // 32),
        head_dim=32)
    assert cfg.family == "dense", "the serve bench cells are dense"
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    mesh = make_mesh((1, ring), ("data", "model"))
    ctx = ParallelContext(mesh=mesh, seq_parallel=True, n_parts=n_parts,
                          comm_packer=packer, comm_coalesce=coalesce)

    rng = np.random.default_rng(seed)
    # all prompt lengths land in the ring-divisible 16-bucket, so the whole
    # run compiles ONE bucketed prefill plan + ONE decode plan
    prompts = [
        rng.integers(0, cfg.vocab_size, size=int(rng.integers(9, 17))).tolist()
        for _ in range(requests)
    ]
    seq_bucket = _next_pow2(max(len(p) for p in prompts))

    with set_mesh(mesh):
        engine = ServingEngine(model, params, max_slots=slots,
                               max_len=max_len, ctx=ctx)
        t0 = time.perf_counter()
        uids = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
        results = engine.run()
        dt = time.perf_counter() - t0

    st = engine.stats
    tokens = sum(len(v) for v in results.values())
    assert set(results) == set(uids)
    stats = ring_comm_stats(
        seq_bucket=seq_bucket, ring=ring, n_layers=cfg.n_layers,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
        dtype_bytes=jax.numpy.dtype(cfg.dtype).itemsize,
        packer=packer, coalesce=coalesce, n_parts=n_parts)
    return {
        "bench": BENCH_NAME,
        "schema_version": SCHEMA_VERSION,
        "strategy": "ring-messages",
        "arch": cfg.name,
        "n_devices": ring,
        "n_parts": n_parts,
        "packer": packer,
        "transport": "ppermute",
        "coalesce": coalesce,
        "mapping": "row-major",
        "seq_bucket": seq_bucket,
        "message_bytes": stats["message_bytes"],
        "wire_bytes": stats["wire_bytes"],
        "collective_count": stats["collective_count"],
        "tokens_generated": tokens,
        "decode_steps": st.decode_steps,
        "prefills": st.prefills,
        "plan_cache_inits": st.plan_inits,
        "plan_cache_hits": st.plan_hits,
        "selected_by": selected_by,
        "tokens_per_sec": tokens / dt if dt > 0 else 0.0,
        "us_per_cycle": dt / max(1, st.decode_steps) * 1e6,
    }


def run_cells(**kw: Any) -> list[dict[str, Any]]:
    records = [
        serve_once(packer=p, coalesce=c, **kw) for p, c in CELLS
    ]
    return records


def auto_cell(trace_path: str, **kw: Any) -> dict[str, Any] | None:
    """Re-run the trace's selected cell with ``selected_by="trace"``.

    If the trace already carries a trace-provenance record (the committed
    baseline does), REPLAY that cell — the guard must be deterministic, not
    re-decided from runner-speed-dependent tokens/sec.  Otherwise (initial
    baseline generation) pick the best EXACT-packer cell by tokens/sec;
    lossy packers are never auto-selected."""
    from repro.stencil.sweep import read_bench_json

    if not os.path.exists(trace_path):
        return None
    records, _ = read_bench_json(trace_path)
    records = [r for r in records if r.get("bench") == BENCH_NAME]
    replay = [r for r in records if r.get("selected_by") == "trace"]
    if replay:
        best = replay[0]
    else:
        import jax.numpy as jnp

        from repro.core.transport import get_packer

        exact = [
            r for r in records
            if not r.get("selected_by")
            and get_packer(r["packer"]).wire_tolerance(jnp.float32)
            == (0.0, 0.0)
        ]
        if not exact:
            return None
        best = max(exact, key=lambda r: r.get("tokens_per_sec", 0.0))
    return serve_once(packer=best["packer"], coalesce=best["coalesce"],
                      n_parts=best["n_parts"], selected_by="trace", **kw)


def check_records(
    records: Sequence[dict], baseline_path: str
) -> list[str]:
    """CI guard: deterministic fields must match the committed baseline
    exactly; wall-clock fields only have to be positive.  Returns the list
    of failures (empty = pass)."""
    from repro.stencil.sweep import read_bench_json

    base, _ = read_bench_json(baseline_path)
    base_by_cell = {
        (r["packer"], r["coalesce"], r.get("selected_by", "")): r
        for r in base if r.get("bench") == BENCH_NAME
    }
    failures = []
    for r in records:
        cell = (r["packer"], r["coalesce"], r.get("selected_by", ""))
        want = base_by_cell.get(cell)
        if want is None:
            failures.append(f"cell {cell}: not in baseline {baseline_path}")
            continue
        for key in STATIC_KEYS:
            if r.get(key) != want.get(key):
                failures.append(
                    f"cell {cell}: {key} = {r.get(key)!r}, baseline has "
                    f"{want.get(key)!r}")
        if not r.get("tokens_per_sec", 0) > 0:
            failures.append(f"cell {cell}: tokens_per_sec not positive")
    return failures


def _main_inner(args: argparse.Namespace) -> int:
    kw = dict(requests=args.requests, slots=args.slots, max_new=args.max_new)
    records = run_cells(**kw)
    trace = args.trace or args.check
    if trace:
        tuned = auto_cell(trace, **kw)
        if tuned is not None:
            records.append(tuned)
    for r in records:
        sel = f" selected_by={r['selected_by']}" if r["selected_by"] else ""
        print(f"lm_serve packer={r['packer']} coalesce={r['coalesce']}"
              f" n_parts={r['n_parts']}: {r['tokens_per_sec']:.1f} tok/s,"
              f" wire={r['wire_bytes']}B/prefill,"
              f" collectives={r['collective_count']},"
              f" plans {r['plan_cache_inits']} inits /"
              f" {r['plan_cache_hits']} hits{sel}")
    if args.out:
        payload = {
            "config": {
                "bench": BENCH_NAME, "schema_version": SCHEMA_VERSION,
                "requests": args.requests, "slots": args.slots,
                "max_new": args.max_new,
            },
            "records": records,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote {len(records)} records -> {args.out}")
    if args.check:
        failures = check_records(records, args.check)
        for msg in failures:
            print(f"CHECK FAIL: {msg}", file=sys.stderr)
        if failures:
            return 1
        print(f"check vs {args.check}: OK")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="")
    ap.add_argument("--check", default="",
                    help="committed BENCH_lm_serve.json to guard against")
    ap.add_argument("--trace", default="",
                    help="trace for the auto cell (defaults to --check)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--inner", action="store_true",
                    help="(internal) already inside the 8-device subprocess")
    args = ap.parse_args(argv)
    if not args.inner:
        # re-exec with the virtual device grid pinned before jax initializes
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env.setdefault("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-m", "repro.serving.bench", "--inner",
             *([a for a in (sys.argv[1:] if argv is None else list(argv))])],
            env=env, timeout=1800,
        )
        return out.returncode
    return _main_inner(args)


if __name__ == "__main__":
    raise SystemExit(main())
