"""Pluggable exchange-strategy registry (Comb's comm-method table).

The paper benchmarks three MPI communication methods over one stencil
workload; Comb selects them by name on the command line.  This module is the
equivalent seam for the JAX port: every strategy is a registered
:class:`ExchangeStrategy` subclass selected through :func:`make_driver`, and
all strategy-specific knobs travel in a typed :class:`StrategyConfig` instead
of positional arguments threaded through the benchmark drivers.

Built-in strategies (the paper's three):

* ``standard``     — Alg. 1: per-iteration plan assembly + jit python
  dispatch (fresh Isend/Irecv envelopes each iteration).
* ``persistent``   — Alg. 2/3/4: AOT-compiled :class:`~repro.core.plan.
  CommPlan`, bare executable dispatch per iteration (``MPI_Start``).
* ``partitioned``  — Alg. 5/6/7: persistent lifecycle + every face split
  into ``n_parts`` partitions packed/sent/unpacked independently
  (``n_parts`` is the thread-count analogue of the paper's §VI sweep).

Adding a strategy::

    @register_strategy
    class MyStrategy(ExchangeStrategy):
        name = "mine"
        def init(self, example): ...
        def step(self, x): ...

and it is immediately sweepable by ``repro.stencil.sweep`` and selectable in
``comb_measure(strategies=("standard", "mine"))``.
"""

from __future__ import annotations

import abc
import dataclasses
import time
from typing import Callable, ClassVar

import jax
from jax.sharding import Mesh

from repro.core import compat
from repro.core.autotune import AUTO
from repro.core.halo import (
    HaloSpec,
    exchange,
    exchange_fused,
    fused_message_group,
    ghost_pspec,
    sequential_message_groups,
)
from repro.core.plan import (
    PLANS,
    CommPlan,
    PlanCache,
    transport_plan,
)
from repro.core.transport import (
    get_packer,
    get_transport,
    schedule_layouts,
    scheduled_collective_count,
)


# ---------------------------------------------------------------------------
# typed configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    """Strategy-specific knobs, carried as one typed value.

    ``n_parts``      — partition count per face (partitioned only; the
                       thread-count analogue in the paper's §VI study).
    ``plan_cache``   — where persistent plans live: ``"private"`` (one fresh
                       plan per driver, freed with it), ``"shared"`` (the
                       process-wide :data:`~repro.core.plan.PLANS` table of
                       initialized requests), or an explicit
                       :class:`~repro.core.plan.PlanCache` instance.
    ``donate``       — donate the input buffer to the step executable
                       (in-place ghost update, the MPI buffer-reuse analogue).
    ``packer``       — registered :class:`~repro.core.transport.Packer` every
                       message of this strategy's exchange stages through
                       (``"slice"`` = inline lax staging, ``"pallas"`` = the
                       Comb-style copy kernel; a first-class §VI sweep axis).
    ``transport``    — registered :class:`~repro.core.transport.Transport`
                       backend moving the packed buffers (``"ppermute"``
                       in-process; ``"multihost"`` is the multi-process seam).
    ``coalesce``     — aggregate each delivery group's messages into ONE
                       contiguous wire buffer + one composed collective per
                       hop chain (static :class:`~repro.core.transport.
                       WireLayout` offset tables recorded in the persistent
                       plan; partitions stay pipelined rounds).  Default on;
                       the off-path is the uncoalesced baseline cell of the
                       §VI sweep's coalesce axis.
    ``mapping``      — registered process-to-node placement
                       (:mod:`repro.launch.mapping`) the driver's mesh was
                       built under.  Purely identity: the schedule never
                       depends on it, but it travels into
                       :class:`~repro.core.halo.HaloSpec` and the persistent
                       plan key, and the sweep/BENCH records stamp it per
                       cell.  Aliases (``"rb"``) canonicalize at
                       construction.

    ``name``, ``packer``, and ``coalesce`` also accept the sentinel
    ``"auto"``: :func:`make_driver` then routes to :class:`AutoStrategy`,
    which resolves every ``auto`` axis at plan-build time through
    :mod:`repro.core.autotune` (trace-driven cost model, else in-situ
    calibration).  A non-``auto`` value on any axis pins that axis and
    autotuning ranges only over the rest.
    """

    name: str = "standard"
    n_parts: int = 1
    plan_cache: str | PlanCache = "private"
    donate: bool = True
    packer: str = "slice"
    transport: str = "ppermute"
    coalesce: bool | str = True
    mapping: str = "row-major"
    #: membership epoch of the grid this driver's mesh belongs to
    #: (:mod:`repro.launch.membership`); ``None`` = outside the membership
    #: domain.  Identity only, like ``mapping``: it flows into
    #: :class:`~repro.core.halo.HaloSpec` and therefore every persistent
    #: plan key and ``ScheduleInfo.tag()``, so plans built before a
    #: JOIN/LOSS re-formation can never hit after it — and only
    #: epoch-stamped plans are candidates for
    #: :meth:`~repro.core.plan.PlanCache.invalidate_stale_epochs`.
    epoch: int | None = None

    def __post_init__(self):
        assert self.n_parts >= 1, self.n_parts
        if isinstance(self.plan_cache, str):
            assert self.plan_cache in ("private", "shared"), self.plan_cache
        if self.packer != AUTO:
            get_packer(self.packer)  # fail construction, not mid-sweep
        assert isinstance(self.coalesce, bool) or self.coalesce == AUTO, (
            self.coalesce
        )
        get_transport(self.transport)
        from repro.launch.mapping import canonical_mapping

        object.__setattr__(self, "mapping", canonical_mapping(self.mapping))

    def resolve_cache(self) -> PlanCache | None:
        """``None`` means un-cached private plans (freed by the driver)."""
        if isinstance(self.plan_cache, PlanCache):
            return self.plan_cache
        if self.plan_cache == "shared":
            return PLANS
        return None

    def with_(self, **kw) -> "StrategyConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# strategy base class
# ---------------------------------------------------------------------------


class ExchangeStrategy(abc.ABC):
    """One halo-exchange (+ optional local update) iteration driver.

    Lifecycle mirrors the MPI request lifecycle the paper measures::

        drv.init(example)   # *_init   (no-op for the standard baseline)
        x = drv.step(x)     # Start / Isend+Irecv
        x = drv.wait(x)     # Waitall
        drv.free()          # Request_free
    """

    #: registry key; subclasses must override.
    name: ClassVar[str] = ""
    #: whether ``config.n_parts`` reaches the exchange (partitioned
    #: transport); non-partitioning strategies always exchange whole faces.
    uses_partitions: ClassVar[bool] = False
    #: whether ``init`` pays amortizable setup worth timing; benchmark
    #: harnesses charge ``init_us`` only to strategies that set this.
    amortizes_init: ClassVar[bool] = False

    def __init__(
        self,
        mesh: Mesh,
        spec_builder: Callable[[], HaloSpec],
        ndim: int,
        *,
        config: StrategyConfig | None = None,
        update_fn: Callable[[jax.Array], jax.Array] | None = None,
    ):
        self.mesh = mesh
        self.ndim = ndim
        self.config = (config or StrategyConfig(name=self.name)).with_(
            name=self.name
        )
        self._spec_builder = spec_builder
        self.update_fn = update_fn

    # -- identity ----------------------------------------------------------
    @property
    def strategy(self) -> str:
        return self.name

    @property
    def n_parts(self) -> int:
        return self.config.n_parts

    @property
    def packer(self) -> str:
        return self.config.packer

    @property
    def transport(self) -> str:
        return self.config.transport

    #: schedule identity recorded in compiled transport plans
    schedule_kind: ClassVar[str] = "sequential"

    def build_spec(self) -> HaloSpec:
        """The exchange plan inputs, stamped with this strategy's identity.

        Partition count, packer, and transport come from the *config*, not
        the builder — the builder only describes geometry (which axes, halo
        width, topology).  Strategies opt into partitioned transport via
        ``uses_partitions``.
        """
        spec = self._spec_builder()
        n_parts = self.n_parts if self.uses_partitions else 1
        return spec.with_(
            strategy=self.name, n_parts=n_parts,
            packer=self.config.packer, transport=self.config.transport,
            coalesce=self.config.coalesce, mapping=self.config.mapping,
            epoch=self.config.epoch,
        )

    # -- plan assembly ------------------------------------------------------
    def _build_step(self) -> Callable[[jax.Array], jax.Array]:
        spec = self.build_spec()  # neighbor tables, slabs, partitions
        pspec = ghost_pspec(spec, self.ndim)
        update = self.update_fn

        def step(x: jax.Array) -> jax.Array:
            x = exchange(x, spec)
            if update is not None:
                x = update(x)
            return x

        return compat.shard_map(
            step, mesh=self.mesh, in_specs=pspec, out_specs=pspec
        )

    # -- schedule introspection ---------------------------------------------
    def _local_block_shape(self, example_shape: tuple[int, ...]) -> tuple[int, ...]:
        """Per-shard ghosted block shape of a globally stored example."""
        spec = self.build_spec()
        shape = list(example_shape)
        for name, a in zip(spec.mesh_axes, spec.array_axes):
            shape[a] //= self.mesh.shape[name]
        return tuple(shape)

    def _message_groups(
        self, shape: tuple[int, ...], spec: HaloSpec
    ) -> tuple[tuple, ...]:
        """The strategy's message tables for one local block shape — the
        same assembler the traced step runs, evaluated outside the trace
        (axis sizes come from the mesh, not ``lax.axis_size``)."""
        sizes = {name: self.mesh.shape[name] for name in spec.mesh_axes}
        return sequential_message_groups(shape, spec, sizes)

    def scheduled_collectives(self, example: jax.Array) -> int:
        """Collectives one step launches — the §VI sweep records this next
        to the plan-cache counters so coalescing's one-collective-per-
        neighbor claim is visible in BENCH artifacts."""
        spec = self.build_spec()
        groups = self._message_groups(
            self._local_block_shape(example.shape), spec
        )
        return scheduled_collective_count(groups, coalesce=spec.coalesce)

    def replan_tables(self, example) -> tuple[tuple, tuple]:
        """Re-derive the FULL static transport schedule for the current
        topology: ``(message groups, wire layouts)``.

        This is the elastic re-plan primitive — after a mesh change the
        surviving topology's :class:`~repro.core.transport.Message` tables
        and :class:`~repro.core.transport.WireLayout` offset tables are
        recomputed from scratch.  The derivation is a pure function of
        (block shape, spec, mesh axis sizes): no device identity, rank id,
        or runtime state enters, so repeated calls — and calls on meshes
        with permuted devices — return identical tables (asserted by the
        elastic runner and tests/core/test_replan_purity.py).  Everything
        here is table math; the expensive trace+compile a topology change
        *also* triggers is measured separately as ``init_us``, while this
        call's time is the sweep's ``replan_us`` metric.
        """
        spec = self.build_spec()
        groups = self._message_groups(
            self._local_block_shape(tuple(example.shape)), spec
        )
        layouts = (
            schedule_layouts(groups, spec.packer, example.dtype)
            if spec.coalesce else ()
        )
        return groups, layouts

    def wire_layouts(self, example: jax.Array) -> tuple:
        """The coalesced schedule's static offset tables (empty when the
        strategy runs uncoalesced) — what persistent plans record."""
        return self.replan_tables(example)[1]

    # -- lifecycle ----------------------------------------------------------
    @abc.abstractmethod
    def init(self, example: jax.Array) -> None:
        """Pay any amortizable setup (trace+lower+compile for persistent)."""

    @abc.abstractmethod
    def step(self, x: jax.Array) -> jax.Array:
        """One exchange(+update) iteration; async (returns futures)."""

    @staticmethod
    def wait(x: jax.Array) -> jax.Array:
        return jax.block_until_ready(x)  # MPI_Waitall

    def free(self) -> None:
        """Release strategy-held executables (no-op by default)."""

    # -- introspection ------------------------------------------------------
    def compiled_text(self, example: jax.Array) -> str:
        """Post-optimization HLO of the step (for overlap/HLO analysis)."""
        raise NotImplementedError(f"{self.name} has no compiled plan")


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type[ExchangeStrategy]] = {}


def register_strategy(cls: type[ExchangeStrategy]) -> type[ExchangeStrategy]:
    """Class decorator: add ``cls`` to the strategy table under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty `name`")
    if cls.name in _REGISTRY:
        raise ValueError(
            f"strategy {cls.name!r} already registered "
            f"({_REGISTRY[cls.name].__name__})"
        )
    _REGISTRY[cls.name] = cls
    return cls


def available_strategies() -> tuple[str, ...]:
    """Registered strategy names, registration order (paper order first)."""
    return tuple(_REGISTRY)


def get_strategy(name: str) -> type[ExchangeStrategy]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown exchange strategy {name!r}; "
            f"registered: {', '.join(_REGISTRY) or '(none)'}"
        ) from None


def make_driver(
    strategy: str | StrategyConfig,
    mesh: Mesh,
    spec_builder: Callable[[], HaloSpec],
    ndim: int,
    *,
    update_fn: Callable[[jax.Array], jax.Array] | None = None,
    **config_kw,
) -> ExchangeStrategy:
    """The factory: name-or-config in, initialized-on-demand driver out.

    Any ``auto`` axis (name, packer, or coalesce) routes to
    :class:`AutoStrategy`, which resolves the remaining axes at plan-build
    time and then behaves exactly as the driver it picked.
    """
    if isinstance(strategy, StrategyConfig):
        config = strategy
    else:
        config = StrategyConfig(name=strategy, **config_kw)
    if AUTO in (config.name, config.packer, config.coalesce):
        return AutoStrategy(
            mesh, spec_builder, ndim, config=config, update_fn=update_fn
        )
    cls = get_strategy(config.name)
    return cls(mesh, spec_builder, ndim, config=config, update_fn=update_fn)


# ---------------------------------------------------------------------------
# the paper's three strategies
# ---------------------------------------------------------------------------


@register_strategy
class StandardStrategy(ExchangeStrategy):
    """Alg. 1: plan re-assembled in python + jit-dispatch every iteration.

    The compiled executable is reused (as MPI reuses connection state) —
    only the per-iteration envelope/plan assembly differs from persistent.
    """

    name = "standard"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._jitted = None  # compiled state reused across iterations

    def init(self, example: jax.Array) -> None:
        return None  # nothing to amortize: baseline sets up per iteration

    def step(self, x: jax.Array) -> jax.Array:
        # Re-derive the plan in python every iteration (neighbor tables,
        # slab geometry, partition layout) — the envelope-posting work
        # persistent MPI amortizes — then dispatch via the jit python path.
        spec = self.build_spec()
        for name in spec.mesh_axes:  # envelope assembly per neighbor pair
            k = self.mesh.shape[name]
            _ = [(i, (i - 1) % k) for i in range(k)]
            _ = [(i, (i + 1) % k) for i in range(k)]
        if self._jitted is None:
            donate = (0,) if self.config.donate else ()
            self._jitted = jax.jit(self._build_step(), donate_argnums=donate)
        return self._jitted(x)

    def free(self) -> None:
        self._jitted = None


@register_strategy
class PersistentStrategy(ExchangeStrategy):
    """Alg. 2/3/4: AOT-compile once at ``init``, bare dispatch per ``step``."""

    name = "persistent"
    amortizes_init = True

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._plan: CommPlan | None = None

    def _plan_key(self, example: jax.Array):
        """Structural plan identity: the step fn is a fresh closure per
        driver, so the cache key must come from what the closure *computes*
        — spec geometry, mesh, update fn, and the abstract input.  The mesh
        and update fn go in by *object* (the cache holds them alive, so
        their identity can't be recycled), letting equal meshes share."""
        return (
            "halo_plan", self.build_spec(), self.ndim, self.config.donate,
            self.mesh, self.update_fn,
            example.shape, str(example.dtype), str(example.sharding),
        )

    def _make_plan(
        self, example: jax.Array, example_args, donate: tuple[int, ...]
    ) -> CommPlan:
        """Overridable plan assembly; ``init`` computes the inputs once.

        The compiled executable is a *transport schedule*: its identity
        (plan name + structural cache key via :meth:`_plan_key` -> spec)
        records the choreography kind, the packer/transport backends, and
        the coalesce mode; a coalesced plan also records its static wire-
        buffer offset tables (``plan.wire_layouts``), computed here exactly
        once — the ``MPI_Send_init`` buffer-amortization analogue.
        """
        return transport_plan(
            self._build_step, example_args,
            schedule=self.build_spec().schedule_info(self.schedule_kind),
            layouts=lambda: self.wire_layouts(example),
            donate_argnums=donate,
            cache=self.config.resolve_cache(), key=self._plan_key(example),
            name=f"halo_{self.name}@{self.config.packer}",
        )

    def init(self, example: jax.Array) -> None:
        if self._plan is not None:
            return
        donate = (0,) if self.config.donate else ()
        example_args = (
            jax.ShapeDtypeStruct(
                example.shape, example.dtype, sharding=example.sharding
            ),
        )
        self._plan = self._make_plan(example, example_args, donate)

    def step(self, x: jax.Array) -> jax.Array:
        if self._plan is None:
            self.init(x)
        # MPI_Startall: bare dispatch of the AOT-compiled executable —
        # async, zero plan assembly, no jit python path in front.
        return self._plan.start(x)

    def free(self) -> None:
        # shared-cache plans stay initialized for other drivers (freed via
        # the cache's own free_all), private plans die with the driver.
        if self._plan is not None and self.config.resolve_cache() is None:
            self._plan.free()
        self._plan = None

    def compiled_text(self, example: jax.Array) -> str:
        if self._plan is None:
            self.init(example)
        assert self._plan is not None
        return self._plan.as_text()


@register_strategy
class PartitionedStrategy(PersistentStrategy):
    """Alg. 5/6/7: persistent lifecycle, faces split into ``n_parts``
    partitions each packed -> sent -> unpacked independently (early work)."""

    name = "partitioned"
    uses_partitions = True


# ---------------------------------------------------------------------------
# overlap strategies (beyond the paper's trio)
# ---------------------------------------------------------------------------


@register_strategy
class FusedStrategy(PersistentStrategy):
    """Fused multi-axis exchange: all D axis passes in one combined step.

    The sequential schedule exchanges axis by axis (each pass's slabs
    include the previous pass's refreshed ghosts, the corner trick); the
    fused schedule posts all ``3^D - 1`` face/edge/corner messages from the
    original buffer in a single pass (:func:`repro.core.halo.
    exchange_fused`) and compiles them into ONE multi-axis
    :class:`~repro.core.plan.CommPlan` (a ``"fused"``-kind transport
    schedule via :func:`repro.core.plan.transport_plan`).  No message
    depends on another, so packs, sends, and unpacks of every axis may
    overlap — trading D dependent passes for maximal concurrency, the Comb
    fused-packing analogue.
    """

    name = "fused"
    schedule_kind = "fused"

    def _message_groups(self, shape, spec):
        sizes = {name: self.mesh.shape[name] for name in spec.mesh_axes}
        return (fused_message_group(shape, spec, sizes),)

    def _build_step(self) -> Callable[[jax.Array], jax.Array]:
        spec = self.build_spec()
        pspec = ghost_pspec(spec, self.ndim)
        update = self.update_fn

        def step(x: jax.Array) -> jax.Array:
            x = exchange_fused(x, spec)
            if update is not None:
                x = update(x)
            return x

        return compat.shard_map(
            step, mesh=self.mesh, in_specs=pspec, out_specs=pspec
        )


@register_strategy
class OverlapStrategy(PersistentStrategy):
    """Double-buffered ghosts: interior update overlapped with the exchange.

    The classic communication/computation-overlap schedule: each step reads
    buffer A and writes buffer B (donation is disabled so both stay live —
    the double buffer; the returned buffer feeds the next step, so the pair
    alternates).  The local update is split by :func:`repro.stencil.domain.
    interior_halo_split`: the deep-interior piece is computed from buffer A
    *while* the boundary exchange is in flight (it has no data dependency
    on the collectives), and only the thin boundary shells wait for the
    refreshed ghosts.

    ``update_fn`` must satisfy the split contract (local shift-invariant
    stencil of radius <= halo on decomposed axes, rim left untouched);
    without an ``update_fn`` the step degenerates to a persistent exchange.
    """

    name = "overlap"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        # double buffering is the whole point: never update in place.
        self.config = self.config.with_(donate=False)

    def _build_step(self) -> Callable[[jax.Array], jax.Array]:
        from repro.stencil.domain import overlapped_update

        spec = self.build_spec()
        pspec = ghost_pspec(spec, self.ndim)
        update = self.update_fn

        def step(x: jax.Array) -> jax.Array:
            fresh = exchange(x, spec)  # boundary exchange in flight...
            if update is None:
                return fresh
            # ...while the deep interior computes from the stale buffer
            return overlapped_update(
                x, fresh, update,
                array_axes=spec.array_axes, halo=spec.halo,
            )

        return compat.shard_map(
            step, mesh=self.mesh, in_specs=pspec, out_specs=pspec
        )


# ---------------------------------------------------------------------------
# autotuned selection (not registered: "auto" is a selector, not a schedule)
# ---------------------------------------------------------------------------


class AutoStrategy(ExchangeStrategy):
    """Resolve every ``auto`` config axis at plan-build time, then delegate.

    On the first ``init``/``step`` the driver enumerates the candidate
    ``(strategy, packer, coalesce, n_parts)`` grid (any concretely-pinned
    axis stays pinned), computes each candidate's static schedule features
    — ``wire_bytes``, collective count, and the intra/inter-node send tally
    under the LIVE mesh's node vector — and asks the process-wide
    :func:`repro.core.autotune.default_tuner` to pick: by recorded trace,
    by fitted cost model, or (when neither covers the cell) by in-situ
    timed probes through this driver's own plan cache.  The winning probe's
    compiled plan is thereby already initialized when the resolved inner
    driver starts — the paper's amortization argument applied to the tuning
    step itself.

    After resolution the driver IS the chosen one: ``strategy``/``config``
    report the concrete cell, and ``selected_by``/``predicted_us``/
    ``calibration_us`` carry the provenance that
    :func:`repro.stencil.comb.run_cycles` stamps into BENCH records.
    ``selected_by`` also lands in :class:`~repro.core.halo.HaloSpec` (and
    so in every persistent plan key): an autotuned plan never silently
    aliases a hand-pinned one.
    """

    name = AUTO
    amortizes_init = True  # resolution + the inner init are the setup cost

    def __init__(self, mesh, spec_builder, ndim, *, config=None,
                 update_fn=None):
        config = config or StrategyConfig(
            name=AUTO, packer=AUTO, coalesce=AUTO
        )
        super().__init__(
            mesh, spec_builder, ndim, config=config, update_fn=update_fn
        )
        # the base ctor stamps name="auto"; restore the caller's strategy
        # pin (e.g. name="persistent", packer="auto" tunes the packer only)
        self.config = config
        self._inner: ExchangeStrategy | None = None
        self._owned_cache: PlanCache | None = None
        #: selection provenance, populated at resolution
        self.selected_by: str | None = None
        self.predicted_us: float | None = None
        self.calibration_us: float = 0.0

    # -- identity: the sentinel before resolution, the winner after --------
    @property
    def strategy(self) -> str:
        return self._inner.strategy if self._inner is not None else AUTO

    @property
    def n_parts(self) -> int:
        return self._inner.n_parts if self._inner is not None else 1

    # -- candidate grid -----------------------------------------------------
    def _probe_plan_cache(self) -> str | PlanCache:
        """Probe drivers and the resolved driver share ONE cache, so the
        winner's probe plan is a cache hit, not a recompile.  A "private"
        request becomes a driver-owned cache (freed with this driver);
        "shared"/explicit caches pass through."""
        if self.config.plan_cache == "private":
            if self._owned_cache is None:
                self._owned_cache = PlanCache()
            return self._owned_cache
        return self.config.plan_cache

    def _candidate_config(self, cand) -> StrategyConfig:
        return self.config.with_(
            name=cand.strategy, packer=cand.packer,
            coalesce=cand.coalesce, n_parts=cand.n_parts,
            plan_cache=self._probe_plan_cache(),
        )

    def _candidates(self, dtype):
        from repro.core import autotune

        pin = lambda v: None if v == AUTO else (v,)
        return autotune.default_candidates(
            dtype=dtype,
            strategies=pin(self.config.name),
            packers=pin(self.config.packer),
            coalesce_modes=(
                None if self.config.coalesce == AUTO
                else (bool(self.config.coalesce),)
            ),
            part_counts=(
                autotune.DEFAULT_PART_COUNTS if self.config.n_parts == 1
                else (self.config.n_parts,)
            ),
        )

    # -- resolution ---------------------------------------------------------
    def _probe(self, cand, example: jax.Array) -> float:
        """One timed calibration run of a candidate (Comb protocol in
        miniature: init, warmup, barrier, timed cycles).  Probes run on a
        COPY of the example (donation-safe, and legal on non-addressable
        multihost arrays, unlike ``jnp.array``), through a plan spec
        stamped ``selected_by="calibration"`` — the same stamp the resolved
        driver uses, so the winner's plan key matches and its compiled plan
        is reused."""
        from repro.core.autotune import PROBE_CYCLES, PROBE_WARMUP

        drv = make_driver(
            self._candidate_config(cand), self.mesh,
            lambda: self._spec_builder().with_(selected_by="calibration"),
            self.ndim, update_fn=self.update_fn,
        )
        x = jax.jit(lambda a: a + 0)(example)
        try:
            drv.init(x)
            for _ in range(PROBE_WARMUP):
                x = drv.step(x)
            drv.wait(x)
            t0 = time.perf_counter()
            for _ in range(PROBE_CYCLES):
                x = drv.step(x)
            drv.wait(x)
            us = (time.perf_counter() - t0) / PROBE_CYCLES * 1e6
            if jax.process_count() > 1:
                # every rank must adopt the SAME timing or the SPMD ranks
                # could resolve different winners and deadlock the mesh
                from jax.experimental import multihost_utils
                import numpy as np

                us = float(multihost_utils.broadcast_one_to_all(
                    np.float32(us)
                ))
            return us
        finally:
            drv.free()  # the shared probe cache keeps the plan initialized

    def _resolve(self, example) -> None:
        if self._inner is not None:
            return
        import numpy as np

        from repro.core import autotune
        from repro.core.transport import schedule_locality
        from repro.launch.mapping import default_node_size, mesh_node_ids

        geo = self._spec_builder()  # geometry only: axes, halo, topology
        candidates = self._candidates(example.dtype)
        axis_names = tuple(self.mesh.axis_names)
        axis_sizes = {name: self.mesh.shape[name] for name in axis_names}
        n_devices = int(self.mesh.devices.size)
        node_size = default_node_size(n_devices, jax.process_count())
        node_of = mesh_node_ids(self.mesh, node_size)
        # per-shard ghosted block shape (pure geometry, no strategy id)
        block = list(example.shape)
        for name, a in zip(geo.mesh_axes, geo.array_axes):
            block[a] //= self.mesh.shape[name]
        face_elems = autotune.max_face_elems(
            tuple(block), geo.array_axes, geo.halo
        )
        cell = {
            "mesh_shape": tuple(axis_sizes[name] for name in axis_names),
            "shape": tuple(example.shape),
            "dtype": str(example.dtype),
            "halo": geo.halo,
            "mapping": self.config.mapping,
            "transport": self.config.transport,
            "node_size": node_size,
            "message_bytes": face_elems * np.dtype(example.dtype).itemsize,
        }
        # static features per candidate; message tables depend only on
        # (strategy, n_parts) — packer/coalesce reuse them (same rule as
        # the sweep's groups_cache)
        groups_cache: dict[tuple[str, int], tuple] = {}
        features = {}
        for cand in candidates:
            gkey = (cand.strategy, cand.n_parts)
            if gkey not in groups_cache:
                drv = make_driver(
                    self._candidate_config(cand), self.mesh,
                    self._spec_builder, self.ndim, update_fn=self.update_fn,
                )
                groups_cache[gkey] = drv._message_groups(
                    drv._local_block_shape(tuple(example.shape)),
                    drv.build_spec(),
                )
            groups = groups_cache[gkey]
            loc = schedule_locality(
                groups, axis_order=axis_names, axis_sizes=axis_sizes,
                node_of=node_of,
            )
            features[cand] = autotune.CellFeatures(
                wire_bytes=face_elems
                * get_packer(cand.packer).wire_itemsize(example.dtype),
                collective_count=scheduled_collective_count(
                    groups, coalesce=cand.coalesce
                ),
                intra_sends=loc.intra_sends,
                inter_sends=loc.inter_sends,
            )
        verdict = autotune.default_tuner().choose_or_calibrate(
            candidates, features, cell,
            probe=lambda cand: self._probe(cand, example),
        )
        self.selected_by = verdict.selected_by
        self.predicted_us = verdict.predicted_us
        self.calibration_us = verdict.calibration_us
        stamp = verdict.plan_stamp()
        self._inner = make_driver(
            self._candidate_config(verdict.candidate), self.mesh,
            lambda: self._spec_builder().with_(selected_by=stamp),
            self.ndim, update_fn=self.update_fn,
        )
        # the resolved driver's config (incl. overlap's forced
        # donate=False) becomes this driver's visible identity
        self.config = self._inner.config

    # -- lifecycle: resolve, then delegate ----------------------------------
    def init(self, example: jax.Array) -> None:
        self._resolve(example)
        self._inner.init(example)

    def step(self, x: jax.Array) -> jax.Array:
        if self._inner is None:
            self._resolve(x)
        return self._inner.step(x)

    def free(self) -> None:
        if self._inner is not None:
            self._inner.free()
        if self._owned_cache is not None:
            self._owned_cache.free_all()

    def build_spec(self) -> HaloSpec:
        if self._inner is None:
            raise RuntimeError(
                "auto strategy has no spec before resolution; "
                "call init(example) first"
            )
        return self._inner.build_spec()

    def scheduled_collectives(self, example: jax.Array) -> int:
        self._resolve(example)
        return self._inner.scheduled_collectives(example)

    def replan_tables(self, example) -> tuple[tuple, tuple]:
        self._resolve(example)
        return self._inner.replan_tables(example)

    def wire_layouts(self, example: jax.Array) -> tuple:
        self._resolve(example)
        return self._inner.wire_layouts(example)

    def compiled_text(self, example: jax.Array) -> str:
        self._resolve(example)
        return self._inner.compiled_text(example)
