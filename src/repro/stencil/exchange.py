"""Back-compat facade over the exchange-strategy registry.

The three strategies (standard / persistent / partitioned) used to be
string-dispatched branches inside one ``ExchangeDriver`` class; they now live
as registered drivers in :mod:`repro.stencil.strategies`.  This module keeps
the historical entry point: ``ExchangeDriver(mesh, spec_builder, ndim,
strategy=...)`` constructs the registered driver for ``strategy`` via the
factory and exposes the same lifecycle (``init`` / ``step`` / ``wait`` /
``free`` / ``compiled_text``).

New code should call :func:`repro.stencil.strategies.make_driver` directly
with a :class:`~repro.stencil.strategies.StrategyConfig`.
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import Mesh

from repro.core.halo import HaloSpec
from repro.core.plan import PlanCache
from repro.stencil.strategies import (
    ExchangeStrategy,
    StrategyConfig,
    make_driver,
)


def ExchangeDriver(
    mesh: Mesh,
    spec_builder: Callable[[], HaloSpec],
    ndim: int,
    *,
    strategy: str | None = None,
    update_fn: Callable[[jax.Array], jax.Array] | None = None,
    plan_cache: PlanCache | None = None,
) -> ExchangeStrategy:
    """One halo-exchange (+ optional local update) iteration, per strategy.

    Factory function (historically a class): resolves ``strategy`` — by
    explicit name, else from the spec builder's ``strategy`` field — through
    the registry.  ``n_parts`` is likewise lifted from the built spec so
    legacy callers that baked partition counts into ``Domain.halo_spec``
    keep their meaning.
    """
    spec = spec_builder()
    config = StrategyConfig(
        name=strategy or spec.strategy,
        n_parts=max(1, spec.n_parts),
        packer=spec.packer,
        transport=spec.transport,
        coalesce=spec.coalesce,
        plan_cache=plan_cache if plan_cache is not None else "private",
    )
    return make_driver(
        config, mesh, spec_builder, ndim, update_fn=update_fn
    )
