"""The three exchange strategies as runnable step drivers (Comb's comm layer).

:class:`ExchangeDriver` owns one iteration of the paper's Algorithm 1/3/6 on a
device mesh:

* ``standard``    — Alg. 1: the exchange *plan* (HaloSpec, neighbor permutation
  tables, slab geometry) is re-assembled in python and the step dispatched
  through the normal jit python path **every call**, like posting fresh
  Isend/Irecv envelopes each iteration.  The compiled executable is reused
  (as MPI reuses its connection state) — only the per-iteration setup differs.
* ``persistent``  — Alg. 2/3/4: ``init()`` AOT-compiles the step once into a
  :class:`~repro.core.plan.CommPlan` (permutation tables baked in);
  ``step()`` is bare executable dispatch; ``free()`` releases it.
* ``partitioned`` — Alg. 5/6/7: same persistent lifecycle, but every face is
  split into ``n_parts`` partitions, each packed -> sent -> unpacked
  independently (early work).

The measurable difference between standard and persistent on any backend is
the per-iteration plan-assembly + dispatch overhead — exactly the overhead
class the paper's persistent MPI amortizes (benchmarks/measured_dispatch.py).
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import Mesh

from repro.core.halo import HaloSpec, exchange, ghost_pspec
from repro.core.plan import CommPlan, PlanCache


class ExchangeDriver:
    """One halo-exchange (+ optional local update) iteration, per strategy."""

    def __init__(
        self,
        mesh: Mesh,
        spec_builder: Callable[[], HaloSpec],
        ndim: int,
        *,
        strategy: str | None = None,
        update_fn: Callable[[jax.Array], jax.Array] | None = None,
        plan_cache: PlanCache | None = None,
    ):
        self.mesh = mesh
        self.ndim = ndim
        self._spec_builder = spec_builder
        self.strategy = strategy or spec_builder().strategy
        self.update_fn = update_fn
        self._plan: CommPlan | None = None
        self._cache = plan_cache
        self._jitted = None  # standard-path jit handle (compiled state reused)

    # -- plan assembly (this work is per-call for standard, once for others) --
    def _build_step(self) -> Callable[[jax.Array], jax.Array]:
        spec = self._spec_builder()  # neighbor tables, slabs, partitions
        pspec = ghost_pspec(spec, self.ndim)
        update = self.update_fn

        def step(x: jax.Array) -> jax.Array:
            x = exchange(x, spec)
            if update is not None:
                x = update(x)
            return x

        return jax.shard_map(
            step, mesh=self.mesh, in_specs=pspec, out_specs=pspec, check_vma=False
        )

    # -- lifecycle ------------------------------------------------------------
    def init(self, example: jax.Array) -> None:
        """Persistent/partitioned: pay trace+lower+compile once (MPI *_init)."""
        if self.strategy == "standard":
            return  # nothing to amortize: baseline sets up per iteration
        step = self._build_step()  # plan assembled exactly once
        self._plan = CommPlan(
            step,
            example_args=(jax.ShapeDtypeStruct(example.shape, example.dtype,
                                               sharding=example.sharding),),
            donate_argnums=(0,),
            name=f"halo_{self.strategy}",
        )
        # dispatch handle: the per-iteration fast path (jax's optimized
        # dispatch), with no per-iteration plan assembly in front of it.
        self._jitted = jax.jit(step, donate_argnums=(0,))

    def step(self, x: jax.Array) -> jax.Array:
        if self.strategy == "standard":
            # Alg. 1: re-derive the plan in python every iteration (neighbor
            # tables, slab geometry, partition layout) — the envelope-posting
            # work persistent MPI amortizes — then dispatch via the jit
            # python path.  The compiled executable itself is reused.
            spec = self._spec_builder()
            for name in spec.mesh_axes:  # envelope assembly per neighbor pair
                k = self.mesh.shape[name]
                _ = [(i, (i - 1) % k) for i in range(k)]
                _ = [(i, (i + 1) % k) for i in range(k)]
            if self._jitted is None:
                self._jitted = jax.jit(self._build_step(), donate_argnums=(0,))
            return self._jitted(x)
        if self._plan is None:
            self.init(x)
        return self._jitted(x)  # MPI_Startall; async, zero plan assembly

    @staticmethod
    def wait(x: jax.Array) -> jax.Array:
        return jax.block_until_ready(x)  # MPI_Waitall

    def free(self) -> None:
        if self._plan is not None:
            self._plan.free()
            self._plan = None

    # -- introspection ----------------------------------------------------------
    def compiled_text(self, example: jax.Array) -> str:
        if self._plan is None:
            self.init(example)
        assert self._plan is not None
        return self._plan.as_text()
