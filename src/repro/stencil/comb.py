"""Comb-style benchmark driver: barriered, multi-cycle halo-exchange timing.

Follows the paper's measurement protocol (§V): synchronize before timing, run
many exchange cycles, extract the average per-cycle cost, repeat the whole
measurement several times and average.  On this CPU container the *measured*
numbers capture real pack/update compute and the python/dispatch overhead gap
between standard and persistent; the network projection for cluster scales
comes from ``repro.core.model_comm`` (benchmarks/fig*.py).

Strategies are resolved through the registry in
:mod:`repro.stencil.strategies`; ``comb_measure`` accepts either names or
fully-typed :class:`~repro.stencil.strategies.StrategyConfig` values, so a
newly registered strategy is benchmarkable without touching this module.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.stencil.domain import Domain
from repro.stencil.strategies import (
    ExchangeStrategy,
    StrategyConfig,
    make_driver,
)


def _mean_checksum(x: jax.Array) -> float:
    """Mean of the (possibly multi-process) stored array, on every rank.

    On a ``jax.distributed`` grid op-by-op numpy conversion of a
    non-addressable global array is illegal; a jitted fully-replicated
    reduction gives every rank the identical scalar, so the cross-strategy
    divergence check below stays meaningful across processes.
    """
    if getattr(x, "is_fully_addressable", True):
        return float(np.asarray(jax.numpy.mean(x)))
    from jax.sharding import NamedSharding, PartitionSpec

    out = jax.jit(
        jax.numpy.mean,
        out_shardings=NamedSharding(x.sharding.mesh, PartitionSpec()),
    )(x)
    return float(np.asarray(out))


@dataclasses.dataclass
class CycleResult:
    strategy: str
    us_per_cycle: float
    init_us: float
    n_cycles: int
    repeats: int
    checksum: float
    n_parts: int = 1
    packer: str = "slice"
    transport: str = "ppermute"
    coalesce: bool = True
    #: process-to-node placement the mesh was built under (repro.launch.
    #: mapping) — the §VI mapping axis, stamped from the driver's config
    mapping: str = "row-major"
    #: collectives ONE step launches (coalescing's one-per-neighbor claim,
    #: verified against compiled HLO by tests/core/test_coalesce.py)
    collective_count: int | None = None
    #: persistent-plan amortization counters for THIS measurement's init
    #: (hits > 0 means setup was skipped — the paper's amortized case)
    plan_cache_inits: int = 0
    plan_cache_hits: int = 0
    #: time to re-derive the full static transport schedule (Message tables
    #: + WireLayout offsets) for the current topology — what an elastic
    #: re-mesh pays *besides* the recompile; static offsets keep it cheap
    replan_us: float = 0.0
    #: plans this measurement's cache dropped to a topology change (zero in
    #: a steady-state sweep; the elastic runner drives it up)
    plan_cache_invalidations: int = 0
    #: autotune provenance when the driver resolved an "auto" cell
    #: ("trace"/"trace-nearest"/"model"/"calibration"/"cache"); None for
    #: hand-pinned cells, whose strategy/packer/coalesce ARE the request
    selected_by: str | None = None
    #: the tuner's score for the chosen cell (recorded us for trace
    #: verdicts, modeled/probed us otherwise); None for pinned cells
    predicted_us: float | None = None
    #: wall time the in-situ calibration probes cost (0 when the verdict
    #: came from a trace, the model, or the persistent autotune cache)
    calibration_us: float = 0.0
    #: how membership churn was (or would be) recovered during this
    #: measurement: "none" for steady-state sweep cells, "relaunch" /
    #: "in-grid" when the elastic runner produced the record
    #: (repro.launch.elastic)
    recovery_mode: str = "none"
    #: total µs spent moving LIVE state onto grown meshes for rank JOINs
    #: (0.0 when no rank joined — every steady-state cell)
    join_us: float = 0.0
    #: ranks that kept their process + warm plan cache through the last
    #: membership change (0 in steady state and after any relaunch)
    warm_ranks: int = 0

    def record(self) -> dict:
        """Flat, json-serializable form (the BENCH_*.json row body)."""
        return dataclasses.asdict(self)


def run_cycles(
    driver: ExchangeStrategy,
    x: jax.Array,
    *,
    n_cycles: int = 50,
    warmup: int = 3,
    repeats: int = 3,
) -> CycleResult:
    """Time ``n_cycles`` exchange(+update) iterations, paper-style.

    ``init_us`` is the measured one-time setup (trace+lower+compile) and is
    only charged to strategies declaring ``amortizes_init`` (no-op inits
    would otherwise record timer noise).  The plan-cache hit/miss delta of
    this init and the step's scheduled collective count ride along in the
    result, so BENCH records can show the persistent-amortization and
    message-coalescing effects directly.
    """
    cache = driver.config.resolve_cache()
    hits0, inits0, invals0 = (
        (cache.stats.cache_hits, cache.stats.inits,
         cache.stats.invalidations) if cache else (0, 0, 0)
    )
    t0 = time.perf_counter()
    driver.init(x)
    init_us = (time.perf_counter() - t0) * 1e6
    if not driver.amortizes_init:
        init_us = 0.0
    if cache is not None:
        plan_hits = cache.stats.cache_hits - hits0
        plan_inits = cache.stats.inits - inits0
        plan_invals = cache.stats.invalidations - invals0
    else:  # private plan: one init when the strategy amortizes, never a hit
        plan_hits, plan_inits, plan_invals = 0, int(driver.amortizes_init), 0
    try:
        collective_count = driver.scheduled_collectives(x)
    except NotImplementedError:
        collective_count = None
    # the elastic re-plan cost: re-deriving the static Message/WireLayout
    # tables for this topology from scratch (table math only — no compile)
    t0 = time.perf_counter()
    driver.replan_tables(x)
    replan_us = (time.perf_counter() - t0) * 1e6

    for _ in range(warmup):
        x = driver.step(x)
    driver.wait(x)

    times = []
    for _ in range(repeats):
        driver.wait(x)  # the paper's pre-timing barrier
        t0 = time.perf_counter()
        for _ in range(n_cycles):
            x = driver.step(x)
        driver.wait(x)  # Waitall before stopping the clock
        times.append((time.perf_counter() - t0) / n_cycles * 1e6)
    checksum = _mean_checksum(x)
    return CycleResult(
        strategy=driver.strategy,
        us_per_cycle=float(np.mean(times)),
        init_us=init_us,
        n_cycles=n_cycles,
        repeats=repeats,
        checksum=checksum,
        n_parts=driver.n_parts,
        packer=driver.config.packer,
        transport=driver.config.transport,
        coalesce=driver.config.coalesce,
        mapping=driver.config.mapping,
        collective_count=collective_count,
        plan_cache_inits=plan_inits,
        plan_cache_hits=plan_hits,
        replan_us=replan_us,
        plan_cache_invalidations=plan_invals,
        # autotuned drivers expose their selection provenance; pinned
        # drivers have none (getattr: only AutoStrategy defines these)
        selected_by=getattr(driver, "selected_by", None),
        predicted_us=getattr(driver, "predicted_us", None),
        calibration_us=getattr(driver, "calibration_us", 0.0),
    )


def _as_config(
    strategy: str | StrategyConfig, default_n_parts: int
) -> StrategyConfig:
    if isinstance(strategy, StrategyConfig):
        return strategy
    if strategy == "auto":
        # the bare name opens every autotunable axis; pass an explicit
        # StrategyConfig to pin packer/coalesce while tuning the rest
        return StrategyConfig(name="auto", packer="auto", coalesce="auto")
    n_parts = default_n_parts if strategy == "partitioned" else 1
    return StrategyConfig(name=strategy, n_parts=n_parts)


def result_label(name: str, packer: str = "slice",
                 coalesce: bool = True) -> str:
    """The one definition of ``comb_measure``'s result-key convention:
    the strategy name, suffixed ``@packer`` for non-default packers (the
    §VI packing axis) and ``~uncoalesced`` for the coalesce-off baseline
    cells.  Callers resolving a measurement by name — e.g. the sweep's
    baseline lookup — must build the key through this."""
    label = name if packer == "slice" else f"{name}@{packer}"
    return label if coalesce else f"{label}~uncoalesced"


def comb_measure(
    domain: Domain,
    *,
    strategies: tuple[str | StrategyConfig, ...] = (
        "standard", "persistent", "partitioned",
    ),
    n_parts: int = 4,
    update_fn: Callable[[jax.Array], jax.Array] | None = None,
    n_cycles: int = 50,
    repeats: int = 3,
    seed: int = 0,
) -> dict[str, CycleResult]:
    """Measure all strategies on one domain; checksums must agree.

    ``n_parts`` is the default partition count applied to strategies named
    ``"partitioned"``; pass explicit :class:`StrategyConfig` values to pin
    per-strategy knobs (partition count, packer, plan-cache policy).
    Results are keyed by strategy name, suffixed ``@packer`` for non-default
    packers (the §VI packing axis); when the same key is swept more than
    once (e.g. partitioned at several partition counts) later entries get a
    ``name#pN`` key — and a ``#2``/``#3`` ordinal when name *and* partition
    count repeat — so no measurement is silently dropped.
    """
    results: dict[str, CycleResult] = {}
    for strategy in strategies:
        config = _as_config(strategy, n_parts)
        label = result_label(config.name, config.packer, config.coalesce)
        if label in results:
            label = f"{label}#p{config.n_parts}"
        if label in results:
            # same name AND same n_parts swept again (e.g. cache-policy
            # A/B runs): stable ordinal suffix instead of dropping either.
            base, n = label, 2
            while label in results:
                label = f"{base}#{n}"
                n += 1
        x = domain.random(seed)
        driver = make_driver(
            config,
            domain.mesh,
            domain.halo_spec,
            ndim=len(domain.global_interior),
            update_fn=update_fn,
        )
        results[label] = run_cycles(
            driver, x, n_cycles=n_cycles, repeats=repeats
        )
        driver.free()
    # divergence check, per pair: each comparison absorbs only the wire
    # tolerance of the two packers involved, so exact-vs-exact pairs keep
    # the tight historical 1e-3 guard even when lossy packers are swept.
    from repro.core.transport import get_packer

    def _wire_tol(res: CycleResult) -> tuple[float, float]:
        return get_packer(res.packer).wire_tolerance(domain.dtype)

    sums = {s: r.checksum for s, r in results.items()}
    ref_label, ref_res = next(iter(results.items()))
    ref = ref_res.checksum
    ref_rtol, ref_atol = _wire_tol(ref_res)
    for s, r in results.items():
        wr, wa = _wire_tol(r)
        rtol = max(1e-3, ref_rtol, wr)
        atol = max(1e-3, ref_atol, wa)
        assert abs(r.checksum - ref) < atol + rtol * abs(ref), (
            f"strategy {s} diverged from {ref_label}: {sums}"
        )
    return results


def speedup_vs_baseline(
    results: dict[str, CycleResult], baseline: str = "standard"
) -> dict[str, float]:
    """Per-strategy speedup multiplier vs the baseline (1.0 = parity)."""
    base = results[baseline].us_per_cycle
    return {s: base / r.us_per_cycle for s, r in results.items()}
