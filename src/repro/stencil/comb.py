"""Comb-style benchmark driver: barriered, multi-cycle halo-exchange timing.

Follows the paper's measurement protocol (§V): synchronize before timing, run
many exchange cycles, extract the average per-cycle cost, repeat the whole
measurement several times and average.  On this CPU container the *measured*
numbers capture real pack/update compute and the python/dispatch overhead gap
between standard and persistent; the network projection for cluster scales
comes from ``repro.core.model_comm`` (benchmarks/fig*.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.stencil.domain import Domain
from repro.stencil.exchange import ExchangeDriver


@dataclasses.dataclass
class CycleResult:
    strategy: str
    us_per_cycle: float
    init_us: float
    n_cycles: int
    repeats: int
    checksum: float


def run_cycles(
    driver: ExchangeDriver,
    x: jax.Array,
    *,
    n_cycles: int = 50,
    warmup: int = 3,
    repeats: int = 3,
) -> CycleResult:
    """Time ``n_cycles`` exchange(+update) iterations, paper-style."""
    init_us = 0.0
    if driver.strategy != "standard":
        t0 = time.perf_counter()
        driver.init(x)
        init_us = (time.perf_counter() - t0) * 1e6

    for _ in range(warmup):
        x = driver.step(x)
    driver.wait(x)

    times = []
    for _ in range(repeats):
        driver.wait(x)  # the paper's pre-timing barrier
        t0 = time.perf_counter()
        for _ in range(n_cycles):
            x = driver.step(x)
        driver.wait(x)  # Waitall before stopping the clock
        times.append((time.perf_counter() - t0) / n_cycles * 1e6)
    checksum = float(np.asarray(jax.numpy.mean(x)))
    return CycleResult(
        strategy=driver.strategy,
        us_per_cycle=float(np.mean(times)),
        init_us=init_us,
        n_cycles=n_cycles,
        repeats=repeats,
        checksum=checksum,
    )


def comb_measure(
    domain: Domain,
    *,
    strategies: tuple[str, ...] = ("standard", "persistent", "partitioned"),
    n_parts: int = 4,
    update_fn: Callable[[jax.Array], jax.Array] | None = None,
    n_cycles: int = 50,
    repeats: int = 3,
    seed: int = 0,
) -> dict[str, CycleResult]:
    """Measure all strategies on one domain; checksums must agree."""
    results: dict[str, CycleResult] = {}
    for strategy in strategies:
        x = domain.random(seed)
        driver = ExchangeDriver(
            domain.mesh,
            lambda s=strategy: domain.halo_spec(
                s, n_parts if s == "partitioned" else 1
            ),
            ndim=len(domain.global_interior),
            strategy=strategy,
            update_fn=update_fn,
        )
        results[strategy] = run_cycles(
            driver, x, n_cycles=n_cycles, repeats=repeats
        )
        driver.free()
    sums = {s: r.checksum for s, r in results.items()}
    ref = next(iter(sums.values()))
    for s, c in sums.items():
        assert abs(c - ref) < 1e-3 + 1e-3 * abs(ref), (
            f"strategy {s} diverged: {sums}"
        )
    return results
