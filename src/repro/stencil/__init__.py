from repro.stencil.domain import Domain, periodic_oracle_step, reference_exchange
from repro.stencil.exchange import ExchangeDriver
from repro.stencil.strategies import (
    ExchangeStrategy,
    StrategyConfig,
    available_strategies,
    get_strategy,
    make_driver,
    register_strategy,
)
from repro.stencil.comb import (
    CycleResult,
    comb_measure,
    result_label,
    run_cycles,
    speedup_vs_baseline,
)

_SWEEP_EXPORTS = ("SweepConfig", "run_sweep", "sweep_cells",
                  "write_bench_json", "read_bench_json")


def __getattr__(name):
    # lazy: `python -m repro.stencil.sweep` warns if the package body already
    # imported the submodule (runpy sys.modules check).
    if name in _SWEEP_EXPORTS:
        from repro.stencil import sweep

        return getattr(sweep, name)
    raise AttributeError(name)

__all__ = [
    "Domain", "periodic_oracle_step", "reference_exchange", "ExchangeDriver",
    "ExchangeStrategy", "StrategyConfig", "available_strategies",
    "get_strategy", "make_driver", "register_strategy",
    "CycleResult", "comb_measure", "result_label", "run_cycles",
    "speedup_vs_baseline",
    "SweepConfig", "run_sweep", "sweep_cells", "write_bench_json",
    "read_bench_json",
]
