from repro.stencil.domain import Domain, periodic_oracle_step
from repro.stencil.exchange import ExchangeDriver
from repro.stencil.comb import CycleResult, comb_measure, run_cycles

__all__ = [
    "Domain", "periodic_oracle_step", "ExchangeDriver",
    "CycleResult", "comb_measure", "run_cycles",
]
