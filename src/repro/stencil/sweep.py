"""The paper's §VI parameter study as a reproducible sweep subsystem.

The headline analysis of the paper sweeps *process count*, *thread count*
and *message size* over Comb's exchange strategies.  The JAX-port analogues
swept here:

* **virtual device count**  (process count)  — each device count runs in a
  fresh subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
  (the count is fixed at first jax init, so it cannot vary in-process);
* **partition count**       (thread count)   — ``StrategyConfig.n_parts``,
  the number of per-face partitions a partitioned exchange posts;
* **message size**          — the domain's face-slab bytes, varied through
  ``global_interior``;
* **packer**                — the registered transport-layer pack backend
  (``"slice"`` inline staging vs the ``"pallas"`` copy kernel,
  :mod:`repro.core.transport`), swept as a first-class dimension;
* **coalesce**              — wire-buffer message aggregation on/off
  (``StrategyConfig.coalesce``): one contiguous buffer and ONE composed
  collective per hop chain vs the historical per-message pipeline.  The
  uncoalesced first mode hosts the baseline cell.
* **mapping**               — the process-to-node placement
  (:mod:`repro.launch.mapping`): each swept mapping permutes rank placement
  onto the mesh coordinates before the cell's mesh is built (row-major /
  blocked / recursive-bisection), and every record carries the static
  hop-locality tally (``intra_node_sends`` / ``inter_node_sends`` under the
  cell's ``node_size`` ranks-per-node) so the wins show up in the tables,
  not just the timings.  The FIRST mapping hosts the baseline cell.

Each cell's records carry ``packer``, ``transport``, ``coalesce``,
``mapping``, ``node_size``, ``process_count``, ``is_multihost``, ``wire_bytes``,
``collective_count`` (what one step launches — the coalescing effect),
``plan_cache_inits``/``plan_cache_hits`` (the persistent-amortization
counters), and ``replan_us``/``plan_cache_invalidations`` (the elastic
re-planning axis: how long re-deriving the static Message/WireLayout
tables takes for the cell's topology, and how many cached plans a
topology change dropped — see :mod:`repro.launch.elastic`) fields.  The transport backend
(``"ppermute"`` in-process, ``"multihost"`` for multi-process meshes) is
one ``SweepConfig.transport`` knob, and the fan-out is per-*process grid*:
``--processes N`` (``SweepConfig.processes``) boots every device-count cell
as an N-rank ``jax.distributed`` grid through
:func:`repro.launch.stencil.launch_grid` — each rank pins ``n//N`` local
devices, all ranks run the same SPMD measurement, and rank 0 aggregates the
timings into the ordinary BENCH record schema.  Wire-compressed packers
(``bf16``, ``scaled-int8``) shrink ``wire_bytes`` relative to
``message_bytes`` — the compression axis ``fig_sweep`` renders.

Every cell measures all requested registered strategies via
:func:`repro.stencil.comb.comb_measure` and emits one flat record per
(strategy, cell) with the cell's speedup-vs-baseline — the exact quantity
behind the paper's "persistent up to 37% / partitioned up to 68%" numbers.
Records serialize to ``BENCH_<name>.json`` (a json list of row dicts), the
repo's benchmark interchange format.

In-process use (device count fixed to the current backend)::

    records = sweep_cells(SweepConfig(sizes=((64, 32),), part_counts=(1, 4)))

Full sweep (spawns one subprocess per device count)::

    PYTHONPATH=src python -m repro.stencil.sweep --out BENCH_stencil_sweep.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import subprocess
import sys
import warnings
from typing import Any, Sequence

SCHEMA_VERSION = 1

#: keys every sweep record carries (validated by tests/stencil/test_sweep.py)
RECORD_KEYS = (
    "bench", "schema_version", "strategy", "n_devices", "n_parts",
    "packer", "transport", "coalesce", "process_count", "is_multihost",
    "mapping", "node_size", "intra_node_sends", "inter_node_sends",
    "global_interior", "mesh_shape", "message_bytes", "wire_bytes",
    "us_per_cycle", "collective_count",
    "plan_cache_inits", "plan_cache_hits",
    "replan_us", "plan_cache_invalidations",
    "selected_by", "predicted_us", "calibration_us",
    "recovery_mode", "join_us", "warm_ranks",
    "init_us", "n_cycles", "repeats", "checksum", "speedup_vs_baseline",
)


def mesh_shape_for(
    n_devices: int, mesh_ndim: int, *, warn: bool = False
) -> tuple[int, ...]:
    """The cell's mesh shape: a 1-D row, or an ``(n/2, 2)`` torus when a
    2-D cell is requested and the device count allows one.

    A 2-D request the device count cannot honor (odd or prime counts)
    silently used to degrade to a 1×N row where no corner chains exist —
    coalescing then measures as a no-op without any trace of why.  With
    ``warn=True`` (the cell-construction sites) the degradation warns, and
    :func:`config_block` records the effective shapes so figures can
    annotate these cells.
    """
    if mesh_ndim == 2:
        if n_devices >= 4 and n_devices % 2 == 0:
            return (n_devices // 2, 2)
        if warn:
            warnings.warn(
                f"mesh_ndim=2 requested but {n_devices} device(s) cannot "
                f"form an (n/2, 2) torus; degrading to the 1-D mesh row "
                f"({n_devices},) — no corner/edge chains exist there, so "
                f"the coalesce axis measures as a no-op for this cell",
                RuntimeWarning,
                stacklevel=2,
            )
    return (n_devices,)


def _assert_decomposable(
    size: tuple[int, ...], mesh_shape: tuple[int, ...], halo: int, why: str
) -> None:
    """The one size-vs-mesh validity rule (config construction AND the
    in-process worker check use it — no drift)."""
    assert len(size) >= len(mesh_shape), (size, mesh_shape)
    for extent, k in zip(size, mesh_shape):
        assert extent % k == 0 and extent // k >= 3 * halo, (
            f"size {size} not decomposable over mesh {mesh_shape}; {why}"
        )


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """The §VI grid: device count x partition count x message/domain size."""

    device_counts: tuple[int, ...] = (2, 4, 8)
    part_counts: tuple[int, ...] = (1, 2, 4)
    #: global interior shapes; the first axis is decomposed over all devices.
    sizes: tuple[tuple[int, ...], ...] = ((32, 16), (64, 32))
    strategies: tuple[str, ...] = (
        "standard", "persistent", "partitioned", "fused", "overlap",
    )
    #: transport-layer pack backends to sweep (first entry hosts the baseline)
    packers: tuple[str, ...] = ("slice", "pallas")
    #: transport backend every cell's messages move through
    transport: str = "ppermute"
    #: wire-buffer coalescing modes to sweep; the FIRST entry hosts the
    #: baseline cell (default: uncoalesced baseline, then coalesced)
    coalesce_modes: tuple[bool, ...] = (False, True)
    #: process-to-node mappings to sweep (repro.launch.mapping registry);
    #: each mapping builds its own permuted mesh per cell.  The FIRST entry
    #: hosts the baseline cell every speedup is normalized against.
    mappings: tuple[str, ...] = ("row-major",)
    #: ranks (devices) per physical node for the hop-locality tally; 0 =
    #: derive via repro.launch.mapping.default_node_size (process-local
    #: device count on a real grid, a modeled 2-node split in-process)
    node_size: int = 0
    #: jax.distributed grid size per cell (1 = the historical in-process
    #: fan-out; >1 boots each device count as a real multi-process grid)
    processes: int = 1
    #: mesh dimensionality per cell: 1 = the paper's 1-D process row
    #: (historical); 2 = an (n/2, 2) torus decomposing the first two array
    #: axes — edges/corners exist, so wire-buffer coalescing has chains to
    #: merge (the smoke grid uses this)
    mesh_ndim: int = 1
    baseline: str = "standard"
    halo: int = 1
    n_cycles: int = 20
    repeats: int = 2
    seed: int = 0

    def __post_init__(self):
        assert self.baseline in self.strategies, (
            f"baseline {self.baseline!r} must be swept"
        )
        # the baseline denominator must be a deterministic static cell —
        # an autotuned baseline would normalize every speedup against a
        # moving target
        assert self.baseline != "auto", "baseline cannot be autotuned"
        assert self.packers, "at least one packer must be swept"
        assert self.coalesce_modes, "at least one coalesce mode must be swept"
        assert all(isinstance(c, bool) for c in self.coalesce_modes), (
            self.coalesce_modes
        )
        assert len(set(self.coalesce_modes)) == len(self.coalesce_modes), (
            self.coalesce_modes
        )
        assert self.processes >= 1, self.processes
        assert self.node_size >= 0, self.node_size
        assert self.mappings, "at least one mapping must be swept"
        # fail at construction, not minutes later in a worker subprocess
        from repro.core.transport import get_packer, get_transport
        from repro.launch.mapping import canonical_mapping

        canon = tuple(canonical_mapping(m) for m in self.mappings)
        assert len(set(canon)) == len(canon), (
            f"duplicate mapping cells after alias resolution: {self.mappings}"
        )
        object.__setattr__(self, "mappings", canon)
        for p in self.packers:
            get_packer(p)
        get_transport(self.transport)
        assert self.mesh_ndim in (1, 2), self.mesh_ndim
        for n in self.device_counts:
            assert n % self.processes == 0, (
                f"device count {n} not divisible into {self.processes} "
                f"process ranks"
            )
            for size in self.sizes:
                _assert_decomposable(
                    size, mesh_shape_for(n, self.mesh_ndim), self.halo,
                    f"device count {n}",
                )

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, text: str) -> "SweepConfig":
        raw = json.loads(text)
        raw["device_counts"] = tuple(raw["device_counts"])
        raw["part_counts"] = tuple(raw["part_counts"])
        raw["sizes"] = tuple(tuple(s) for s in raw["sizes"])
        raw["strategies"] = tuple(raw["strategies"])
        raw["packers"] = tuple(raw.get("packers", ("slice",)))
        # pre-coalescing config jsons ran the historical uncoalesced path
        # on 1-D mesh rows
        raw["coalesce_modes"] = tuple(
            bool(c) for c in raw.get("coalesce_modes", (False,))
        )
        raw.setdefault("mesh_ndim", 1)
        # pre-mapping config jsons ran the identity placement
        raw["mappings"] = tuple(raw.get("mappings", ("row-major",)))
        raw.setdefault("node_size", 0)
        return cls(**raw)


def _size_records(
    config: SweepConfig, size: tuple[int, ...], n_devices: int
) -> list[dict]:
    """Measure one (device count, size) slab: non-partitioning strategies
    once per packer, partitioning strategies once per (partition count,
    packer), each mapping on its own permuted mesh, all against the same
    baseline run — the first mapping's first-packer first-mode baseline
    strategy — so the packing, coalescing AND placement axes show up in
    the speedup, not as a moving denominator."""
    import jax
    import numpy as _np

    from repro.core.compat import make_mesh
    from repro.core.transport import get_packer, schedule_locality
    from repro.launch.mapping import default_node_size, get_mapping
    from repro.stencil.comb import comb_measure, result_label
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import (
        StrategyConfig,
        get_strategy,
        make_driver,
    )

    mesh_shape = mesh_shape_for(n_devices, config.mesh_ndim, warn=True)
    axis_names = ("px", "py")[: len(mesh_shape)]
    axis_sizes = dict(zip(axis_names, mesh_shape))
    node_size = config.node_size or default_node_size(
        n_devices, jax.process_count()
    )
    n_proc = jax.process_count()
    base_us: float | None = None
    # Message tables are a pure function of (strategy, n_parts, shape,
    # spec) — identical across mappings (test_replan_purity asserts this)
    # — so the hop tables are derived once per (strategy, n_parts) and
    # re-classified under each mapping's node vector.
    groups_cache: dict[tuple[str, int], tuple] = {}
    records: list[dict] = []
    for mapping in config.mappings:
        placed = get_mapping(mapping).permute_devices(
            jax.devices()[:n_devices], mesh_shape, node_size
        )
        mesh = make_mesh(mesh_shape, axis_names, devices=placed)
        domain = Domain(
            mesh,
            global_interior=tuple(size),
            mesh_axes=axis_names + (None,) * (len(size) - len(mesh_shape)),
            halo=config.halo,
        )
        strat_configs = []
        for coalesce in config.coalesce_modes:
            for packer in config.packers:
                knobs = dict(packer=packer, transport=config.transport,
                             coalesce=coalesce, mapping=mapping)
                for s in config.strategies:
                    if s == "auto":
                        continue  # one tuned cell per mapping, added below
                    if get_strategy(s).uses_partitions:
                        strat_configs.extend(
                            StrategyConfig(name=s, n_parts=p, **knobs)
                            for p in config.part_counts
                        )
                    else:
                        # the partition-count axis does not apply: once per
                        # (packer, coalesce mode)
                        strat_configs.append(StrategyConfig(name=s, **knobs))
        if "auto" in config.strategies:
            # the autotuned cell: ONE per mapping — the tuner owns the
            # strategy/packer/coalesce/partition axes, so the static
            # packer x coalesce grid does not multiply it
            strat_configs.append(StrategyConfig(
                name="auto", packer="auto", coalesce="auto",
                transport=config.transport, mapping=mapping,
            ))
        results = comb_measure(
            domain,
            strategies=tuple(strat_configs),
            n_cycles=config.n_cycles,
            repeats=config.repeats,
            seed=config.seed,
        )
        if base_us is None:
            base_us = results[
                result_label(config.baseline, config.packers[0],
                             config.coalesce_modes[0])
            ].us_per_cycle
        node_of = get_mapping(mapping).node_of(mesh_shape, node_size)
        example = jax.ShapeDtypeStruct(
            domain.stored_global, _np.dtype(domain.dtype)
        )
        message_bytes = domain.max_face_bytes()
        face_elems = message_bytes // _np.dtype(domain.dtype).itemsize
        for label, res in results.items():
            key = (res.strategy, res.n_parts)
            if key not in groups_cache:
                drv = make_driver(
                    StrategyConfig(name=res.strategy, n_parts=res.n_parts),
                    domain.mesh, domain.halo_spec, ndim=len(size),
                )
                groups_cache[key] = drv.replan_tables(example)[0]
            loc = schedule_locality(
                groups_cache[key], axis_order=axis_names,
                axis_sizes=axis_sizes, node_of=node_of,
            )
            rec = {
                "bench": "stencil_sweep",
                "schema_version": SCHEMA_VERSION,
                "n_devices": n_devices,
                "process_count": n_proc,
                "is_multihost": n_proc > 1,
                "node_size": node_size,
                "intra_node_sends": loc.intra_sends,
                "inter_node_sends": loc.inter_sends,
                "global_interior": list(size),
                "mesh_shape": list(mesh_shape),
                "message_bytes": message_bytes,
                # what the face actually costs on the wire under this
                # record's packer (compressed packers shrink it)
                "wire_bytes": face_elems
                * get_packer(res.packer).wire_itemsize(domain.dtype),
                "speedup_vs_baseline": base_us / res.us_per_cycle,
                **res.record(),
            }
            records.append(rec)
    return records


def sweep_cells(
    config: SweepConfig, *, n_devices: int | None = None
) -> list[dict]:
    """Run the partition-count x size grid on the current process's devices.

    This is the in-process entry (one device count — the one jax booted
    with); :func:`run_sweep` fans the device-count axis out to subprocesses.
    """
    import jax

    n = n_devices or min(max(config.device_counts), len(jax.devices()))
    assert n <= len(jax.devices()), (n, len(jax.devices()))
    for size in config.sizes:
        _assert_decomposable(
            size, mesh_shape_for(n, config.mesh_ndim), config.halo,
            "this process's device count; pass n_devices= explicitly",
        )
    records = []
    for size in config.sizes:
        records.extend(_size_records(config, size, n))
    return records


# ---------------------------------------------------------------------------
# subprocess fan-out over the device-count axis
# ---------------------------------------------------------------------------


def _worker_env(n_devices: int) -> dict[str, str]:
    # the ONE worker-environment recipe (device pin + PYTHONPATH) lives
    # with the launch harness; no coordinator -> plain single-process env.
    from repro.launch.stencil import worker_env

    return worker_env(local_devices=n_devices)


def run_sweep(config: SweepConfig, *, timeout: float = 1200.0) -> list[dict]:
    """The full §VI grid: one worker run per device count (the device-count
    flag must precede jax init), each emitting its cells' records as json
    on stdout.

    With ``config.processes == 1`` each device count is one fresh
    subprocess (the historical in-process fan-out).  With ``processes > 1``
    each device count boots as a real N-rank ``jax.distributed`` grid via
    :func:`repro.launch.stencil.launch_grid`: every rank pins ``n // N``
    local devices, the same worker entry point runs SPMD on the global
    mesh, and only rank 0 prints the aggregated records.
    """
    records: list[dict] = []
    for n in config.device_counts:
        sub = dataclasses.replace(config, device_counts=(n,))
        argv = [sys.executable, "-m", "repro.stencil.sweep",
                "--worker", sub.to_json()]
        if config.processes > 1:
            from repro.launch.stencil import launch_grid

            stdout = launch_grid(
                argv, processes=config.processes,
                local_devices=n // config.processes, timeout=timeout,
            )
        else:
            out = subprocess.run(
                argv, env=_worker_env(n), capture_output=True, text=True,
                timeout=timeout,
            )
            if out.returncode != 0:
                raise RuntimeError(
                    f"sweep worker ({n} devices) failed:\n{out.stderr[-4000:]}"
                )
            stdout = out.stdout
        records.extend(json.loads(stdout))
    return records


def is_bench_path(path: str) -> bool:
    """The one definition of the ``BENCH_*.json`` naming rule."""
    base = os.path.basename(path)
    return base.startswith("BENCH_") and base.endswith(".json")


def write_bench_json(
    records: Sequence[dict], path: str, *, config: dict | None = None
) -> None:
    """Serialize records to the repo's ``BENCH_*.json`` interchange format.

    Without ``config`` the file is the historical bare list of row dicts;
    with it, records are wrapped as ``{"config": ..., "records": [...]}``
    so the run's parameters (grid, packers, transport, subprocess timeout)
    travel with the measurements.  :func:`read_bench_json` accepts both.
    """
    assert is_bench_path(path), path
    payload: Any = (
        list(records) if config is None
        else {"config": config, "records": list(records)}
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def read_bench_json(path: str) -> tuple[list[dict], dict | None]:
    """Load a ``BENCH_*.json`` file: (records, config-block-or-None).

    Malformed payloads raise :class:`ValueError` naming the file and the
    shape mismatch — not a bare ``KeyError`` from deep inside a consumer
    (the regression guard's historical failure mode on stale baselines).
    """
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict):
        if "records" not in payload:
            raise ValueError(
                f"{path}: BENCH dict payload has no 'records' key (top-level"
                f" keys: {sorted(payload)}); expected the bare record list "
                f"or the {{'config': ..., 'records': [...]}} wrapper — the "
                f"file is not a BENCH interchange artifact"
            )
        return list(payload["records"]), payload.get("config")
    if not isinstance(payload, list):
        raise ValueError(
            f"{path}: BENCH payload must be a json list or dict, got "
            f"{type(payload).__name__}"
        )
    return list(payload), None


def summarize(records: Sequence[dict]) -> list[str]:
    """csv rows (name,us,derived) matching benchmarks/run.py's emit format.

    The name carries the full cell coordinate including the PR 7 mapping
    axis; the derived column carries the locality tally
    (``intra=``/``inter=`` node sends) and, for autotuned records, the
    selection provenance — an ``auto:`` tag also prefixes the resolved
    strategy so a tuned cell never collides with the identical static one.
    """
    rows = []
    for r in records:
        tag = "auto:" if r.get("selected_by") else ""
        name = (f"sweep/d{r['n_devices']}/p{r['n_parts']}"
                f"/m{r['message_bytes']}/{r.get('packer', 'slice')}"
                f"/c{int(bool(r.get('coalesce', False)))}"
                f"/{r.get('mapping', 'row-major')}"
                f"/{tag}{r['strategy']}")
        pct = (r["speedup_vs_baseline"] - 1.0) * 100.0
        derived = (f"speedup={pct:.1f}%;init_us={r['init_us']:.0f};"
                   f"replan_us={r.get('replan_us', 0.0):.0f}")
        if "intra_node_sends" in r or "inter_node_sends" in r:
            derived += (f";intra={r.get('intra_node_sends', 0)}"
                        f";inter={r.get('inter_node_sends', 0)}")
        if r.get("selected_by"):
            derived += f";selected_by={r['selected_by']}"
        rows.append(f"{name},{r['us_per_cycle']:.1f},{derived}")
    return rows


def regression_failures(
    baseline_records: Sequence[dict],
    records: Sequence[dict],
    *,
    threshold: float = 0.25,
) -> list[str]:
    """Compare a fresh sweep against a committed baseline sweep.

    Per *strategy* present in BOTH record sets, the best
    ``speedup_vs_baseline`` across all its cells must not fall more than
    ``threshold`` below the committed best.  Speedups (not absolute
    microseconds) are compared, so the guard survives CI machines of
    different speeds; keying by strategy (not per-cell coordinate) keeps
    the max over ~a dozen cells, whose run-to-run noise is far below any
    single tiny cell's — single-cell jitter on the 3-cycle smoke grid
    exceeds 25%, so a finer key would flash red on identical code.  Only
    ``speedup_vs_baseline`` is compared: newer record fields (e.g. the
    ``replan_us`` re-plan latency or ``plan_cache_invalidations``) are
    tolerated in either record set and simply travel along — a baseline
    written before a field existed never trips the guard.  The
    check is only meaningful when both runs swept comparable grids (CI
    runs it on the full-matrix smoke job, never the restricted ``--packer``
    cells).  Returns human-readable failure lines (empty = pass).

    Autotuned records (``selected_by`` set) are NOT keyed by their resolved
    strategy name — that would let a ``strategy=auto`` sweep satisfy the
    guard by merely resolving to the same names.  They pool under one
    ``auto`` key whose best speedup must clear the committed autotuned best
    when the baseline carries one, else the committed *best static* cell —
    the tuner's whole contract is matching the static oracle, so falling
    ``threshold`` below it is a selection regression even if every static
    path is healthy.

    A record missing the two keys the guard actually reads (``strategy``,
    ``speedup_vs_baseline``) raises :class:`ValueError` naming the record
    and the likely cause (a baseline predating the schema), instead of the
    historical bare ``KeyError``.
    """

    def best(recs: Sequence[dict], which: str) -> tuple[
        dict[str, float], float | None
    ]:
        """(per-strategy best of the STATIC records, best autotuned-or-None)."""
        static: dict[str, float] = {}
        auto: float | None = None
        for i, r in enumerate(recs):
            for key in ("strategy", "speedup_vs_baseline"):
                if key not in r:
                    raise ValueError(
                        f"{which} record {i} is missing {key!r} "
                        f"(schema_version={r.get('schema_version')!r}): the "
                        f"file likely predates the current record schema — "
                        f"regenerate it with `python -m repro.stencil.sweep "
                        f"--smoke --out BENCH_stencil_sweep.json`"
                    )
            if r.get("selected_by"):
                auto = max(r["speedup_vs_baseline"],
                           auto if auto is not None else 0.0)
            else:
                static[r["strategy"]] = max(r["speedup_vs_baseline"],
                                            static.get(r["strategy"], 0.0))
        return static, auto

    old, old_auto = best(baseline_records, "baseline")
    new, new_auto = best(records, "fresh-sweep")
    fails = []
    if new_auto is not None:
        if old_auto is not None:
            ref, ref_label = old_auto, "committed autotuned best"
        elif old:
            ref = max(old.values())
            ref_label = "committed best static cell"
        else:
            raise ValueError(
                "fresh sweep carries autotuned records but the baseline has "
                "no records to floor them against — the baseline predates "
                "the autotune schema; regenerate it with `python -m "
                "repro.stencil.sweep --smoke --out BENCH_stencil_sweep.json`"
            )
        floor = ref * (1.0 - threshold)
        if new_auto < floor:
            fails.append(
                f"auto: best autotuned speedup {new_auto:.3f} fell below "
                f"{floor:.3f} ({ref_label} {ref:.3f}, threshold "
                f"{threshold:.0%})"
            )
    compared_auto = new_auto is not None
    if (old or new) and not set(old) & set(new) and not compared_auto:
        raise ValueError(
            f"no strategy appears in BOTH record sets (baseline strategies "
            f"{sorted(old)}, fresh {sorted(new)}): the sweeps are not "
            f"comparable — a stale baseline or mismatched grids would make "
            f"this guard silently vacuous"
        )
    for strategy in sorted(set(old) & set(new)):
        floor = old[strategy] * (1.0 - threshold)
        if new[strategy] < floor:
            fails.append(
                f"{strategy}: best speedup {new[strategy]:.3f} fell below "
                f"{floor:.3f} (committed {old[strategy]:.3f}, threshold "
                f"{threshold:.0%})"
            )
    return fails


def check_against_baseline(
    records: Sequence[dict], baseline_path: str, *, threshold: float = 0.25
) -> list[str]:
    """CLI helper: load the committed BENCH baseline and diff ``records``."""
    baseline_records, _config = read_bench_json(baseline_path)
    return regression_failures(baseline_records, records,
                               threshold=threshold)


def smoke_config(
    n_devices: int = 4,
    packers: tuple[str, ...] | None = None,
    coalesce_modes: tuple[bool, ...] | None = None,
    mappings: tuple[str, ...] | None = None,
    strategies: tuple[str, ...] | None = None,
) -> SweepConfig:
    """A 1-cell grid over ALL registered strategies x ALL registered
    packers (incl. the wire-compressed ones) x both coalesce modes x two
    process-to-node mappings (row-major baseline + blocked) — the
    CI ``sweep-smoke`` step: any strategy, packer, coalesce, or placement
    path whose exchange regresses (crashes, diverges, loses its speedup
    record) surfaces here in seconds.

    The decomposed extent scales with the device count (4 cells per
    shard), so the smoke grid stays valid at any ``--processes`` fan-out
    — the face (message) size is along the decomposed axis and does not
    change with it.
    """
    from repro.core.transport import available_packers
    from repro.stencil.strategies import available_strategies

    return SweepConfig(
        device_counts=(n_devices,), part_counts=(1, 2),
        sizes=((4 * n_devices, 8),),
        strategies=(
            tuple(available_strategies()) if strategies is None
            else strategies
        ),
        n_cycles=3, repeats=1,
        packers=available_packers() if packers is None else packers,
        coalesce_modes=(
            (False, True) if coalesce_modes is None else coalesce_modes
        ),
        # row-major hosts the baseline; blocked exercises a genuinely
        # permuted mesh (on the (2, 2) torus its node vector differs)
        mappings=(
            ("row-major", "blocked") if mappings is None else mappings
        ),
        # a 2-D (n/2, 2) torus: edges/corners exist, so the coalesce axis
        # has hop chains to merge (3 vs 12 collectives for a fused cell)
        mesh_ndim=2,
    )


def config_block(
    config: SweepConfig,
    *,
    timeout: float,
    smoke: bool = False,
    processes: int | None = None,
) -> dict:
    """The BENCH config block: the full grid + run parameters (incl. the
    subprocess ``timeout``) and runtime provenance, so a recorded sweep is
    re-runnable as-is.  The one schema for every writer (this CLI and
    ``benchmarks.run``).

    ``processes`` is the per-cell grid size the records were measured
    under; it defaults to this process's own ``jax.process_count()`` —
    callers writing on behalf of a spawned grid (the ``--processes``
    fan-out, whose launcher never joins the grid) must pass the real
    count.
    """
    import jax

    n_proc = (max(config.processes, jax.process_count())
              if processes is None else processes)
    return {
        "sweep": dataclasses.asdict(config),
        "timeout": timeout,
        "smoke": smoke,
        "backend": jax.default_backend(),
        "process_count": n_proc,
        "is_multihost": n_proc > 1,
        # the mesh each device count ACTUALLY ran on (a 2-D request can
        # degrade to a 1-D row — see mesh_shape_for's warning)
        "effective_mesh_shapes": {
            str(n): list(mesh_shape_for(n, config.mesh_ndim))
            for n in config.device_counts
        },
    }


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", metavar="CONFIG_JSON",
                    help="(internal) run one device-count's cells in-process")
    ap.add_argument("--out", default="BENCH_stencil_sweep.json",
                    help="output path (must match BENCH_*.json)")
    ap.add_argument("--fast", action="store_true",
                    help="2-cell smoke grid instead of the full default grid")
    ap.add_argument("--smoke", action="store_true",
                    help="1-cell in-process grid over all registered "
                         "strategies x packers (no subprocess fan-out; CI "
                         "smoke)")
    ap.add_argument("--packer", metavar="NAME",
                    help="restrict the packer axis to ONE registered packer "
                         "(default: sweep the config's packers)")
    ap.add_argument("--coalesce", choices=("on", "off", "both"),
                    default="both",
                    help="restrict the wire-buffer coalescing axis "
                         "(default: sweep both modes; the uncoalesced cell "
                         "hosts the baseline when present)")
    ap.add_argument("--mapping", metavar="NAME",
                    help="restrict the process-to-node mapping axis to ONE "
                         "registered mapping (row-major|blocked|rb), or "
                         "'all' to sweep every registered mapping "
                         "(default: the config's mappings)")
    ap.add_argument("--strategy", metavar="NAMES",
                    help="comma list of strategies to sweep; 'all' = every "
                         "registered strategy (the default), 'auto' = the "
                         "autotuned cell (repro.core.autotune picks the "
                         "best strategy x packer x coalesce per cell).  The "
                         "static baseline is always swept alongside, so "
                         "speedups keep their denominator")
    ap.add_argument("--autotune-trace", metavar="BENCH_JSON",
                    help="BENCH sweep the autotuner's trace-driven cost "
                         "model fits from (sets REPRO_AUTOTUNE_TRACE for "
                         "this run and every worker subprocess)")
    ap.add_argument("--autotune-cache", metavar="PATH",
                    help="persistent autotune calibration-verdict cache "
                         "(sets REPRO_AUTOTUNE_CACHE; default "
                         "~/.cache/repro/autotune.json)")
    ap.add_argument("--check", metavar="BENCH_JSON",
                    help="after the run, diff the records against this "
                         "committed BENCH baseline and exit non-zero if any "
                         "strategy's speedup regressed beyond the threshold")
    ap.add_argument("--check-threshold", type=float, default=0.25,
                    help="allowed fractional speedup regression for --check "
                         "(default 0.25)")
    ap.add_argument("--processes", type=int, default=1,
                    help="boot every device-count cell as an N-rank "
                         "jax.distributed grid (real multihost transport; "
                         "each rank pins devices/N local devices and rank 0 "
                         "aggregates the records)")
    ap.add_argument("--timeout", type=float, default=1200.0,
                    help="per-subprocess timeout (seconds) for the "
                         "device-count fan-out; recorded in the BENCH "
                         "config block")
    args = ap.parse_args(argv)

    if args.worker:
        # may be one rank of a --processes grid: join it before jax boots
        from repro.launch.stencil import maybe_initialize_from_env

        rank = maybe_initialize_from_env()
        config = SweepConfig.from_json(args.worker)
        import jax

        assert jax.process_count() == config.processes, (
            jax.process_count(), config.processes,
        )
        records = sweep_cells(config, n_devices=config.device_counts[0])
        if rank == 0:
            print(json.dumps(records))
        return

    if args.processes < 1:
        ap.error(f"--processes must be >= 1, got {args.processes}")

    if not is_bench_path(args.out):
        ap.error(f"--out must be named BENCH_*.json, got {args.out!r}")

    if args.packer:
        from repro.core.transport import available_packers

        if args.packer not in available_packers():
            ap.error(f"--packer must be one of {available_packers()}, "
                     f"got {args.packer!r}")

    coalesce_modes = {"on": (True,), "off": (False,), "both": None}[
        args.coalesce
    ]

    mappings: tuple[str, ...] | None = None
    if args.mapping:
        from repro.launch.mapping import available_mappings, canonical_mapping

        if args.mapping == "all":
            mappings = available_mappings()
        else:
            try:
                mappings = (canonical_mapping(args.mapping),)
            except KeyError as e:
                ap.error(str(e.args[0]) if e.args else str(e))

    # the autotuner's inputs travel by env var so worker subprocesses (which
    # copy os.environ) resolve "auto" cells from the same trace and share
    # the same persistent calibration cache
    if args.autotune_trace:
        os.environ["REPRO_AUTOTUNE_TRACE"] = args.autotune_trace
    if args.autotune_cache:
        os.environ["REPRO_AUTOTUNE_CACHE"] = args.autotune_cache

    strategies: tuple[str, ...] | None = None
    if args.strategy and args.strategy != "all":
        from repro.stencil.strategies import available_strategies

        names = tuple(s.strip() for s in args.strategy.split(",") if s.strip())
        for s in names:
            if s != "auto" and s not in available_strategies():
                ap.error(
                    f"--strategy must name registered strategies "
                    f"{available_strategies()} or 'auto', got {s!r}"
                )
        # the static baseline always rides along: every record's speedup is
        # normalized against it, and the guard's auto-vs-best-static floor
        # needs at least one static cell
        baseline = SweepConfig.__dataclass_fields__["baseline"].default
        strategies = tuple(dict.fromkeys((baseline,) + names))

    def maybe_check(records) -> None:
        if not args.check:
            return
        fails = check_against_baseline(records, args.check,
                                       threshold=args.check_threshold)
        if fails:
            for line in fails:
                print(f"REGRESSION: {line}", file=sys.stderr)
            raise SystemExit(1)
        print(f"# regression check vs {args.check}: ok")

    if args.smoke:
        if args.processes > 1:
            # a real grid cannot be joined from this already-running
            # process: spawn the 1-cell smoke as an N-rank worker grid
            # (2 local devices per rank) through the multihost transport.
            config = smoke_config(
                2 * args.processes,
                packers=(args.packer,) if args.packer else None,
                coalesce_modes=coalesce_modes,
                mappings=mappings,
                strategies=strategies,
            )
            config = dataclasses.replace(
                config, processes=args.processes, transport="multihost",
            )
            records = run_sweep(config, timeout=args.timeout)
        else:
            # in-process: the device count must be pinned before jax
            # initializes.  An already-exported pin (a common local
            # setting) is honored — the smoke grid runs at that count —
            # rather than silently fighting the env and tripping a
            # device-count mismatch.
            pin = re.search(
                r"--xla_force_host_platform_device_count=(\d+)",
                os.environ.get("XLA_FLAGS", ""),
            )
            n = int(pin.group(1)) if pin else 4
            if pin is None:
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "")
                    + f" --xla_force_host_platform_device_count={n}"
                ).strip()
            config = smoke_config(
                n, packers=(args.packer,) if args.packer else None,
                coalesce_modes=coalesce_modes,
                mappings=mappings,
                strategies=strategies,
            )
            records = sweep_cells(config, n_devices=n)
        write_bench_json(
            records, args.out,
            config=config_block(config, timeout=args.timeout, smoke=True,
                                processes=args.processes),
        )
        for row in summarize(records):
            print(row)
        print(f"# smoke: {len(records)} records -> {args.out}")
        maybe_check(records)
        return

    config = SweepConfig()
    if args.fast:
        config = dataclasses.replace(
            config, device_counts=(2, 4), part_counts=(1, 2), sizes=((32, 16),)
        )
    if args.packer:
        config = dataclasses.replace(config, packers=(args.packer,))
    if coalesce_modes is not None:
        config = dataclasses.replace(config, coalesce_modes=coalesce_modes)
    if mappings is not None:
        config = dataclasses.replace(config, mappings=mappings)
    if strategies is not None:
        config = dataclasses.replace(config, strategies=strategies)
    if args.processes > 1:
        config = dataclasses.replace(
            config, processes=args.processes, transport="multihost",
        )
    records = run_sweep(config, timeout=args.timeout)
    write_bench_json(records, args.out,
                     config=config_block(config, timeout=args.timeout,
                                         processes=args.processes))
    for row in summarize(records):
        print(row)
    print(f"# wrote {len(records)} records -> {args.out}")
    maybe_check(records)


if __name__ == "__main__":
    main()
