"""Cartesian domain decomposition for stencil workloads (Comb's mesh layer).

A :class:`Domain` splits a global interior mesh across named mesh axes; every
shard carries ghost rims of width ``halo`` on each decomposed axis.  The
*stored* global array is therefore ``(interior/procs + 2*halo) * procs`` per
decomposed axis — the per-shard ghosted block layout that
``repro.core.halo.exchange`` operates on.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.halo import HaloSpec, ghost_pspec


@dataclasses.dataclass(frozen=True)
class Domain:
    """A periodic structured mesh decomposed over ``mesh_axes``.

    ``global_interior[i]`` cells along array axis ``i``; axis ``i`` is
    decomposed over mesh axis ``mesh_axes[i]`` (None = not decomposed).
    """

    mesh: Mesh
    global_interior: tuple[int, ...]
    mesh_axes: tuple[str | None, ...]
    halo: int = 1
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.global_interior) == len(self.mesh_axes)
        for size, name in zip(self.global_interior, self.mesh_axes):
            if name is not None:
                procs = self.mesh.shape[name]
                assert size % procs == 0, (size, name, procs)
                assert size // procs >= self.halo, "shard thinner than halo"

    # -- geometry -----------------------------------------------------------
    @property
    def decomposed(self) -> list[tuple[int, str]]:
        return [
            (i, name) for i, name in enumerate(self.mesh_axes) if name is not None
        ]

    @property
    def local_interior(self) -> tuple[int, ...]:
        out = []
        for size, name in zip(self.global_interior, self.mesh_axes):
            out.append(size // self.mesh.shape[name] if name else size)
        return tuple(out)

    @property
    def local_ghosted(self) -> tuple[int, ...]:
        return tuple(
            s + (2 * self.halo if name else 0)
            for s, name in zip(self.local_interior, self.mesh_axes)
        )

    @property
    def stored_global(self) -> tuple[int, ...]:
        """Shape of the stored (ghost-carrying) global array."""
        out = []
        for s, name in zip(self.local_ghosted, self.mesh_axes):
            out.append(s * self.mesh.shape[name] if name else s)
        return tuple(out)

    def face_bytes(self) -> dict[str, int]:
        """Per decomposed mesh axis: bytes of one face message (the paper's
        *message size* axis — a full-extent ghost slab of width ``halo``)."""
        itemsize = np.dtype(self.dtype).itemsize
        out = {}
        for axis, name in self.decomposed:
            slab = 1
            for a, s in enumerate(self.local_ghosted):
                slab *= self.halo if a == axis else s
            out[name] = slab * itemsize
        return out

    def max_face_bytes(self) -> int:
        """Largest single face message — the sweep's message-size coordinate."""
        return max(self.face_bytes().values(), default=0)

    def pspec(self) -> P:
        return P(*self.mesh_axes)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec())

    def halo_spec(self, strategy: str = "standard", n_parts: int = 1) -> HaloSpec:
        idxs, names = [], []
        for i, name in self.decomposed:
            idxs.append(i)
            names.append(name)
        return HaloSpec(
            mesh_axes=tuple(names),
            array_axes=tuple(idxs),
            halo=self.halo,
            periodic=True,
            strategy=strategy,
            n_parts=n_parts,
        )

    # -- data ---------------------------------------------------------------
    def from_global_interior(self, interior: np.ndarray) -> jax.Array:
        """Scatter a dense global interior into the ghosted sharded layout
        (ghosts zeroed; call an exchange to fill them)."""
        assert interior.shape == self.global_interior, interior.shape
        h = self.halo
        blocks = interior
        # carve into per-shard blocks and pad each with ghost rims
        for axis, name in reversed(self.decomposed):
            procs = self.mesh.shape[name]
            pieces = np.split(blocks, procs, axis=axis)
            widths = [(0, 0)] * blocks.ndim
            widths[axis] = (h, h)
            pieces = [np.pad(p, widths) for p in pieces]
            blocks = np.concatenate(pieces, axis=axis)
        return jax.device_put(jnp.asarray(blocks, self.dtype), self.sharding())

    def to_global_interior(self, x: jax.Array) -> np.ndarray:
        """Strip ghosts and reassemble the dense global interior."""
        h = self.halo
        arr = np.asarray(x)
        for axis, name in self.decomposed:
            procs = self.mesh.shape[name]
            pieces = np.split(arr, procs, axis=axis)
            pieces = [
                p[tuple(
                    slice(h, -h) if a == axis else slice(None)
                    for a in range(p.ndim)
                )]
                for p in pieces
            ]
            arr = np.concatenate(pieces, axis=axis)
        return arr

    def random(self, seed: int = 0) -> jax.Array:
        rng = np.random.default_rng(seed)
        return self.from_global_interior(
            rng.normal(size=self.global_interior).astype(self.dtype)
        )


def periodic_oracle_step(interior: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """NumPy oracle: one 27-point (or 9-point in 2-D) periodic stencil update."""
    pad = np.pad(interior, 1, mode="wrap")
    out = np.zeros_like(interior, dtype=np.float32)
    ranges = [range(3)] * interior.ndim
    import itertools

    for offs in itertools.product(*ranges):
        sl = tuple(slice(o, o + s) for o, s in zip(offs, interior.shape))
        out += weights[offs].astype(np.float32) * pad[sl].astype(np.float32)
    return out.astype(interior.dtype)
