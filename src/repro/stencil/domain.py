"""Cartesian domain decomposition for stencil workloads (Comb's mesh layer).

A :class:`Domain` splits a global interior mesh across named mesh axes; every
shard carries ghost rims of width ``halo`` on each decomposed axis.  The
*stored* global array is therefore ``(interior/procs + 2*halo) * procs`` per
decomposed axis — the per-shard ghosted block layout that
``repro.core.halo.exchange`` operates on.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.halo import HaloSpec, ghost_pspec


@dataclasses.dataclass(frozen=True)
class Domain:
    """A periodic structured mesh decomposed over ``mesh_axes``.

    ``global_interior[i]`` cells along array axis ``i``; axis ``i`` is
    decomposed over mesh axis ``mesh_axes[i]`` (None = not decomposed).
    """

    mesh: Mesh
    global_interior: tuple[int, ...]
    mesh_axes: tuple[str | None, ...]
    halo: int = 1
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.global_interior) == len(self.mesh_axes)
        for size, name in zip(self.global_interior, self.mesh_axes):
            if name is not None:
                procs = self.mesh.shape[name]
                assert size % procs == 0, (size, name, procs)
                assert size // procs >= self.halo, "shard thinner than halo"

    # -- geometry -----------------------------------------------------------
    @property
    def decomposed(self) -> list[tuple[int, str]]:
        return [
            (i, name) for i, name in enumerate(self.mesh_axes) if name is not None
        ]

    @property
    def local_interior(self) -> tuple[int, ...]:
        out = []
        for size, name in zip(self.global_interior, self.mesh_axes):
            out.append(size // self.mesh.shape[name] if name else size)
        return tuple(out)

    @property
    def local_ghosted(self) -> tuple[int, ...]:
        return tuple(
            s + (2 * self.halo if name else 0)
            for s, name in zip(self.local_interior, self.mesh_axes)
        )

    @property
    def stored_global(self) -> tuple[int, ...]:
        """Shape of the stored (ghost-carrying) global array."""
        out = []
        for s, name in zip(self.local_ghosted, self.mesh_axes):
            out.append(s * self.mesh.shape[name] if name else s)
        return tuple(out)

    def face_bytes(self) -> dict[str, int]:
        """Per decomposed mesh axis: bytes of one face message (the paper's
        *message size* axis — a full-extent ghost slab of width ``halo``)."""
        itemsize = np.dtype(self.dtype).itemsize
        out = {}
        for axis, name in self.decomposed:
            slab = 1
            for a, s in enumerate(self.local_ghosted):
                slab *= self.halo if a == axis else s
            out[name] = slab * itemsize
        return out

    def max_face_bytes(self) -> int:
        """Largest single face message — the sweep's message-size coordinate."""
        return max(self.face_bytes().values(), default=0)

    def pspec(self) -> P:
        return P(*self.mesh_axes)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec())

    def halo_spec(self, strategy: str = "standard", n_parts: int = 1) -> HaloSpec:
        idxs, names = [], []
        for i, name in self.decomposed:
            idxs.append(i)
            names.append(name)
        return HaloSpec(
            mesh_axes=tuple(names),
            array_axes=tuple(idxs),
            halo=self.halo,
            periodic=True,
            strategy=strategy,
            n_parts=n_parts,
        )

    # -- data ---------------------------------------------------------------
    def from_global_interior(self, interior: np.ndarray) -> jax.Array:
        """Scatter a dense global interior into the ghosted sharded layout
        (ghosts zeroed; call an exchange to fill them).

        Works on multi-process meshes too: when this process cannot address
        every shard (a ``jax.distributed`` grid), each process contributes
        its addressable blocks via ``make_array_from_callback`` — every rank
        holds the same dense ``interior``, so the assembled global array is
        consistent without any cross-process data movement.
        """
        sharding = self.sharding()
        stored = self.stored_from_interior(interior)
        if not sharding.is_fully_addressable:
            return jax.make_array_from_callback(
                stored.shape, sharding, lambda idx: stored[idx]
            )
        return jax.device_put(jnp.asarray(stored), sharding)

    def stored_from_interior(self, interior: np.ndarray) -> np.ndarray:
        """Host-side stored (ghost-carrying) layout of a dense interior.

        The carve-and-pad is a pure function of this domain's decomposition,
        exposed separately so elastic JOINs can re-shard *live* state onto a
        grown mesh through :func:`repro.train.fault_tolerance.reshard_state`
        (stored layout here, placement there) instead of restoring a
        checkpoint through :meth:`from_global_interior`.
        """
        assert interior.shape == self.global_interior, interior.shape
        h = self.halo
        blocks = interior
        # carve into per-shard blocks and pad each with ghost rims
        for axis, name in reversed(self.decomposed):
            procs = self.mesh.shape[name]
            pieces = np.split(blocks, procs, axis=axis)
            widths = [(0, 0)] * blocks.ndim
            widths[axis] = (h, h)
            pieces = [np.pad(p, widths) for p in pieces]
            blocks = np.concatenate(pieces, axis=axis)
        return np.asarray(blocks, dtype=self.dtype)

    def to_global_interior(self, x: jax.Array) -> np.ndarray:
        """Strip ghosts and reassemble the dense global interior."""
        h = self.halo
        arr = np.asarray(x)
        for axis, name in self.decomposed:
            procs = self.mesh.shape[name]
            pieces = np.split(arr, procs, axis=axis)
            pieces = [
                p[tuple(
                    slice(h, -h) if a == axis else slice(None)
                    for a in range(p.ndim)
                )]
                for p in pieces
            ]
            arr = np.concatenate(pieces, axis=axis)
        return arr

    def random(self, seed: int = 0) -> jax.Array:
        rng = np.random.default_rng(seed)
        return self.from_global_interior(
            rng.normal(size=self.global_interior).astype(self.dtype)
        )


def reference_exchange(domain: Domain, interior: np.ndarray) -> np.ndarray:
    """Single-device reference roll: the exchanged stored layout, by gather.

    Along each decomposed axis (chunk ``c``, halo ``h``) shard ``i`` stores
    ``[ghost_l | interior | ghost_r]`` = global indices
    ``(i*c - h) .. (i*c + c + h)`` wrapped periodically; the full stored
    array is the tensor product of those per-axis index maps.  This is the
    correctness oracle every exchange strategy is held to — in-process
    (``tests/stencil/test_equivalence.py``) and across real processes
    (``tests/distributed_progs/check_multihost.py``), where each rank
    compares just its addressable shards against this dense prediction.
    """
    out = np.asarray(interior, dtype=domain.dtype)
    h = domain.halo
    for axis, name in domain.decomposed:
        k = domain.mesh.shape[name]
        g = interior.shape[axis]
        c = g // k
        idx = [
            (i * c + off - h) % g for i in range(k) for off in range(c + 2 * h)
        ]
        out = np.take(out, idx, axis=axis)
    return out


# ---------------------------------------------------------------------------
# interior/halo region split (the communication/computation-overlap schedule)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class UpdateRegion:
    """One piece of an interior/boundary-split stencil update.

    ``src`` is the (start, size) window of the local ghosted block fed to
    the update fn; ``out`` is the (start, size) window *within the piece's
    output* whose cells were validly updated; ``dst`` is where that window
    lands back in the block.  ``needs_fresh_ghosts`` says whether the piece
    must read the post-exchange buffer (boundary shell) or may read the
    pre-exchange one (deep interior — computable while messages fly).
    """

    src: tuple[tuple[int, int], ...]
    out: tuple[tuple[int, int], ...]
    dst: tuple[int, ...]
    needs_fresh_ghosts: bool

    @staticmethod
    def _window(x: jax.Array, win: tuple[tuple[int, int], ...]) -> jax.Array:
        return jax.lax.slice(
            x, [s for s, _ in win], [s + n for s, n in win]
        )

    def updated(self, block: jax.Array, update_fn) -> jax.Array:
        """Run ``update_fn`` on this piece's window; return the valid cells."""
        return self._window(update_fn(self._window(block, self.src)), self.out)


def interior_halo_split(
    shape: tuple[int, ...], array_axes: tuple[int, ...], halo: int
) -> tuple[UpdateRegion, ...]:
    """Split a local ghosted block into overlap-schedulable update pieces.

    The contract on the update fn is the stencil-workload one: a local,
    shift-invariant stencil of radius <= ``halo`` along each decomposed
    axis, writing positions at distance >= ``halo`` from the block edge on
    those axes and leaving the ``halo``-wide rim untouched (undecomposed
    axes are unconstrained — pieces always span their full extent).

    Under that contract, the *deep interior* piece (all decomposed-axis
    positions >= ``2*halo`` from the edge) reads only interior cells, so it
    is computable from the **pre-exchange** buffer concurrently with the
    boundary exchange; the two boundary-shell pieces per decomposed axis
    need the refreshed ghosts.  Piece outputs tile the full updatable
    region; where shells meet at edges/corners they recompute identical
    values, so unpack order is immaterial.
    """
    h = halo
    dec = set(array_axes)
    for a in dec:
        assert shape[a] >= 3 * h, (shape, a, h)
    regions: list[UpdateRegion] = []

    def full(a: int) -> tuple[int, int]:
        return (0, shape[a])

    # deep interior: feed the interior sub-block (all values locally valid)
    if all(shape[a] - 4 * h > 0 for a in dec):
        src = tuple(
            (h, shape[a] - 2 * h) if a in dec else full(a)
            for a in range(len(shape))
        )
        out = tuple(
            (h, shape[a] - 4 * h) if a in dec else full(a)
            for a in range(len(shape))
        )
        dst = tuple(2 * h if a in dec else 0 for a in range(len(shape)))
        regions.append(UpdateRegion(src, out, dst, needs_fresh_ghosts=False))

    # boundary shells: one 3h-thick slab per side of each decomposed axis
    for axis in array_axes:
        s = shape[axis]
        for lo in (True, False):
            src = tuple(
                ((0, 3 * h) if lo else (s - 3 * h, 3 * h)) if a == axis
                else full(a)
                for a in range(len(shape))
            )
            out = tuple(
                (h, h) if a == axis
                else ((h, shape[a] - 2 * h) if a in dec else full(a))
                for a in range(len(shape))
            )
            dst = tuple(
                ((h if lo else s - 2 * h) if a == axis
                 else (h if a in dec else 0))
                for a in range(len(shape))
            )
            regions.append(UpdateRegion(src, out, dst, needs_fresh_ghosts=True))
    return tuple(regions)


def overlapped_update(
    stale: jax.Array,
    fresh: jax.Array,
    update_fn: Callable[[jax.Array], jax.Array],
    *,
    array_axes: tuple[int, ...],
    halo: int,
) -> jax.Array:
    """Apply ``update_fn`` with the interior/boundary overlap schedule.

    ``stale`` is the pre-exchange buffer, ``fresh`` the post-exchange one
    (identical except for refreshed ghost rims).  The deep-interior piece
    reads ``stale`` — giving it no data dependency on the exchange's
    collectives, so XLA may compute it while messages are in flight — and
    the boundary shells read ``fresh``.  Equals ``update_fn(fresh)`` under
    the :func:`interior_halo_split` contract.
    """
    out = fresh
    for region in interior_halo_split(stale.shape, array_axes, halo):
        piece = region.updated(
            fresh if region.needs_fresh_ghosts else stale, update_fn
        )
        out = jax.lax.dynamic_update_slice(out, piece, region.dst)
    return out


def periodic_oracle_step(interior: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """NumPy oracle: one 27-point (or 9-point in 2-D) periodic stencil update."""
    pad = np.pad(interior, 1, mode="wrap")
    out = np.zeros_like(interior, dtype=np.float32)
    ranges = [range(3)] * interior.ndim
    import itertools

    for offs in itertools.product(*ranges):
        sl = tuple(slice(o, o + s) for o, s in zip(offs, interior.shape))
        out += weights[offs].astype(np.float32) * pad[sl].astype(np.float32)
    return out.astype(interior.dtype)
