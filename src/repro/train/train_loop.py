"""Training loop: step factory + fault-tolerant driver.

The train step is executed through a persistent plan (``repro.core.plan``) —
compile once at init, bare dispatch per iteration — exactly the paper's
persistent-communication lifecycle applied to the whole SPMD step.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig, RunConfig
from repro.core.plan import CommPlan
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models.api import Model
from repro.parallel import sharding as shd
from repro.parallel.context import LOCAL, ParallelContext
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (
    FailureInjector, SimulatedFailure, StragglerMonitor,
)
from repro.train.optimizer import adamw_update, compress_grads, init_opt_state

log = logging.getLogger("repro.train")

TrainState = dict  # {"params": ..., "opt": {...}}


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    ctx: ParallelContext = LOCAL,
                    microbatches: int = 1) -> Callable:
    """(state, batch) -> (state, metrics); pure, jit/AOT-compilable.

    ``microbatches > 1`` scans gradient accumulation over equal batch slices
    (accumulator dtype per ``model.cfg.grad_accum_dtype``), bounding the
    per-layer activation carry — the memory lever that lets grok-scale train
    cells fit 16 GB/chip (see configs/grok_1_314b.py).
    """
    accum_dtype = jnp.dtype(model.cfg.grad_accum_dtype)

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch, ctx=ctx))(params)

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatches <= 1:
            loss, grads = grad_fn(state["params"], batch)
        else:
            def split(x):
                y = x.reshape((microbatches, x.shape[0] // microbatches)
                              + x.shape[1:])
                if ctx.mesh is not None:
                    # keep the per-microbatch batch dim on the data axes —
                    # without this GSPMD may shard the microbatch dim instead
                    # and every microbatch gathers the others' rows.
                    from jax.sharding import NamedSharding, PartitionSpec as P

                    da = ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]
                    spec = P(None, da, *([None] * (y.ndim - 2)))
                    y = jax.lax.with_sharding_constraint(
                        y, NamedSharding(ctx.mesh, spec))
                return y

            micro = jax.tree.map(split, batch)
            params = state["params"]
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def accum(carry, mb):
                g_sum, l_sum = carry
                l, g = grad_fn(params, mb)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_sum, g)
                return (g_sum, l_sum + l), None

            (g_sum, l_sum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), g_sum,
                state["params"])
            loss = l_sum / microbatches
        grads = compress_grads(grads, opt_cfg.grad_compression)
        params, opt, metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics["loss"] = loss
        return {"params": params, "opt": opt}, metrics

    return step


def init_state(model: Model, opt_cfg: OptimizerConfig, key) -> TrainState:
    params = model.init(key)
    return {"params": params,
            "opt": init_opt_state(params, opt_cfg, model.cfg.opt_state_dtype)}


def state_pspecs(model: Model, state_shapes: TrainState, mesh,
                 ctx: ParallelContext) -> TrainState:
    """Sharding specs for a train state (params TP + ZeRO-1 moments)."""
    pspec = shd.param_pspecs(state_shapes["params"],
                             model_axis=ctx.model_axis or "model",
                             model_size=ctx.model_size)
    mspec = shd.zero1_pspecs(state_shapes["opt"]["m"],
                             shd.param_pspecs(state_shapes["opt"]["m"],
                                              model_axis=ctx.model_axis or "model",
                                              model_size=ctx.model_size),
                             data_axes=ctx.data_axes, mesh=mesh)
    from jax.sharding import PartitionSpec as P

    return {"params": pspec,
            "opt": {"m": mspec, "v": mspec, "step": P()}}


@dataclasses.dataclass
class TrainResult:
    steps_done: int
    losses: list
    restarts: int
    straggler_flags: int
    checksum: float


class Trainer:
    """Fault-tolerant training driver.

    init -> [restore latest checkpoint] -> prefetch -> persistent step plan ->
    loop { step; observe straggler; periodic async checkpoint; injected
    failures trigger restart-from-checkpoint }.
    """

    def __init__(self, model: Model, run_cfg: RunConfig,
                 ctx: ParallelContext = LOCAL,
                 injector: FailureInjector | None = None,
                 shardings: Any | None = None):
        self.model = model
        self.run_cfg = run_cfg
        self.ctx = ctx
        self.injector = injector or FailureInjector(enabled=False)
        self.monitor = StragglerMonitor(ewma=run_cfg.straggler_ewma,
                                        factor=run_cfg.straggler_factor)
        self.step_fn = make_train_step(model, run_cfg.optimizer, ctx)
        self.shardings = shardings
        self.checkpointer = (
            ckpt.AsyncCheckpointer(run_cfg.checkpoint_dir,
                                   keep=run_cfg.keep_checkpoints)
            if run_cfg.checkpoint_dir and run_cfg.async_checkpoint else None)
        self.restarts = 0

    # -- state ------------------------------------------------------------------
    def _fresh_state(self) -> tuple[TrainState, int]:
        state = init_state(self.model, self.run_cfg.optimizer,
                           jax.random.key(self.run_cfg.seed))
        return state, 0

    def _load_or_init(self) -> tuple[TrainState, int]:
        d = self.run_cfg.checkpoint_dir
        if self.run_cfg.resume and d and ckpt.latest_step(d) is not None:
            like = jax.eval_shape(
                lambda: init_state(self.model, self.run_cfg.optimizer,
                                   jax.random.key(self.run_cfg.seed)))
            state, step = ckpt.restore(d, like=like, shardings=self.shardings)
            log.info("restored checkpoint at step %d", step)
            return state, step
        return self._fresh_state()

    # -- loop -------------------------------------------------------------------
    def run(self) -> TrainResult:
        losses: list[float] = []
        while True:
            try:
                return self._run_once(losses)
            except SimulatedFailure as e:
                self.restarts += 1
                log.warning("%s -> restart %d", e, self.restarts)
                if self.restarts > 5:
                    raise

    def _run_once(self, losses: list) -> TrainResult:
        cfg = self.run_cfg
        state, start_step = self._load_or_init()
        dataset = SyntheticLM(self.model.cfg, cfg.shape.global_batch,
                              cfg.shape.seq_len, seed=cfg.seed)
        batch_sh = None
        if self.shardings is not None and "batch" in (self.shardings or {}):
            batch_sh = self.shardings["batch"]
        prefetch = Prefetcher(dataset, batch_sh, start_step=start_step)
        jitted = jax.jit(self.step_fn, donate_argnums=(0,))
        try:
            for step, batch in prefetch:
                if step >= cfg.steps:
                    break
                self.injector.check(step)
                t0 = time.perf_counter()
                state, metrics = jitted(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.monitor.observe(step, dt)
                losses.append(loss)
                if cfg.log_every and step % cfg.log_every == 0:
                    log.info("step %d loss %.4f (%.0f ms)", step, loss, dt * 1e3)
                if (cfg.checkpoint_dir and cfg.checkpoint_every
                        and (step + 1) % cfg.checkpoint_every == 0):
                    if self.checkpointer is not None:
                        self.checkpointer.save(state, step + 1)
                    else:
                        ckpt.save(state, cfg.checkpoint_dir, step + 1,
                                  keep=cfg.keep_checkpoints)
        finally:
            prefetch.stop()
        if self.checkpointer is not None:
            self.checkpointer.wait()
        checksum = float(jnp.mean(jax.tree.leaves(state["params"])[0]
                                  .astype(jnp.float32)))
        return TrainResult(
            steps_done=min(cfg.steps, cfg.steps),
            losses=losses,
            restarts=self.restarts,
            straggler_flags=len(self.monitor.flagged),
            checksum=checksum,
        )
