"""Fault tolerance: failure injection, restart-from-checkpoint, straggler
detection, elastic re-meshing.

The mechanisms are real (restart restores exact state and the loss trajectory
continues bit-for-bit — tested); the *failures* are injected, since this
container has no flaky NICs to offer.  On a real cluster the SimulatedFailure
hook is where a missed-heartbeat / ICI-error signal lands.
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
import zlib
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

log = logging.getLogger("repro.ft")


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / NIC flap / preemption."""


# ---------------------------------------------------------------------------
# heartbeat + epoch types (mechanism; policy lives in repro.launch.membership)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Heartbeat:
    """One liveness report: ``rank`` was alive at ``when`` (coordinator
    clock), optionally annotated with the step it was executing."""

    rank: int
    when: float
    step: int | None = None


@dataclasses.dataclass(frozen=True)
class EpochBump:
    """Why the grid moved to ``epoch``.

    ``cause`` is ``"form"`` (initial seal), ``"join"`` (a rank registered
    mid-run), or ``"loss"`` (missed heartbeats).  The epoch value is what
    gets stamped into :class:`~repro.core.transport.ScheduleInfo` /
    persistent plan keys so stale plans can never deliver into the
    re-formed mesh.
    """

    epoch: int
    cause: str

    def __post_init__(self):
        assert self.cause in ("form", "join", "loss"), self.cause


class HeartbeatLedger:
    """Last-beat table with a miss window — the detection half of in-grid
    recovery.  :class:`repro.launch.membership.MembershipService` drives
    one of these; it is separate so timeout logic is testable with a fake
    clock and no sockets."""

    def __init__(self, timeout: float):
        self.timeout = float(timeout)
        self._last: dict[int, Heartbeat] = {}

    def beat(self, rank: int, when: float, step: int | None = None) -> None:
        self._last[rank] = Heartbeat(rank=rank, when=when, step=step)

    def last(self, rank: int) -> Heartbeat | None:
        return self._last.get(rank)

    def missing(self, now: float) -> tuple[int, ...]:
        """Ranks whose last beat is older than the window, sorted."""
        return tuple(sorted(
            r for r, hb in self._last.items()
            if now - hb.when > self.timeout
        ))

    def evict(self, rank: int) -> bool:
        return self._last.pop(rank, None) is not None

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(sorted(self._last))

    def __contains__(self, rank: int) -> bool:
        return rank in self._last

    def __len__(self) -> int:
        return len(self._last)


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given steps (or with probability p).

    ``phases`` restricts firing to labeled chaos points: callers tag each
    :meth:`check` with where in the iteration it sits (e.g.
    ``"mid-exchange"``, ``"plan-build:round"`` — the elastic runner's
    adversarial injection points); with a non-empty ``phases`` only checks
    whose tag is listed may fire.  Every fire — deterministic *or*
    probabilistic — is recorded in ``_fired`` keyed by ``(step, phase)``,
    so a restart that replays the same step never refires: without the
    dedup the probability path is seeded by ``seed + step`` and a resumed
    run would deterministically hit the same failure forever.

    Transient phases (a JOIN window, a recovery barrier) must be tagged
    through :meth:`phase_scope`, not by threading the tag into every
    ``check`` call: the scope restores the previous tag on exit, so an
    injector armed for ``phases=("join",)`` can structurally never fire
    during steady-state steps of the grown grid — the "join" tag cannot
    outlive the window it names.  Inside a scope, untagged checks inherit
    the scoped phase; explicitly-tagged checks keep their own tag.
    """

    fail_at_steps: tuple[int, ...] = ()
    probability: float = 0.0
    seed: int = 0
    enabled: bool = True
    phases: tuple[str, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)
    _active_phase: str | None = dataclasses.field(default=None, repr=False)

    @contextlib.contextmanager
    def phase_scope(self, phase: str):
        """Tag every untagged ``check`` inside the block with ``phase``."""
        prev = self._active_phase
        self._active_phase = phase
        try:
            yield self
        finally:
            self._active_phase = prev

    def check(self, step: int, phase: str | None = None) -> None:
        if not self.enabled:
            return
        if phase is None:
            phase = self._active_phase
        if self.phases and phase not in self.phases:
            return
        key = (step, phase)
        if key in self._fired:
            return
        at = f"step {step}" + (f" ({phase})" if phase else "")
        if step in self.fail_at_steps:
            self._fired.add(key)
            raise SimulatedFailure(f"injected failure at {at}")
        if self.probability > 0:
            salt = zlib.crc32((phase or "").encode())
            rng = np.random.default_rng(self.seed + step + salt)
            if rng.random() < self.probability:
                self._fired.add(key)
                raise SimulatedFailure(f"random failure at {at}")


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than factor x the mean.

    Mitigation on a real cluster: evict/replace the slow host and re-mesh
    (see :func:`reshard_state`); here the monitor records flags and exposes
    a hook.
    """

    ewma: float = 0.9
    factor: float = 3.0
    _mean: float | None = None
    flagged: list = dataclasses.field(default_factory=list)
    on_straggler: Callable[[int, float, float], None] | None = None

    def observe(self, step: int, seconds: float) -> bool:
        is_straggler = False
        if self._mean is not None and seconds > self.factor * self._mean:
            self.flagged.append((step, seconds, self._mean))
            is_straggler = True
            if self.on_straggler:
                self.on_straggler(step, seconds, self._mean)
            # do not poison the mean with the outlier
        else:
            self._mean = (seconds if self._mean is None
                          else self.ewma * self._mean + (1 - self.ewma) * seconds)
        return is_straggler


def run_with_restarts(
    make_step_iter: Callable[[], Any],
    *,
    max_restarts: int = 3,
    on_restart: Callable[[int], None] | None = None,
) -> Any:
    """Drive an iterator of training steps, restarting on SimulatedFailure.

    ``make_step_iter`` must restore from the latest checkpoint when called
    again (the training loop owns that logic); this wrapper owns the retry
    policy and restart accounting.
    """
    restarts = 0
    while True:
        try:
            return make_step_iter()
        except SimulatedFailure as e:
            restarts += 1
            log.warning("failure: %s (restart %d/%d)", e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
            if on_restart:
                on_restart(restarts)


def reshard_state(state: Any, new_mesh: Mesh, new_pspecs: Any) -> Any:
    """Elastic re-mesh: move a state tree onto a different mesh/sharding.

    Works across data-parallel width changes (e.g. 8 -> 4 data shards after
    losing a pod slice): every leaf is fetched to host and re-placed with the
    new NamedSharding.  Multi-host note: with jax.distributed initialized the
    same code path uses resharding-in-place; the host hop is the
    single-process fallback.
    """
    def move(x, spec):
        return jax.device_put(np.asarray(x), NamedSharding(new_mesh, spec))

    return jax.tree.map(move, state, new_pspecs)
