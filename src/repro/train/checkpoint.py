"""Sharded, atomic, async checkpointing with manifest + checksums.

Layout:
    <dir>/step_<N>/
        manifest.json    tree structure, shapes, dtypes, crc32 per leaf
        leaf_<i>.npy     one array per tree leaf
        _COMMITTED       written last; an uncommitted dir is ignored/cleaned

Design notes for multi-host (exercised single-host here): each host writes
only the addressable shards of its leaves into ``leaf_<i>.host<H>.npy`` and
rank 0 writes the manifest; restore re-shards via ``jax.device_put`` with the
target sharding — which is also what elastic re-meshing uses
(``fault_tolerance.reshard_state``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib
from typing import Any

import jax
import ml_dtypes
import numpy as np

Params = Any
_COMMIT = "_COMMITTED"

# numpy cannot natively serialize bf16/fp8; store as a same-width uint view
# and record the logical dtype in the manifest.
_VIEW_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_numpy(leaf) -> tuple[np.ndarray, str]:
    dtype_name = str(leaf.dtype)
    arr = np.asarray(leaf)
    if dtype_name in _VIEW_DTYPES:
        arr = arr.view(_VIEW_DTYPES[dtype_name][1])
    return arr, dtype_name


def _from_numpy(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[dtype_name][0])
    return arr


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", "?"))))
        paths.append("/".join(parts))
    return paths


def save(state: Params, ckpt_dir: str, step: int, *, keep: int = 3) -> str:
    """Atomic synchronous save; returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "paths": _leaf_paths(state),
        "leaves": [],
        "time": time.time(),
    }
    for i, leaf in enumerate(leaves):
        arr, dtype_name = _to_numpy(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write(str(step))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: int) -> None:
    steps = sorted(committed_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def committed_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _COMMIT)):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def _tree_from_paths(paths: list[str], leaves: list) -> Params:
    """Rebuild a nested-dict tree from manifest leaf paths (``"a/b/c"``).

    This is the structure-free restore used by elastic resume: a process
    that replaces a dead rank knows the checkpoint *directory* but not the
    state's treedef.  Dict-of-dicts trees round-trip exactly; sequence
    nodes come back as dicts keyed by their stringified index (pass
    ``like`` when that distinction matters).
    """
    root: dict = {}
    for path, leaf in zip(paths, leaves):
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


def restore(ckpt_dir: str, step: int | None = None, *, like: Params = None,
            shardings: Any = None, verify: bool = True) -> tuple[Params, int]:
    """Load a checkpoint; optionally re-shard onto ``shardings`` (elastic).

    ``like`` supplies the tree structure; without it the structure is
    reconstructed from the manifest's leaf paths (nested dicts — what the
    elastic stencil runner checkpoints and resumes without ever having
    held the pre-failure state object).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for meta in manifest["leaves"]:
        arr = np.load(os.path.join(d, meta["file"]))
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch in {meta['file']}")
        leaves.append(_from_numpy(arr, meta["dtype"]))
    if like is None:
        state = _tree_from_paths(manifest["paths"], leaves)
    else:
        treedef = jax.tree.structure(like)
        state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state, step


class AsyncCheckpointer:
    """Background-thread checkpoint writer (snapshot-to-host then async IO)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        self.saved_steps: list[int] = []

    def save(self, state: Params, step: int) -> None:
        self.wait()
        # snapshot device arrays to host synchronously (cheap vs training step)
        host_state = jax.tree.map(lambda x: _from_numpy(*_to_numpy(x)), state)

        def work():
            try:
                save(host_state, self.ckpt_dir, step, keep=self.keep)
                self.saved_steps.append(step)
            except Exception as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
