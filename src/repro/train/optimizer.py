"""AdamW optimizer with ZeRO-friendly state, LR schedule, clipping, and
gradient-compression / bucketed-collective hooks.

Built from scratch (no optax in this container).  Moments can be stored in
bf16 for very large models (grok: see configs/grok_1_314b.py memory note);
the update math always runs in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

Params = Any


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Params, max_norm: float) -> tuple[Params, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


# ---------------------------------------------------------------------------
# gradient compression (beyond-paper distributed-optimization trick)
# ---------------------------------------------------------------------------


def compress_grads(tree: Params, mode: str, key: jax.Array | None = None) -> Params:
    """Wire-format compression applied before the gradient collectives.

    'bf16'            — cast to bf16 (halves gradient all-reduce bytes)
    'int8_stochastic' — per-tensor scale + stochastic rounding to int8,
                        immediately dequantized (simulates the wire format
                        end-to-end so training quality effects are real).
    """
    if mode == "none":
        return tree
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)
    if mode == "int8_stochastic":
        leaves, treedef = jax.tree.flatten(tree)
        keys = jax.random.split(key if key is not None else jax.random.key(0),
                                len(leaves))
        out = []
        for g, k in zip(leaves, keys):
            gf = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            scaled = gf / scale
            noise = jax.random.uniform(k, g.shape, jnp.float32, -0.5, 0.5)
            q = jnp.clip(jnp.round(scaled + noise), -127, 127)
            out.append((q * scale).astype(g.dtype))
        return jax.tree.unflatten(treedef, out)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def init_opt_state(params: Params, cfg: OptimizerConfig,
                   state_dtype: str = "float32") -> dict:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _decay_mask(path) -> bool:
    """Weight decay only on >=2-D weights (not norms/biases/gains)."""
    return True


def adamw_update(
    params: Params,
    grads: Params,
    opt: dict,
    cfg: OptimizerConfig,
) -> tuple[Params, dict, dict]:
    """One AdamW step; returns (params, opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
