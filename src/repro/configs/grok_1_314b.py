"""Grok-1 314B — 8 experts, top-2 routing, the largest assigned arch.

[hf:xai-org/grok-1; unverified] 64L d_model=6144 48H (GQA kv=8) d_ff=32768
(per expert) vocab=131072, MoE 8e top-2.

Memory note (256 chips, 16 GB HBM v5e):
  params bf16           628 GB  -> 2.45 GB/chip
  grads bf16            628 GB  -> 2.45 GB/chip (reduce-scattered over data)
  Adam m+v bf16        1256 GB  -> 4.91 GB/chip (ZeRO-1 over data axis)
  activations (full remat, microbatched) ~2 GB/chip
  total ~12 GB/chip -> fits.  fp32 Adam states would NOT fit (see DESIGN.md),
  hence ``opt_state_dtype='bfloat16'`` here.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        n_experts=8,
        top_k=2,
        ep_slots=16,
        moe_seq_chunk=0,  # §Perf G1: chunking re-reads expert weights per chunk
        fsdp_experts=True,
        act="geglu",  # gated gelu (GeGLU)
        remat="dots",  # §Perf G4: full-remat recompute is pure compute waste here
        train_microbatches=8,  # §Perf G2: FSDP gather/reduce traffic scales with microbatches
        grad_accum_dtype="bfloat16",
        opt_state_dtype="bfloat16",
        logits_chunk=8192,
    )
)
