"""Llama 3.2 Vision 11B — text decoder with gated cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified] 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256.  8 of the 40 layers are cross-attention layers (every
5th, HF layout).  The vision tower is a STUB: ``input_specs()`` supplies
precomputed patch embeddings of width ``d_vision``.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500_000.0,
        n_cross_layers=8,
        cross_every=5,
        vision_tokens=1601,
        d_vision=1280,
        remat="dots",
        train_microbatches=8,
        logits_chunk=8192,
    )
)
