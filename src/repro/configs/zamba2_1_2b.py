"""Zamba2 1.2B — Mamba2 backbone + shared attention block every 6 layers.

[arXiv:2411.15242; hf] 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Hybrid/sub-quadratic: runs the long_500k cell.  The shared
transformer block (one set of weights) is applied every ``attn_every`` mamba
blocks — the most literal halo/stencil analogue in the pool (conv1d ghost
cells + SSD state ring across sequence shards).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_heads=64,  # d_inner / 64 head_dim
        ssm_expand=2,
        conv_kernel=4,
        attn_every=6,
        remat="dots",
        train_microbatches=2,
    )
)
