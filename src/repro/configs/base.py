"""Configuration system for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances.  Configs are frozen
dataclasses so they can be hashed into jit static arguments and plan-cache keys.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set; identical across all LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) workload cell.

    ``kind`` selects which step function the cell lowers:
      * ``train``   -> ``train_step``   (forward + backward + optimizer)
      * ``prefill`` -> ``serve_step``   (full-sequence forward, cache build)
      * ``decode``  -> ``serve_step``   (1 new token against a seq_len cache)
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering all 10 assigned families."""

    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid | rwkv

    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    causal: bool = True
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # partial rotary (stablelm-2: 0.25)

    # --- norms / activations ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # expert-parallel slot layout: experts are stored as ``ep_slots`` slots of
    # hidden-shard width d_ff/(ep_slots/n_experts), so an 8-expert model can
    # occupy a 16-way model axis (grok: 16 slots = 8 experts x 2-way hidden).
    # 0 -> n_experts (one slot per expert, no hidden split).
    ep_slots: int = 0
    # sequence chunking through the MoE layer: bounds the all-to-all dispatch
    # buffer and pipelines dispatch chunks (partitioned-communication style).
    moe_seq_chunk: int = 0  # 0 = whole sequence at once
    # FSDP-style 2-D expert sharding: layer-stack dim over the data axes in
    # addition to slots over model (grok: 618 GB of expert weights would
    # otherwise replicate across data-parallel replicas -> 39 GB/chip).
    # GSPMD re-gathers each layer's slice inside the scan (the FSDP price,
    # visible in the roofline collective term).
    fsdp_experts: bool = False

    # --- vision (llama-3.2-vision): cross-attention image layers ---
    n_cross_layers: int = 0  # number of cross-attn layers interleaved
    cross_every: int = 0  # a cross layer after every N self layers
    vision_tokens: int = 1601  # stub patch-embedding count per image
    d_vision: int = 1280  # stub vision embedding width

    # --- audio (hubert): frame-embedding stub + mask-predict head ---
    audio_frontend_stub: bool = False

    # --- SSM / RWKV / hybrid ---
    ssm_state: int = 0  # Mamba2 state size N
    ssm_heads: int = 0  # Mamba2 value heads
    ssm_expand: int = 2  # d_inner = expand * d_model
    conv_kernel: int = 4
    rwkv_head_size: int = 64
    attn_every: int = 0  # zamba2: shared attention block every N ssm blocks
    scan_chunk: int = 0  # WKV/SSD intra-chunk length (0 = family default;
    #   bigger chunks = fewer sequential steps but a larger pairwise tensor —
    #   swept in EXPERIMENTS.md §Perf extras)

    # --- numerics / memory policy ---
    dtype: str = "bfloat16"  # activation dtype
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # bf16 for very large models (grok)
    remat: str = "none"  # none | dots | full
    logits_chunk: int = 0  # chunked loss for huge vocabs (0 = off)
    # gradient accumulation: scan over this many microbatches per step so the
    # per-layer activation carry fits HBM (launchers clamp to the batch/data
    # divisibility; see launch/dryrun.py)
    train_microbatches: int = 1
    grad_accum_dtype: str = "float32"  # bf16 for grok (memory note in config)

    # --- distribution defaults (overridable per run) ---
    sequence_parallel_prefill: bool = True  # ring attention for prefill shapes
    partitioned_collectives: bool = True  # paper technique on by default
    halo_n_parts: int = 4  # default partition count for partitioned comm

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def is_encoder_only(self) -> bool:
        return self.family == "audio"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k is runnable."""
        return self.family in ("ssm", "hybrid", "rwkv")

    def shapes(self) -> list[ShapeConfig]:
        """The live cells for this arch (skips per DESIGN.md §4)."""
        out = [TRAIN_4K, PREFILL_32K]
        if not self.is_encoder_only:
            out.append(DECODE_32K)
            if self.supports_long_context:
                out.append(LONG_500K)
        return out

    def skipped_shapes(self) -> list[tuple[str, str]]:
        out = []
        if self.is_encoder_only:
            out.append(("decode_32k", "encoder-only: no decode step"))
            out.append(("long_500k", "encoder-only: no decode step"))
        elif not self.supports_long_context:
            out.append(("long_500k", "full quadratic attention: skipped per spec"))
        return out

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (validated against published sizes)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            qkv = d * (n_q * hd) + 2 * d * (n_kv * hd)
            if self.qkv_bias:
                qkv += n_q * hd + 2 * n_kv * hd
            o = (n_q * hd) * d
            return qkv + o

        def mlp_params(ff: int) -> int:
            if self.act in ("silu", "geglu"):  # gated
                return 3 * d * ff
            return 2 * d * ff

        def norm_params() -> int:
            return d if self.norm == "rmsnorm" else 2 * d

        total = 0
        emb = v * d
        total += emb if self.tie_embeddings else 2 * emb
        total += norm_params()  # final norm

        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(f) + 2 * norm_params()
            # vlm: n_cross_layers of the n_layers are cross-attention layers
            n_self = self.n_layers - self.n_cross_layers
            total += n_self * per_layer
            if self.family == "vlm":
                # cross-attn layers: q from text, kv from vision tokens (+ q/k norms, gates)
                cross = (
                    d * (n_q * hd)
                    + 2 * d * (n_kv * hd)
                    + (n_q * hd) * d
                    + mlp_params(f)
                    + 2 * norm_params()
                    + 2 * hd  # q/k head norms
                    + 2  # attn/ffn tanh gates
                )
                total += self.n_cross_layers * cross
                total += self.d_vision * d  # patch-embedding projection stub
        elif self.family == "audio":
            per_layer = attn_params() + mlp_params(f) + 2 * norm_params()
            total += self.n_layers * per_layer
            total += self.d_vision * d  # frame-embedding projection stub
        elif self.family == "moe":
            expert = mlp_params(f)
            router = d * self.n_experts
            per_layer = (
                attn_params() + self.n_experts * expert + router + 2 * norm_params()
            )
            total += self.n_layers * per_layer
        elif self.family == "rwkv":
            # time-mix: r,k,v,g,o (d*d) + w lora + u;  channel-mix: k (d*f), v (f*d), r (d*d)
            tm = 5 * d * d + 6 * 32 * d * 2 + d  # lora(32) decay proj + bonus u
            cm = d * f + f * d + d * d
            total += self.n_layers * (tm + cm + 2 * norm_params())
        elif self.family == "ssm":
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * ns + nh)
            conv = (di + 2 * ns) * self.conv_kernel
            out_proj = di * d
            total += self.n_layers * (in_proj + conv + out_proj + nh + nh + norm_params())
        elif self.family == "hybrid":
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * ns + nh)
            conv = (di + 2 * ns) * self.conv_kernel
            out_proj = di * d
            mamba = in_proj + conv + out_proj + 2 * nh + norm_params()
            total += self.n_layers * mamba
            # one shared attention+mlp block (applied every attn_every layers)
            shared = attn_params() + mlp_params(f) + 2 * norm_params()
            # zamba2 concatenates [x, emb] into the shared block: first-proj doubled
            shared += d * (n_q * hd)  # extra input width for q
            total += shared
        else:
            raise ValueError(self.family)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        expert = 3 * d * f if self.act in ("silu", "geglu") else 2 * d * f
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert
        return self.param_count() - inactive

    # ------------------------------------------------------------------
    def model_flops_per_token(self, seq_len: int, kind: str = "train") -> float:
        """MODEL_FLOPS term: 6*N (train) / 2*N (inference) per token, dense or
        active-param based, plus quadratic attention term where applicable."""
        n = self.active_param_count()
        mult = 6.0 if kind == "train" else 2.0
        flops = mult * n
        if self.family not in ("ssm", "rwkv") and self.n_heads:
            # attention scores+values: 2 * 2 * d_attn * seq (causal halves it)
            d_attn = self.n_heads * self.resolved_head_dim
            causal_factor = 0.5 if self.causal else 1.0
            att = mult * 2 * d_attn * seq_len * causal_factor
            n_attn_layers = (
                self.n_layers
                if self.family != "hybrid"
                else max(1, self.n_layers // max(1, self.attn_every))
            )
            flops += att * (
                n_attn_layers / max(1, self.n_layers)
            ) * self.n_layers  # == att * n_attn_layers
        return flops

    def with_updates(self, **kw: Any) -> "ModelConfig":
        return replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=64,
            d_ff=128,
            vocab_size=128,
            remat="none",
            logits_chunk=0,
            halo_n_parts=2,
        )
        if self.n_heads:
            kw.update(n_heads=4, n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4, head_dim=16)
        if self.family == "moe":
            # capacity high enough that no token drops: prefill/full-forward
            # equivalence is exact in smoke tests (drop semantics are covered
            # by tests/models/test_moe.py)
            kw.update(n_experts=4, top_k=2, ep_slots=0, capacity_factor=8.0,
                      moe_seq_chunk=0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_heads=4, attn_every=2 if self.attn_every else 0)
        if self.family == "rwkv":
            kw.update(rwkv_head_size=16)
        if self.family in ("vlm", "audio"):
            kw.update(d_vision=32, vision_tokens=8)
        if self.family == "vlm":
            kw.update(n_cross_layers=1, cross_every=2)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Run / training configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # ZeRO-1: shard optimizer state over the data axis where divisible
    zero1: bool = True
    # gradient compression (beyond-paper distributed-optimization trick)
    grad_compression: str = "none"  # none | bf16 | int8_stochastic
    # partitioned (bucketed/chunked) gradient collectives
    partitioned_grad_buckets: int = 0  # 0 = single fused collective


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig = TRAIN_4K
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    # checkpointing / fault tolerance
    checkpoint_dir: str = ""
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    resume: bool = True
    # straggler mitigation
    straggler_ewma: float = 0.9
    straggler_factor: float = 3.0

    @property
    def microbatch(self) -> int:
        return self.shape.global_batch


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry lazily
    from repro import configs as _c  # noqa: F401  (imports all arch modules)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    from repro import configs as _c  # noqa: F401

    return dict(_REGISTRY)
