"""Comb-paper experiment configuration: Quartz-class machine constants and the
four figure workloads.

``QUARTZ`` was calibrated against the paper's quoted speedups by
``benchmarks/calibrate.py`` (random-search weighted least squares; seed 3,
8000 iters — re-run that script to re-derive).  Per-claim residuals are
reported in EXPERIMENTS.md §Paper: C1/C3/C5/C6 fit well; the paper's single
68 % strong-scaling point (C2 peak) is under-predicted ~2x by any smooth
NIC-share model and is discussed there.
"""

from __future__ import annotations

from repro.core.model_comm import MachineModel, StencilWorkload

# calibrated constants (benchmarks/calibrate.py, seed 3, loss 8.16)
QUARTZ = MachineModel(
    alpha=1.24193e-06,
    o_msg=1.0175e-06,
    o_persist_msg=1e-06,
    o_part=2.71578e-06,
    pack_bw=6e9,
    mem_bw=2e9,
    contention_coef=0.207763,
    on_node_fraction=0.698488,
    proto_frac=0.14907,
    rdv_rtt_factor=5.84895,
    burst_penalty=0.0,
    burst_scale=0.791465,
    tm_coef=0.0112673,
    socket_split_penalty=2.15235,
    ht_eff=0.571904,
    nic_bw=12.5e9,
    o_persist_init=25e-6,
    eager_threshold=16384,
    thread_launch=4.0e-6,
    threads_per_socket=32,
    contention_base=64,
    cores=32,
)

# paper experiment grids ------------------------------------------------------

FIG2_WEAK = dict(
    procs=(64, 128, 256, 512, 1024, 2048, 4096),
    face_doubles=524_288,
    ranks_per_node=32,
    threads=2,
)

FIG3_STRONG = dict(
    procs=(128, 256, 512, 1024, 2048, 4096),
    global_cells=(2048, 2048, 2048),
    ranks_per_node=32,
    threads=2,
)

FIG4_MSG_SIZE = dict(
    procs=4096,
    doubles=(768, 1536, 3072, 6144, 12288, 24576, 49152, 98304, 196_608),
    ranks_per_node=32,
    threads=2,
)

FIG5_RANKS_PER_NODE = dict(
    nodes=64,
    ranks_per_node=(1, 2, 4, 8, 16, 32),
    threads_per_node=64,
    global_cells=(2048, 4096, 4096),
)


def fig2_workload() -> StencilWorkload:
    return StencilWorkload.from_face_doubles(FIG2_WEAK["face_doubles"])


def fig3_workload(nprocs: int) -> StencilWorkload:
    return StencilWorkload.from_global_mesh(FIG3_STRONG["global_cells"], nprocs)


def fig4_workload(doubles: int) -> StencilWorkload:
    return StencilWorkload.from_face_doubles(doubles)


def fig5_workload(nprocs: int) -> StencilWorkload:
    return StencilWorkload.from_global_mesh(
        FIG5_RANKS_PER_NODE["global_cells"], nprocs
    )
