"""Architecture registry: importing this package registers all assigned archs."""

from repro.configs.base import (  # noqa: F401
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    all_configs,
    get_config,
    register,
)

# one module per assigned architecture (imports register into the registry)
from repro.configs import (  # noqa: F401
    rwkv6_1_6b,
    llama_3_2_vision_11b,
    qwen2_5_14b,
    llama3_8b,
    granite_8b,
    stablelm_1_6b,
    phi3_5_moe_42b,
    grok_1_314b,
    hubert_xlarge,
    zamba2_1_2b,
)

ARCH_IDS = [
    "rwkv6-1.6b",
    "llama-3.2-vision-11b",
    "qwen2.5-14b",
    "llama3-8b",
    "granite-8b",
    "stablelm-1.6b",
    "phi3.5-moe-42b-a6.6b",
    "grok-1-314b",
    "hubert-xlarge",
    "zamba2-1.2b",
]
