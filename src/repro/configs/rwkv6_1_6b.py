"""RWKV-6 (Finch) 1.6B — attention-free, data-dependent decay linear attention.

[arXiv:2404.05892; unverified] 24L d_model=2048 d_ff=7168 vocab=65536.
Sub-quadratic: runs the long_500k cell.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-1.6b",
        family="rwkv",
        n_layers=24,
        d_model=2048,
        d_ff=7168,
        vocab_size=65536,
        rwkv_head_size=64,
        norm="layernorm",
        act="relu",  # channel-mix uses squared relu
        tie_embeddings=False,
        remat="dots",
        scan_chunk=64,  # §Perf extras: U-shaped sweep, 3.1x memory-term win vs 16
        train_microbatches=2,
        dtype="bfloat16",
    )
)
