"""StableLM-2 1.6B — dense MHA decoder (kv=32), LayerNorm, partial rotary.

[hf:stabilityai/stablelm-2-1_6b; unverified] 24L d_model=2048 32H (GQA kv=32)
d_ff=5632 vocab=100352.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        norm="layernorm",
        rope_pct=0.25,
        remat="none",
        train_microbatches=2,
        logits_chunk=8192,
    )
)
