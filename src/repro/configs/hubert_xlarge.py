"""HuBERT X-Large — encoder-only audio transformer (wav2vec2 architecture).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504 (k-means cluster codebook).  Encoder-only: decode shapes are skipped.
The conv waveform frontend is a STUB: ``input_specs()`` supplies precomputed
frame embeddings of width ``d_vision`` (=512, the conv feature width).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        norm="layernorm",
        act="gelu",
        causal=False,
        audio_frontend_stub=True,
        d_vision=512,  # conv feature-extractor output width (stubbed)
        remat="dots",
        train_microbatches=2,
    )
)
