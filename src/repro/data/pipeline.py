"""Deterministic synthetic data pipeline with host-side prefetch.

Tokens follow a Zipf-like distribution with a deterministic per-(seed, step)
stream, so a restarted run consumes byte-identical batches — the property the
fault-tolerance tests rely on.  A background thread keeps ``prefetch`` batches
ahead of the training loop and places them with the batch sharding.

Multi-host note: each host would draw only its ``process_index`` slice of the
global batch (the slicing is in ``_host_slice``); this container has one host.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic LM/audio/vlm batches for a config."""

    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 0xC0FFEE]))
        b, s, v = self.global_batch, self.seq_len, self.cfg.vocab_size
        if self.cfg.family == "audio":
            frames = rng.normal(size=(b, s, self.cfg.d_vision)).astype(np.float32)
            labels = self._zipf(rng, (b, s), v)
            mask = (rng.random((b, s)) < 0.3).astype(np.float32)
            return {"frames": frames, "labels": labels, "mask": mask}
        # zipf-ish heavy-tailed token stream + next-token labels
        tokens = self._zipf(rng, (b, s + 1), v)
        out = {"tokens": tokens[:, :-1].astype(np.int32),
               "labels": tokens[:, 1:].astype(np.int32)}
        if self.cfg.family == "vlm":
            out["vision_emb"] = rng.normal(
                size=(b, self.cfg.vision_tokens, self.cfg.d_vision)
            ).astype(np.float32)
        return out

    @staticmethod
    def _zipf(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
        u = rng.random(shape)
        # inverse-CDF of a truncated zipf(1.1): heavy-tailed like real text
        ranks = np.floor(np.exp(u * np.log(vocab))).astype(np.int64) - 1
        return np.clip(ranks, 0, vocab - 1).astype(np.int32)

    def _host_slice(self, batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        n = jax.process_count()
        if n == 1:
            return batch
        i = jax.process_index()
        return {k: v[i * v.shape[0] // n: (i + 1) * v.shape[0] // n]
                for k, v in batch.items()}


class Prefetcher:
    """Background-thread prefetch + device placement."""

    def __init__(self, dataset: SyntheticLM, shardings: Any | None = None,
                 start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch: dict[str, np.ndarray]) -> dict[str, jax.Array]:
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {
            k: jax.device_put(v, self.shardings[k]) for k, v in batch.items()
        }

    def _work(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step)
            try:
                self._q.put((step, self._place(batch)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
