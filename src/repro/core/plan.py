"""Persistent communication/step plans — the MPI persistent-request analogue.

The paper's persistent MPI (`MPI_Send_init` / `MPI_Start` / `MPI_Wait` /
`MPI_Request_free`) amortizes per-message setup over all iterations of an
iterative exchange.  The XLA-native analogue implemented here:

* **init**  -> trace + lower + compile the SPMD step once (``jax.jit(...).
  lower(...).compile()``); permutation tables and block slices are baked in as
  static constants (the "tag-matching done at init" analogue).
* **start** -> dispatch the pre-compiled executable (async under JAX's
  dispatch model — the returned arrays are futures).
* **wait**  -> ``jax.block_until_ready`` on the outputs.
* **free**  -> drop the executable.

A process-wide :class:`PlanCache` plays the role of the application's table of
initialized persistent requests; its hit/miss counters let tests and
benchmarks measure the amortization the paper reports (setup paid once).

The *standard* (non-persistent) baseline is modeled by :func:`dispatch_standard`,
which re-derives the plan arguments and goes through the normal ``jax.jit``
python dispatch path every call — preserving the relative per-iteration
overhead the paper measures between baseline and persistent modes.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Hashable, Sequence

import jax
import numpy as np


def _abstractify(x: Any) -> Any:
    """Concrete array / ShapeDtypeStruct -> hashable abstract description."""
    if isinstance(x, jax.ShapeDtypeStruct):
        return (x.shape, str(x.dtype), str(getattr(x, "sharding", None)))
    if isinstance(x, (jax.Array, np.ndarray)):
        sh = getattr(x, "sharding", None)
        return (x.shape, str(x.dtype), str(sh))
    return ("static", repr(x))


@dataclasses.dataclass
class PlanStats:
    inits: int = 0
    starts: int = 0
    cache_hits: int = 0
    init_seconds: float = 0.0
    frees: int = 0
    #: plans dropped because their topology died under them (elastic
    #: re-meshing); the next get_or_init on the new mesh pays a fresh init
    invalidations: int = 0


class CommPlan:
    """One persistent plan: a pre-compiled SPMD step with a fixed signature.

    Mirrors the MPI persistent-request lifecycle::

        plan = CommPlan(fn, example_args=...)     # MPI_Send_init
        out  = plan.start(*args)                  # MPI_Start(all)
        out  = plan.wait(out)                     # MPI_Wait(all)
        plan.free()                               # MPI_Request_free
    """

    def __init__(
        self,
        fn: Callable,
        *,
        example_args: Sequence[Any],
        mesh: jax.sharding.Mesh | None = None,
        in_shardings: Any = None,
        out_shardings: Any = None,
        donate_argnums: tuple[int, ...] = (),
        static_argnums: tuple[int, ...] = (),
        name: str | None = None,
    ):
        self.name = name or getattr(fn, "__name__", "plan")
        #: transport-schedule identity + coalesced wire-layout offset
        #: tables, stamped by :func:`transport_plan` at init
        self.schedule = None
        self.wire_layouts: tuple = ()
        self._freed = False
        t0 = time.perf_counter()
        kw: dict[str, Any] = dict(
            donate_argnums=donate_argnums, static_argnums=static_argnums
        )
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            kw["out_shardings"] = out_shardings
        jitted = jax.jit(fn, **kw)
        ctx = mesh if mesh is not None else _NullCtx()
        with ctx:  # type: ignore[attr-defined]
            self.lowered = jitted.lower(*example_args)
            self.compiled = self.lowered.compile()
        self.init_seconds = time.perf_counter() - t0

    # -- lifecycle ---------------------------------------------------------
    def start(self, *args: Any) -> Any:
        """Begin the exchange (async dispatch of the compiled executable)."""
        if self._freed:
            raise RuntimeError(f"plan {self.name!r} used after free()")
        return self.compiled(*args)

    @staticmethod
    def wait(out: Any) -> Any:
        """Block until the started exchange has completed."""
        return jax.block_until_ready(out)

    def __call__(self, *args: Any) -> Any:
        return self.start(*args)

    def free(self) -> None:
        self._freed = True
        self.compiled = None
        self.lowered = None

    # -- introspection (feeds the dry-run / roofline) -----------------------
    def memory_analysis(self) -> Any:
        return self.compiled.memory_analysis()

    def cost_analysis(self) -> dict:
        from repro.core.compat import cost_analysis_dict

        return cost_analysis_dict(self.compiled)

    def as_text(self) -> str:
        return self.compiled.as_text()

    def lowered_text(self) -> str:
        return self.lowered.as_text()


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


class PlanCache:
    """Registry of initialized persistent plans (keyed by fn + abstract args).

    The framework-wide instance (:data:`PLANS`) is what the training loop and
    serving engine use; per-instance caches can be created for tests.
    """

    def __init__(self) -> None:
        self._plans: dict[Hashable, CommPlan] = {}
        self._lock = threading.Lock()
        self.stats = PlanStats()

    def key_for(self, fn: Callable, args: Sequence[Any], extra: Hashable = ()) -> Hashable:
        flat, treedef = jax.tree.flatten(list(args))
        return (
            getattr(fn, "__qualname__", repr(fn)),
            id(getattr(fn, "__wrapped__", fn)),
            str(treedef),
            tuple(_abstractify(x) for x in flat),
            extra,
        )

    def get_or_init(
        self,
        fn: Callable,
        args: Sequence[Any],
        *,
        extra_key: Hashable = (),
        key: Hashable | None = None,
        lazy_fn: bool = False,
        **plan_kwargs: Any,
    ) -> CommPlan:
        """``key`` overrides the default (qualname + fn identity + abstract
        args) cache key entirely — for callers whose ``fn`` is a fresh
        closure each time (e.g. exchange strategies rebuilding their step)
        but whose plan identity is structural.  With ``lazy_fn``, ``fn`` is
        a zero-arg *factory* for the real function, only invoked on a miss
        (a hit skips plan assembly entirely, as MPI_Start skips setup)."""
        if key is None:
            assert not lazy_fn, "lazy_fn requires an explicit structural key"
            key = self.key_for(fn, args, extra_key)
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.stats.cache_hits += 1
                return plan
        plan = CommPlan(fn() if lazy_fn else fn, example_args=args,
                        **plan_kwargs)
        with self._lock:
            self._plans[key] = plan
            self.stats.inits += 1
            self.stats.init_seconds += plan.init_seconds
        return plan

    def invalidate(self, predicate: Callable[[Hashable], bool] | None = None) -> int:
        """Drop (and free) cached plans whose topology no longer exists.

        This is the elastic re-mesh path: after rank loss the surviving
        processes re-form the mesh, and every plan compiled against the old
        device assignment is garbage — its permutation tables name shards
        that are gone.  ``predicate`` selects which keys to drop (default:
        all).  Returns the number of invalidated plans; the count is also
        accumulated in ``stats.invalidations`` (a BENCH-recorded metric).

        A plan build *in flight* during the failure never lands here:
        :meth:`get_or_init` inserts only after a successful init, so an
        aborted build cannot poison the cache.
        """
        with self._lock:
            doomed = [k for k in self._plans
                      if predicate is None or predicate(k)]
            for k in doomed:
                self._plans.pop(k).free()
                self.stats.frees += 1
            self.stats.invalidations += len(doomed)
        return len(doomed)

    def free_all(self) -> None:
        with self._lock:
            for p in self._plans.values():
                p.free()
                self.stats.frees += 1
            self._plans.clear()

    def invalidate_stale_epochs(self, live_epoch: int) -> int:
        """Drop only the plans compiled against a dead membership epoch.

        The in-grid recovery path (:mod:`repro.launch.membership`): when the
        coordinator bumps the grid to ``live_epoch`` after a JOIN or rank
        loss, plans stamped with an older epoch can never deliver into the
        re-formed mesh — but everything else a surviving rank has warmed up
        (other shapes, other workloads, epoch-free plans) stays resident.
        That retention is the whole point of recovering without a relaunch.
        """
        return self.invalidate(lambda key: stale_epoch(key, live_epoch))

    def keys(self) -> tuple:
        """Snapshot of the resident plan keys (retention assertions: the
        in-grid chaos tests prove unrelated entries survive a recovery)."""
        with self._lock:
            return tuple(self._plans)

    def __len__(self) -> int:
        return len(self._plans)


def stale_epoch(key: Hashable, live_epoch: int) -> bool:
    """True when any element of a (possibly nested) plan key carries a
    membership ``epoch`` older than ``live_epoch``.

    Plan keys are structural tuples; the epoch rides inside whatever spec
    object the strategy embeds (e.g. :class:`~repro.core.halo.HaloSpec`),
    so this walks the key duck-typed rather than binding to one spec type.
    Keys with no epoch-stamped element (``epoch`` absent or ``None``) are
    never stale — epoch-free callers (the whole non-elastic world) are
    untouched by epoch invalidation.
    """
    def walk(obj) -> bool:
        epoch = getattr(obj, "epoch", None)
        if isinstance(epoch, int) and not isinstance(epoch, bool) \
                and epoch < live_epoch:
            return True
        if isinstance(obj, tuple):
            return any(walk(el) for el in obj)
        return False

    return walk(key)


#: process-wide persistent-plan registry
PLANS = PlanCache()


def build_plan(
    step_factory: Callable[[], Callable],
    example_args: Sequence[Any],
    *,
    donate_argnums: tuple[int, ...] = (),
    cache: "PlanCache | None" = None,
    key: Hashable | None = None,
    name: str | None = None,
) -> CommPlan:
    """Assemble one persistent plan, private or from a shared cache.

    The cache-vs-private branch of every persistent-style ``init`` lives
    here exactly once.  Without ``cache`` the factory is invoked and the
    plan is owned by the caller; with ``cache`` the plan joins that table
    of initialized requests under ``key`` (which must then be a structural
    key, as for :meth:`PlanCache.get_or_init`) and the factory only runs
    on a miss — the step is NOT rebuilt or recompiled on a hit.
    """
    if cache is None:
        return CommPlan(
            step_factory(),  # plan assembled exactly once
            example_args=example_args, donate_argnums=donate_argnums,
            name=name,
        )
    assert key is not None, "cached plans need a structural key"
    return cache.get_or_init(
        step_factory, example_args, key=key,
        donate_argnums=donate_argnums, name=name, lazy_fn=True,
    )


def transport_plan(
    step_factory: Callable[[], Callable],
    example_args: Sequence[Any],
    *,
    schedule: Any,
    layouts: Sequence[Any] | Callable[[], Sequence[Any]] | None = None,
    donate_argnums: tuple[int, ...] = (),
    cache: "PlanCache | None" = None,
    key: Hashable | None = None,
    name: str | None = None,
) -> CommPlan:
    """Compile ONE persistent plan for a transport schedule.

    ``schedule`` is a :class:`repro.core.transport.ScheduleInfo` naming the
    choreography (sequential/fused), the mesh axes it spans, the registered
    packer/transport backends every message resolves, and whether messages
    coalesce — so the compiled executable's identity (plan name, and the
    structural cache ``key`` the caller derives from its spec) always
    records *which* pack/transport pipeline was baked in.  ``layouts`` is
    the coalesced schedule's static :class:`~repro.core.transport.
    WireLayout` offset tables (one per wire buffer) — a sequence, or a
    zero-arg factory invoked only when the plan is freshly stamped —
    recorded on the plan (``plan.wire_layouts``) as introspection the way
    ``MPI_Send_init`` records its amortized buffers: computed once at the
    plan's first init, never per ``start`` and never again on a cache hit.
    This is the one place the free-floating "compile this exchange step"
    call used to live; every persistent-style strategy now initializes
    through it.
    """
    axes = tuple(schedule.mesh_axes)
    assert axes, "a transport plan needs at least one mesh axis"
    assert len(set(axes)) == len(axes), f"duplicate mesh axes: {axes}"
    plan = build_plan(
        step_factory, example_args, donate_argnums=donate_argnums,
        cache=cache, key=key, name=name or schedule.tag(),
    )
    if plan.schedule is None:  # a cache hit keeps its original stamp
        plan.schedule = schedule
        if callable(layouts):
            layouts = layouts()
        plan.wire_layouts = tuple(layouts) if layouts is not None else ()
    return plan


def multi_axis_plan(
    step_factory: Callable[[], Callable],
    example_args: Sequence[Any],
    *,
    mesh_axes: Sequence[str],
    packer: str = "slice",
    transport: str = "ppermute",
    coalesce: bool = False,
    mapping: str = "row-major",
    layouts: Sequence[Any] | None = None,
    donate_argnums: tuple[int, ...] = (),
    cache: "PlanCache | None" = None,
    key: Hashable | None = None,
    name: str | None = None,
) -> CommPlan:
    """Build ONE persistent plan spanning every mesh axis of an exchange.

    The sequential schedule would compile (or at least sequence) one
    exchange pass per decomposed mesh axis; the fused multi-axis schedule
    hands the whole D-axis step to a single :class:`CommPlan` so every
    pack/send/unpack of every axis lives in one AOT-compiled executable —
    the ``MPI_Send_init`` of all ``3^D - 1`` neighbor requests at once.
    Assembly delegates to :func:`transport_plan` with a ``"fused"``
    schedule identity.
    """
    from repro.core.transport import ScheduleInfo

    return transport_plan(
        step_factory, example_args,
        schedule=ScheduleInfo(
            kind="fused", mesh_axes=tuple(mesh_axes),
            packer=packer, transport=transport, coalesce=coalesce,
            mapping=mapping,
        ),
        layouts=layouts,
        donate_argnums=donate_argnums, cache=cache, key=key, name=name,
    )


def persistent(
    fn: Callable | None = None,
    *,
    cache: PlanCache | None = None,
    donate_argnums: tuple[int, ...] = (),
    mesh: jax.sharding.Mesh | None = None,
) -> Callable:
    """Decorator: make ``fn`` execute through a persistent plan.

    First call with a given abstract signature pays init (trace+compile);
    subsequent calls dispatch the stored executable directly.  This is the
    ergonomic form used by the training loop and serving engine.
    """

    def deco(f: Callable) -> Callable:
        c = cache if cache is not None else PLANS

        def wrapper(*args: Any) -> Any:
            plan = c.get_or_init(
                f, args, donate_argnums=donate_argnums, mesh=mesh
            )
            c.stats.starts += 1
            return plan.start(*args)

        wrapper.__wrapped__ = f  # type: ignore[attr-defined]
        wrapper.__name__ = getattr(f, "__name__", "persistent_fn")  # type: ignore
        wrapper.plan_cache = c  # type: ignore[attr-defined]
        return wrapper

    return deco(fn) if fn is not None else deco


def dispatch_standard(fn: Callable, *args: Any, **jit_kwargs: Any) -> Any:
    """The *baseline* (non-persistent) dispatch path.

    Re-wraps ``fn`` in a fresh ``jax.jit`` object each call, so python-level
    plan assembly (signature hashing, sharding resolution, dispatch-cache
    lookup) is re-done per iteration — the analogue of posting fresh
    ``MPI_Isend``/``Irecv`` envelopes each iteration.  XLA's compile cache
    still avoids recompiling the HLO (as MPI avoids re-opening connections),
    so the measured difference is exactly the per-iteration setup the paper's
    persistent mode amortizes.
    """
    return jax.jit(fn, **jit_kwargs)(*args)
