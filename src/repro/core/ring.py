"""Sequence-parallel ring primitives built on partitioned communication.

Two LM-side incarnations of the paper's halo-exchange pipeline:

* :func:`ring_attention` — blockwise attention where the KV shard circulates
  around the mesh-axis ring.  The *partitioned* variant splits each KV block
  into ``n_parts`` partitions so the permute of partition *k+1* overlaps the
  attention compute consuming partition *k* (early work), exactly the paper's
  ``Pready``/``Parrived`` pipeline with attention as the consumer.

* :func:`state_passing` — the recurrent-state "ghost cell" exchange for
  SSM/RWKV sequence parallelism.  Each device reduces its sequence shard to an
  affine operator ``s -> D*s + C``; the incoming state for each shard is the
  exclusive prefix-composition of its predecessors.  ``method='ring'`` is the
  literal 1-D stencil neighbor pass (k-1 hops); ``method='tree'`` is the
  beyond-paper log-step doubling scan.

All functions run inside ``shard_map``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compat
from repro.core.transport import (
    Message,
    Packer,
    Partitioner,
    Transport,
    exchange_messages,
    resolve_packer,
    resolve_transport,
    ring_perm,
)

_NEG_INF = -1e30


def ring_kv_messages(
    kv_shape: tuple[int, ...],
    axis_name: str,
    ring_size: int,
    *,
    n_parts: int = 1,
    shift: int = 1,
) -> tuple[Message, ...]:
    """Message table for ONE hop of the ring-attention KV rotation.

    ``kv_shape`` is the stacked wire view ``(2, B, Skv, Hkv, D)`` — K at
    index 0, V at index 1.  Both messages share the single periodic-ring hop
    chain, so coalesced delivery packs K and V into ONE contiguous
    :class:`~repro.core.transport.WireLayout` buffer and routes the hop as
    ONE collective.  ``n_parts > 1`` partitions along the sequence axis
    (paper §II-B equal-partition rule, clipped remainder tail) and delivery
    pipelines the partitions as rounds.

    ``ring_size`` is passed explicitly (not read from a live mesh) so the
    same table drives both the in-``shard_map`` delivery and the static
    wire/collective accounting of the serve benchmark
    (:mod:`repro.serving.bench`).
    """
    assert kv_shape[0] == 2, kv_shape
    perm = tuple((i, (i + shift) % ring_size) for i in range(ring_size))
    hops = ((axis_name, perm),)
    part_axis = 2 if n_parts > 1 else None
    shape = (1,) + tuple(kv_shape[1:])
    out = []
    for tensor in range(2):
        start = (tensor,) + (0,) * (len(kv_shape) - 1)
        out.append(
            Message(start, start, shape, hops,
                    n_parts=n_parts, part_axis=part_axis)
        )
    return tuple(out)


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D) for GQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _attend_block(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    m: jax.Array,  # (B, H, Sq) running max
    l: jax.Array,  # (B, H, Sq) running denom
    acc: jax.Array,  # (B, Sq, H, D) running numerator
    q_off: jax.Array | int,
    kv_off: jax.Array | int,
    *,
    causal: bool,
    scale: float,
):
    """One online-softmax accumulation step over a KV block."""
    n_rep = q.shape[2] // k.shape[2]
    kf = _repeat_kv(k, n_rep)
    vf = _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * scale
    if causal:
        iq = q_off + jnp.arange(q.shape[1])
        ik = kv_off + jnp.arange(k.shape[1])
        mask = iq[:, None] >= ik[None, :]
        s = jnp.where(mask[None, None], s, _NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    # renormalize previous accumulation
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l = l * corr + p.sum(axis=-1)
    acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(vf.dtype), vf
    ).astype(acc.dtype)
    return m_new, l, acc


def ring_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    axis_name: str,
    *,
    causal: bool = True,
    n_parts: int = 1,
    scale: float | None = None,
    block_fn: Callable | None = None,
    transport: str | Transport = "ppermute",
    packer: str | Packer = "slice",
    coalesce: bool = True,
    comm: str = "messages",
) -> jax.Array:
    """Sequence-parallel attention with the KV shard circulating a ring.

    q: (B, Sq_local, H, D); k, v: (B, Skv_local, Hkv, D), sequence sharded
    over ``axis_name``.  Returns (B, Sq_local, H, D) with the same sharding
    as ``q``.  ``n_parts > 1`` splits each circulating KV block into equal
    partitions (paper's partitioned pipeline; partition transfer overlaps
    block attention).  ``block_fn`` may override the per-block accumulation
    (e.g. the Pallas flash kernel).

    ``comm="messages"`` (the default) routes every hop through the
    transport layer (:func:`repro.core.transport.exchange_messages`) on a
    stacked ``(2, B, Skv, Hkv, D)`` KV buffer: one :class:`Message` per
    tensor sharing a single ring hop chain, so ``coalesce=True`` ships K
    and V as ONE wire buffer and ONE collective per hop (n_parts pipelined
    rounds otherwise), and ``packer`` selects the registered wire format —
    wire-compressed ``bf16``/``scaled-int8`` apply per hop (lossy packers
    re-quantize at every hop; opt-in only).  ``comm="permute"`` is the
    historical bare-``Transport.permute`` reference path (bitwise-identical
    values for exact packers), kept for equivalence tests.
    """
    t = resolve_transport(transport)
    p = resolve_packer(packer)
    ksize = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    attend = block_fn or _attend_block
    if comm not in ("messages", "permute"):
        raise ValueError(f"unknown ring comm mode {comm!r}")

    m = jnp.full((b, h, sq), _NEG_INF, jnp.float32)
    l = jnp.zeros((b, h, sq), jnp.float32)
    acc = jnp.zeros((b, sq, h, d), jnp.float32)
    q_off = idx * sq

    part = Partitioner(n_parts, 1) if n_parts > 1 else None
    # static clipped partition windows, hoisted out of the hop loop (the
    # remainder tail attends at its true width; all-padding tails vanish)
    windows = part.slices(skv) if part is not None else [(0, skv)]

    def consume(m, l, acc, cur_k, cur_v, kv_off):
        for off, width in windows:
            if width <= 0:
                continue
            kc = lax.slice_in_dim(cur_k, off, off + width, axis=1)
            vc = lax.slice_in_dim(cur_v, off, off + width, axis=1)
            m, l, acc = attend(
                q, kc, vc, m, l, acc, q_off, kv_off + off,
                causal=causal, scale=scale,
            )
        return m, l, acc

    if comm == "messages" and ksize > 1:
        # the transport-layer path: each hop is a Message-table delivery on
        # the stacked KV buffer; attention consumes the current block while
        # the next hop's wire buffers are in flight (dataflow overlap — the
        # Pready/Parrived pipeline with the attention block as consumer).
        kv = jnp.stack([k, v])
        msgs = ring_kv_messages(kv.shape, axis_name, ksize, n_parts=n_parts)
        for s in range(ksize):
            owner = (idx - s) % ksize
            if s < ksize - 1:
                nxt = exchange_messages(
                    kv, (msgs,), packer=p, transport=t, coalesce=coalesce
                )
            m, l, acc = consume(m, l, acc, kv[0], kv[1], owner * skv)
            if s < ksize - 1:
                kv = nxt
    else:
        # reference path: bare per-tensor permutes.  Partition splits are
        # hoisted — split ONCE up front, permute the chunks each hop, and
        # consume from the chunk list directly (no per-hop re-split, no
        # merge/re-clip churn).
        perm = ring_perm(axis_name) if ksize > 1 else []
        if part is None:
            cur_k, cur_v = k, v
            for s in range(ksize):
                owner = (idx - s) % ksize
                if s < ksize - 1:
                    nxt_k = t.permute(cur_k, axis_name, perm)
                    nxt_v = t.permute(cur_v, axis_name, perm)
                m, l, acc = consume(m, l, acc, cur_k, cur_v, owner * skv)
                if s < ksize - 1:
                    cur_k, cur_v = nxt_k, nxt_v
        else:
            csize = part.part_size(skv)
            k_parts = part.split(k)
            v_parts = part.split(v)
            for s in range(ksize):
                owner = (idx - s) % ksize
                kv_off = owner * skv
                if s < ksize - 1:
                    nxt_k_parts = [
                        t.permute(c, axis_name, perm) for c in k_parts
                    ]
                    nxt_v_parts = [
                        t.permute(c, axis_name, perm) for c in v_parts
                    ]
                for ci, (kc, vc) in enumerate(zip(k_parts, v_parts)):
                    width = min(csize, skv - ci * csize)
                    if width <= 0:
                        continue
                    kc = lax.slice_in_dim(kc, 0, width, axis=1)
                    vc = lax.slice_in_dim(vc, 0, width, axis=1)
                    m, l, acc = attend(
                        q, kc, vc, m, l, acc, q_off, kv_off + ci * csize,
                        causal=causal, scale=scale,
                    )
                if s < ksize - 1:
                    k_parts, v_parts = nxt_k_parts, nxt_v_parts

    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# recurrent-state passing (SSM / RWKV sequence parallelism)
# ---------------------------------------------------------------------------


def state_passing(
    C: jax.Array,
    D: jax.Array,
    axis_name: str,
    *,
    method: str = "ring",
    transport: str | Transport = "ppermute",
) -> jax.Array:
    """Exclusive prefix of the affine state operators ``s -> D*s + C`` along a
    mesh axis; returns the incoming state ``s_in`` for each shard.

    ``C``: each shard's state contribution (state produced from a zero
    incoming state).  ``D``: each shard's cumulative decay (elementwise,
    broadcastable to ``C``).  Composition (later ∘ earlier):
    ``(D2, C2) ∘ (D1, C1) = (D2*D1, D2*C1 + C2)``.

    method='ring' — k-1 neighbor hops (the paper's 1-D stencil transport).
    method='tree' — ceil(log2(k)) doubling hops + 1 shift (beyond-paper).
    ``transport`` selects the registered hop backend
    (:mod:`repro.core.transport`).
    """
    t = resolve_transport(transport)
    k = compat.axis_size(axis_name)
    if k == 1:
        return jnp.zeros_like(C)
    idx = lax.axis_index(axis_name)
    D = jnp.broadcast_to(D, C.shape).astype(C.dtype)

    if method == "ring":
        shift = [(i, i + 1) for i in range(k - 1)]  # causal: no wraparound
        s = jnp.zeros_like(C)
        for _ in range(k - 1):
            s = t.permute(D * s + C, axis_name, shift)  # rank 0 gets zeros
        return s

    if method == "tree":
        return _tree_state_passing(C, D, axis_name, t)

    raise ValueError(method)


def _tree_state_passing(
    C: jax.Array, D: jax.Array, axis_name: str, t: Transport
) -> jax.Array:
    """Inclusive doubling scan over affine operators, then shift by one."""
    k = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    Dc, Cc = D, C
    hop = 1
    while hop < k:
        shift = [(i, i + hop) for i in range(k - hop)]
        D_prev = t.permute(Dc, axis_name, shift)
        C_prev = t.permute(Cc, axis_name, shift)
        has_prev = idx >= hop
        new_D = Dc * D_prev
        new_C = Dc * C_prev + Cc
        Dc = jnp.where(has_prev, new_D, Dc)
        Cc = jnp.where(has_prev, new_C, Cc)
        hop *= 2
    return t.permute(Cc, axis_name, [(i, i + 1) for i in range(k - 1)])
