"""Sequence-parallel ring primitives built on partitioned communication.

Two LM-side incarnations of the paper's halo-exchange pipeline:

* :func:`ring_attention` — blockwise attention where the KV shard circulates
  around the mesh-axis ring.  The *partitioned* variant splits each KV block
  into ``n_parts`` partitions so the permute of partition *k+1* overlaps the
  attention compute consuming partition *k* (early work), exactly the paper's
  ``Pready``/``Parrived`` pipeline with attention as the consumer.

* :func:`state_passing` — the recurrent-state "ghost cell" exchange for
  SSM/RWKV sequence parallelism.  Each device reduces its sequence shard to an
  affine operator ``s -> D*s + C``; the incoming state for each shard is the
  exclusive prefix-composition of its predecessors.  ``method='ring'`` is the
  literal 1-D stencil neighbor pass (k-1 hops); ``method='tree'`` is the
  beyond-paper log-step doubling scan.

All functions run inside ``shard_map``.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compat
from repro.core.transport import (
    Partitioner,
    Transport,
    resolve_transport,
    ring_perm,
)

_NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D) for GQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _attend_block(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    m: jax.Array,  # (B, H, Sq) running max
    l: jax.Array,  # (B, H, Sq) running denom
    acc: jax.Array,  # (B, Sq, H, D) running numerator
    q_off: jax.Array | int,
    kv_off: jax.Array | int,
    *,
    causal: bool,
    scale: float,
):
    """One online-softmax accumulation step over a KV block."""
    n_rep = q.shape[2] // k.shape[2]
    kf = _repeat_kv(k, n_rep)
    vf = _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) * scale
    if causal:
        iq = q_off + jnp.arange(q.shape[1])
        ik = kv_off + jnp.arange(k.shape[1])
        mask = iq[:, None] >= ik[None, :]
        s = jnp.where(mask[None, None], s, _NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    # renormalize previous accumulation
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l = l * corr + p.sum(axis=-1)
    acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(vf.dtype), vf
    ).astype(acc.dtype)
    return m_new, l, acc


def ring_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    axis_name: str,
    *,
    causal: bool = True,
    n_parts: int = 1,
    scale: float | None = None,
    block_fn: Callable | None = None,
    transport: str | Transport = "ppermute",
) -> jax.Array:
    """Sequence-parallel attention with the KV shard circulating a ring.

    q: (B, Sq_local, H, D); k, v: (B, Skv_local, Hkv, D), sequence sharded
    over ``axis_name``.  Returns (B, Sq_local, H, D) with the same sharding
    as ``q``.  ``n_parts > 1`` splits each circulating KV block into equal
    partitions (paper's partitioned pipeline; partition transfer overlaps
    block attention).  ``block_fn`` may override the per-block accumulation
    (e.g. the Pallas flash kernel); ``transport`` selects the registered
    backend (:mod:`repro.core.transport`) each KV hop goes through.
    """
    t = resolve_transport(transport)
    ksize = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    attend = block_fn or _attend_block

    m = jnp.full((b, h, sq), _NEG_INF, jnp.float32)
    l = jnp.zeros((b, h, sq), jnp.float32)
    acc = jnp.zeros((b, sq, h, d), jnp.float32)
    q_off = idx * sq

    perm = ring_perm(axis_name) if ksize > 1 else []
    part = Partitioner(n_parts, 1) if n_parts > 1 else None
    cur_k, cur_v = k, v
    for s in range(ksize):
        owner = (idx - s) % ksize
        kv_off = owner * skv
        if s < ksize - 1:
            # start the next block's transfer (partitioned: n_parts hops)
            if part is None:
                nxt_k = t.permute(cur_k, axis_name, perm)
                nxt_v = t.permute(cur_v, axis_name, perm)
            else:
                nxt_k_parts = [t.permute(c, axis_name, perm) for c in part.split(cur_k)]
                nxt_v_parts = [t.permute(c, axis_name, perm) for c in part.split(cur_v)]
        # consume the current block while the next one is in flight
        if part is None:
            m, l, acc = attend(
                q, cur_k, cur_v, m, l, acc, q_off, kv_off, causal=causal, scale=scale
            )
        else:
            csize = part.part_size(skv)
            for ci, (kc, vc) in enumerate(zip(part.split(cur_k), part.split(cur_v))):
                width = min(csize, skv - ci * csize)
                if width <= 0:
                    continue
                kc = lax.slice_in_dim(kc, 0, width, axis=1)
                vc = lax.slice_in_dim(vc, 0, width, axis=1)
                m, l, acc = attend(
                    q, kc, vc, m, l, acc, q_off, kv_off + ci * csize,
                    causal=causal, scale=scale,
                )
        if s < ksize - 1:
            if part is None:
                cur_k, cur_v = nxt_k, nxt_v
            else:
                cur_k = part.merge(nxt_k_parts, skv)
                cur_v = part.merge(nxt_v_parts, skv)

    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# recurrent-state passing (SSM / RWKV sequence parallelism)
# ---------------------------------------------------------------------------


def state_passing(
    C: jax.Array,
    D: jax.Array,
    axis_name: str,
    *,
    method: str = "ring",
    transport: str | Transport = "ppermute",
) -> jax.Array:
    """Exclusive prefix of the affine state operators ``s -> D*s + C`` along a
    mesh axis; returns the incoming state ``s_in`` for each shard.

    ``C``: each shard's state contribution (state produced from a zero
    incoming state).  ``D``: each shard's cumulative decay (elementwise,
    broadcastable to ``C``).  Composition (later ∘ earlier):
    ``(D2, C2) ∘ (D1, C1) = (D2*D1, D2*C1 + C2)``.

    method='ring' — k-1 neighbor hops (the paper's 1-D stencil transport).
    method='tree' — ceil(log2(k)) doubling hops + 1 shift (beyond-paper).
    ``transport`` selects the registered hop backend
    (:mod:`repro.core.transport`).
    """
    t = resolve_transport(transport)
    k = compat.axis_size(axis_name)
    if k == 1:
        return jnp.zeros_like(C)
    idx = lax.axis_index(axis_name)
    D = jnp.broadcast_to(D, C.shape).astype(C.dtype)

    if method == "ring":
        shift = [(i, i + 1) for i in range(k - 1)]  # causal: no wraparound
        s = jnp.zeros_like(C)
        for _ in range(k - 1):
            s = t.permute(D * s + C, axis_name, shift)  # rank 0 gets zeros
        return s

    if method == "tree":
        return _tree_state_passing(C, D, axis_name, t)

    raise ValueError(method)


def _tree_state_passing(
    C: jax.Array, D: jax.Array, axis_name: str, t: Transport
) -> jax.Array:
    """Inclusive doubling scan over affine operators, then shift by one."""
    k = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    Dc, Cc = D, C
    hop = 1
    while hop < k:
        shift = [(i, i + hop) for i in range(k - hop)]
        D_prev = t.permute(Dc, axis_name, shift)
        C_prev = t.permute(Cc, axis_name, shift)
        has_prev = idx >= hop
        new_D = Dc * D_prev
        new_C = Dc * C_prev + Cc
        Dc = jnp.where(has_prev, new_D, Dc)
        Cc = jnp.where(has_prev, new_C, Cc)
        hop *= 2
    return t.permute(Cc, axis_name, [(i, i + 1) for i in range(k - 1)])
