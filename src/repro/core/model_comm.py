"""Analytic performance model for stencil halo exchange (Quartz-class CPU cluster).

This container has one CPU core and no network, so the paper's *timings*
cannot be re-measured; what can be reproduced is the paper's *model of why*
each strategy wins or loses.  This module implements a LogGP-style
discrete-event model of one halo-exchange iteration under the three
strategies, with the cost terms the paper identifies:

* per-message host posting overhead (``o_msg``), reduced to ``o_persist_msg``
  by persistent init (amortized ``o_persist_init``);
* per-partition overhead ``o_part`` (``MPI_Pready`` + ``MPI_THREAD_MULTIPLE``
  serialization) — this is what makes partitioned *lose* for small messages
  and large partition counts (paper Figs. 4, 5);
* pack/unpack at ``pack_bw`` per OpenMP thread, with partition packing
  *overlapping* injection in the partitioned strategy (the core win);
* NIC serialization (``alpha`` + ``beta``·bytes per transfer) shared by all
  ranks on a node, with a weak-scaling contention factor (paper Fig. 2's
  rising, converging curves).

The model is validated claim-by-claim against the paper's quoted numbers in
``benchmarks/`` and EXPERIMENTS.md; constants live in
``repro/configs/comb_paper.py``.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class MachineModel:
    """Cost constants for a Quartz-class node (calibrated in configs/comb_paper)."""

    alpha: float = 1.6e-6  # per-transfer wire latency (s)
    nic_bw: float = 12.5e9  # node NIC bandwidth (bytes/s), Omni-Path 100 Gb/s
    mem_bw: float = 5.0e9  # on-node transfer bandwidth per rank-pair (bytes/s)
    o_msg: float = 1.1e-6  # host posting overhead per message (Isend/Irecv)
    o_persist_msg: float = 0.35e-6  # posting overhead per message (Start)
    o_persist_init: float = 25e-6  # one-time init per message (Send_init)
    o_part: float = 1.0e-6  # per-partition overhead (Pready + THREAD_MULTIPLE)
    pack_bw: float = 2.2e9  # pack/unpack bytes/s per OpenMP thread
    thread_launch: float = 4.0e-6  # per parallel-region launch cost
    socket_split_penalty: float = 2.0  # o_part multiplier when threads span sockets
    threads_per_socket: int = 32
    contention_base: int = 64  # procs at which contention starts
    contention_coef: float = 0.055  # beta multiplier growth per log2(procs)
    on_node_fraction: float = 0.55  # fraction of neighbor bytes staying on-node
    # --- persistent-path savings (Hatanaka'13-style: what *_init amortizes) ---
    proto_frac: float = 0.16  # per-byte protocol/registration overhead the
    #   standard path pays and persistent channels avoid (pre-pinned buffers)
    eager_threshold: int = 16384  # bytes; above it the standard path pays a
    rdv_rtt_factor: float = 2.0  # rendezvous RTS/CTS handshake of this many
    #   alphas per message (persistent pre-negotiates after init)
    # --- partitioned-path savings (paper §II-B: "utilizing the network early
    #   rather than sending all data at once") ---
    burst_penalty: float = 0.22  # incast/burst contention multiplier on beta
    #   when a rank injects all messages back-to-back after packing
    #   (standard & persistent); partitioned's staggered injection avoids it.
    burst_scale: float = 0.35  # growth of the burst penalty per log2(procs)
    #   beyond contention_base (congestion relief matters more at scale)
    # --- MPI_THREAD_MULTIPLE serialization (paper: "can cause slowdowns that
    #   vary greatly among versions of MPI") ---
    tm_coef: float = 0.06  # per-thread growth of o_part under THREAD_MULTIPLE
    cores: int = 32  # active cores per node (paper: 32 of 36)
    ht_eff: float = 0.25  # marginal efficiency of the 2nd hyperthread

    def beta_eff(self, nprocs: int, ranks_per_node: int) -> float:
        """Effective per-rank off-node seconds/byte including NIC sharing and
        at-scale contention."""
        share = self.nic_bw / max(1, ranks_per_node)
        beta = 1.0 / share
        if nprocs > self.contention_base:
            beta *= 1.0 + self.contention_coef * math.log2(
                nprocs / self.contention_base
            )
        return beta

    def burst_eff(self, nprocs: int) -> float:
        """Burst/incast penalty grows with job scale (more flows per switch)."""
        scale = 1.0
        if nprocs > self.contention_base:
            scale += self.burst_scale * math.log2(nprocs / self.contention_base)
        return self.burst_penalty * scale

    def pack_threads_eff(self, threads: int, ranks_per_node: int) -> float:
        """Packing threads beyond a rank's physical cores only add hyperthread
        headroom (paper runs 2 threads/core)."""
        rank_cores = max(1, self.cores // max(1, ranks_per_node))
        if threads <= rank_cores:
            return float(max(1, threads))
        return rank_cores + (threads - rank_cores) * self.ht_eff


@dataclass(frozen=True)
class StencilWorkload:
    """Per-rank halo-exchange workload for a 27-point 3-D stencil."""

    local_cells: tuple[int, int, int]
    vars_per_cell: int = 3
    halo: int = 1
    elem_bytes: int = 8  # doubles

    def messages(self) -> list[int]:
        """Byte sizes of the 26 neighbor messages (6 faces, 12 edges, 8 corners)."""
        nx, ny, nz = self.local_cells
        unit = self.vars_per_cell * self.elem_bytes * self.halo
        faces = [ny * nz, ny * nz, nx * nz, nx * nz, nx * ny, nx * ny]
        edges = [nx] * 4 + [ny] * 4 + [nz] * 4
        corners = [1] * 8
        return [c * unit for c in faces + edges + corners]

    @staticmethod
    def from_face_doubles(face_doubles: int, vars_per_cell: int = 3) -> "StencilWorkload":
        """Workload whose *face* messages carry ``face_doubles`` doubles
        (how Figs. 2 and 4 parametrize size)."""
        face_cells = max(1, face_doubles // vars_per_cell)
        n = max(1, round(face_cells ** 0.5))
        return StencilWorkload((n, n, n), vars_per_cell)

    @staticmethod
    def from_global_mesh(
        global_cells: tuple[int, int, int], nprocs: int, vars_per_cell: int = 3
    ) -> "StencilWorkload":
        """Split a global mesh over ``nprocs`` (near-cubic process grid)."""
        grid = _near_cubic_grid(nprocs)
        local = tuple(
            max(1, g // p) for g, p in zip(global_cells, grid)
        )
        return StencilWorkload(local, vars_per_cell)  # type: ignore[arg-type]


def _near_cubic_grid(n: int) -> tuple[int, int, int]:
    best = (n, 1, 1)
    best_score = float("inf")
    for a in range(1, int(round(n ** (1 / 3))) + 2):
        if n % a:
            continue
        m = n // a
        for b in range(a, int(math.isqrt(m)) + 1):
            if m % b:
                continue
            c = m // b
            dims = (a, b, c)
            score = max(dims) / min(dims)
            if score < best_score:
                best_score, best = score, dims
    return best


@dataclass
class TimeBreakdown:
    pack: float = 0.0
    post: float = 0.0
    net_exposed: float = 0.0  # network time not hidden behind packing
    unpack: float = 0.0
    part_overhead: float = 0.0
    thread_launch: float = 0.0
    init_amortized: float = 0.0

    @property
    def total(self) -> float:
        return (
            self.pack
            + self.post
            + self.net_exposed
            + self.unpack
            + self.part_overhead
            + self.thread_launch
            + self.init_amortized
        )


def _pack_finish_times(
    items: list[int], threads: int, pack_bw: float
) -> list[float]:
    """Round-robin the pack work items over ``threads``; return each item's
    completion time (staggered — this is what partitioned overlap exploits)."""
    t = [0.0] * max(1, threads)
    finish = []
    for i, nbytes in enumerate(items):
        th = i % max(1, threads)
        t[th] += nbytes / pack_bw
        finish.append(t[th])
    return finish


def simulate(
    strategy: str,
    machine: MachineModel,
    workload: StencilWorkload,
    *,
    nprocs: int,
    ranks_per_node: int = 32,
    threads: int = 2,
    n_parts: int | None = None,
    iters: int = 1000,
) -> TimeBreakdown:
    """Model one rank's halo-exchange iteration cost (seconds) under a strategy.

    ``n_parts`` defaults to ``threads`` (the paper binds one partition per
    packing thread).  ``iters`` only affects amortized persistent init.
    """
    assert strategy in ("standard", "persistent", "partitioned"), strategy
    msgs = workload.messages()
    n_msgs = len(msgs)
    total_bytes = sum(msgs)
    beta_off = machine.beta_eff(nprocs, ranks_per_node)
    beta_on = 1.0 / machine.mem_bw
    beta = (
        machine.on_node_fraction * beta_on
        + (1.0 - machine.on_node_fraction) * beta_off
    )
    if nprocs <= ranks_per_node:
        beta = beta_on  # single-node job: all neighbors on-node
    teff = machine.pack_threads_eff(threads, ranks_per_node)
    tb = TimeBreakdown()
    tb.thread_launch = 2 * machine.thread_launch  # pack + unpack regions
    tb.unpack = total_bytes / (machine.pack_bw * teff)

    if strategy in ("standard", "persistent"):
        # Alg. 1 / Alg. 3: pack everything, then post, then wait.
        tb.pack = total_bytes / (machine.pack_bw * teff)
        o = machine.o_msg if strategy == "standard" else machine.o_persist_msg
        tb.post = o * n_msgs
        # NIC serializes the injections after packing completes; the
        # back-to-back burst pays an incast/contention penalty that grows
        # with job scale.
        beta_burst = beta * (1.0 + machine.burst_eff(nprocs))
        net = 0.0
        for nbytes in msgs:
            net += machine.alpha + nbytes * beta_burst
            if strategy == "standard":
                # per-iteration protocol work the persistent channel avoids:
                # buffer registration/bookkeeping (per byte) + rendezvous
                # handshake for large messages.
                net += nbytes * beta * machine.proto_frac
                if nbytes > machine.eager_threshold:
                    net += machine.rdv_rtt_factor * machine.alpha
        tb.net_exposed = net
        if strategy == "persistent":
            tb.init_amortized = machine.o_persist_init * n_msgs / max(1, iters)
        return tb

    # partitioned (Alg. 6): Startall, then threads pack partitions and Pready
    # each as it completes; transfers overlap remaining packing.  Every
    # message is split into P equal partitions (padding per the standard).
    P = max(1, n_parts if n_parts is not None else threads)
    tb.post = machine.o_persist_msg * n_msgs
    # MPI_THREAD_MULTIPLE: concurrent Pready/progress calls serialize inside
    # the library; the per-partition cost grows with thread count, and doubles
    # again when the thread team spans sockets (paper Fig. 5's 1-rank cliff).
    o_part = machine.o_part * (1.0 + machine.tm_coef * threads)
    if threads > machine.threads_per_socket:
        o_part *= machine.socket_split_penalty
    items = [nbytes / P for nbytes in msgs for _ in range(P)]
    tb.part_overhead = o_part * len(items)
    ready = _pack_finish_times(items, int(round(teff)), machine.pack_bw)
    # NIC queue: staggered injections — no burst penalty (the paper's "early
    # communication reduces network contention").
    nic_free = 0.0
    done = 0.0
    for r, wire in sorted(zip(ready, items)):
        start = max(r, nic_free)
        nic_free = start + machine.alpha + wire * beta
        done = nic_free
    pack_all = max(ready) if ready else 0.0
    tb.pack = pack_all
    tb.net_exposed = max(0.0, done - pack_all)
    tb.init_amortized = machine.o_persist_init * n_msgs / max(1, iters)
    return tb


def speedup(base: TimeBreakdown, other: TimeBreakdown) -> float:
    """Paper-style speedup of ``other`` over ``base`` in percent."""
    return (base.total / other.total - 1.0) * 100.0
