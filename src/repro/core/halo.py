"""N-dimensional halo (ghost-cell) exchange on a named device mesh.

This is the JAX port of the paper's stencil boundary exchange (Comb's
communication core), with the three strategies under study:

* ``standard``   — the non-blocking baseline: slabs packed and sent as whole
  messages each iteration; the driver re-derives the plan per call
  (``core.plan.dispatch_standard``).
* ``persistent`` — identical data movement, but the whole exchange step is an
  AOT-compiled :class:`~repro.core.plan.CommPlan` with permutation tables
  precomputed at init (``MPI_Send_init`` analogue).
* ``partitioned``— every face slab is split into ``n_parts`` equal partitions
  (offsets per the paper's equal-size rule); each partition is packed, sent,
  and **unpacked into the ghost region immediately on arrival** (early work /
  ``MPI_Parrived``), giving XLA per-partition overlap freedom.

All data movement is described as :class:`repro.core.transport.Message`
tables — this module only *assembles schedules* (which slab goes where) and
delegates every pack -> send -> unpack to the transport layer, so the packer
(inline ``slice`` staging vs the ``pallas`` copy kernel) and the transport
backend (in-process ``ppermute`` vs a multi-host backend) are swappable knobs
on :class:`HaloSpec` rather than code paths.

Corner/edge handling uses the axis-by-axis trick: exchanging full-extent slabs
(including already-filled ghost rims of previously exchanged axes) propagates
edge and corner values in D passes instead of 3^D - 1 point-to-point
messages.  On a TPU torus this maps each face exchange onto a neighbor
``ppermute`` — the native ICI transport (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat
from repro.core.transport import (
    Message,
    Partitioner,
    ScheduleInfo,
    exchange_messages,
    resolve_packer,
    resolve_transport,
)

STRATEGIES = ("standard", "persistent", "partitioned")


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Describes one halo exchange.

    ``mesh_axes[i]`` is the named mesh axis that decomposes array axis
    ``array_axes[i]``.  ``halo`` is the ghost width (paper: 1).
    ``packer``/``transport`` name the registered transport-layer backends
    every message of this exchange goes through
    (:mod:`repro.core.transport`); ``coalesce`` aggregates each delivery
    group's messages into one wire buffer + ONE composed collective per hop
    chain (default on — the pMR message-aggregation optimization).
    ``mapping`` names the registered process-to-node placement the mesh was
    built under (:mod:`repro.launch.mapping`): it never changes the
    schedule the spec assembles (the tables are a pure function of mesh
    shape), but it IS part of the exchange's identity — it lands in
    :class:`~repro.core.transport.ScheduleInfo` and therefore in every
    persistent plan key.
    """

    mesh_axes: tuple[str, ...]
    array_axes: tuple[int, ...]
    halo: int = 1
    periodic: bool = True
    #: label only — any registered strategy name (the paper trio is
    #: STRATEGIES); transport behavior is carried by ``n_parts``.
    strategy: str = "standard"
    n_parts: int = 1
    packer: str = "slice"
    transport: str = "ppermute"
    coalesce: bool = True
    mapping: str = "row-major"
    #: autotune provenance ("trace"/"model"/"calibration"/...) when this
    #: exchange's cell was picked by :mod:`repro.core.autotune`; ``None``
    #: for caller-pinned cells.  Part of the plan identity: an autotuned
    #: plan never silently aliases a hand-pinned one.
    selected_by: str | None = None
    #: membership epoch of the mesh this exchange targets
    #: (:mod:`repro.launch.membership`).  Bumped on every JOIN / in-grid
    #: LOSS re-formation; part of the plan identity so a plan compiled
    #: against a dead topology can never be a cache hit on the re-formed
    #: mesh.  ``None`` = outside the membership domain (never
    #: epoch-invalidated); 0 = stamped formation epoch.
    epoch: int | None = None

    def __post_init__(self):
        assert len(self.mesh_axes) == len(self.array_axes)
        assert self.strategy, "strategy label must be non-empty"
        assert self.n_parts >= 1, self.n_parts
        # unknown backend names fail at the spec's construction site, not
        # buried in a shard_map trace stack (mirrors StrategyConfig)
        from repro.core.transport import get_packer, get_transport
        from repro.launch.mapping import canonical_mapping

        get_packer(self.packer)
        get_transport(self.transport)
        # aliases ("rb") canonicalize here so equal placements hash equal
        # wherever the spec becomes a plan key
        object.__setattr__(self, "mapping", canonical_mapping(self.mapping))

    def with_(self, **kw) -> "HaloSpec":
        return dataclasses.replace(self, **kw)

    def schedule_info(self, kind: str) -> ScheduleInfo:
        return ScheduleInfo(
            kind=kind, mesh_axes=self.mesh_axes,
            packer=self.packer, transport=self.transport,
            coalesce=self.coalesce, mapping=self.mapping,
            selected_by=self.selected_by, epoch=self.epoch,
        )


# ---------------------------------------------------------------------------
# schedule assembly: HaloSpec + block shape -> Message tables
# ---------------------------------------------------------------------------


def _neighbor_perms(k: int, periodic: bool) -> tuple[tuple, tuple]:
    """(to_left, to_right) source-target tables — precomputed at trace time,
    i.e. once per plan: the persistent 'envelope'."""
    to_left = tuple((i, (i - 1) % k) for i in range(k) if periodic or i > 0)
    to_right = tuple((i, (i + 1) % k) for i in range(k) if periodic or i < k - 1)
    return to_left, to_right


def _tangent_axis(shape: Sequence[int], array_axis: int) -> int:
    """Pick the largest non-exchange axis to partition a slab along."""
    ndim = len(shape)
    best, best_size = (array_axis + 1) % ndim, -1
    for a in range(ndim):
        if a != array_axis and shape[a] > best_size:
            best, best_size = a, shape[a]
    return best


def _mesh_sizes(spec: HaloSpec) -> dict[str, int]:
    """Axis sizes inside ``shard_map`` (trace-time python ints)."""
    return {name: compat.axis_size(name) for name in spec.mesh_axes}


def axis_message_group(
    shape: tuple[int, ...],
    axis_name: str,
    array_axis: int,
    *,
    k: int,
    halo: int,
    periodic: bool = True,
    n_parts: int = 1,
) -> tuple[Message, ...]:
    """The two messages of one sequential axis pass.

    The local block layout along ``array_axis`` is
    ``[left ghost | interior ... interior | right ghost]`` with ghost width
    ``halo``.  Slabs span the *full* extent of all other axes (ghosts
    included) so sequential per-axis passes fill edges/corners.  ``k`` is
    the mesh-axis size (``k == 1`` periodic degenerates to a hop-free
    self-wrap; ``k == 1`` non-periodic to no messages at all).
    """
    size = shape[array_axis]
    assert size >= 3 * halo, (size, halo)
    if k == 1 and not periodic:
        return ()
    to_left, to_right = _neighbor_perms(k, periodic)
    left_hops = ((axis_name, to_left),) if k > 1 else ()
    right_hops = ((axis_name, to_right),) if k > 1 else ()

    # a face is a width-``halo`` point in 1-D: no tangent axis to partition
    # along, so partitioned degenerates to the whole-message exchange (the
    # paper's 1-partition case).
    part_axis = None
    if n_parts > 1 and len(shape) > 1:
        part_axis = _tangent_axis(shape, array_axis)
    eff_parts = n_parts if part_axis is not None else 1

    def window(src_edge: int, dst_edge: int) -> tuple[tuple, tuple, tuple]:
        src = [0] * len(shape)
        dst = [0] * len(shape)
        sz = list(shape)
        src[array_axis], dst[array_axis], sz[array_axis] = (
            src_edge, dst_edge, halo,
        )
        return tuple(src), tuple(dst), tuple(sz)

    # left interiors travel left and fill the *right* ghosts there (and the
    # mirror for right interiors) — the SPMD view of "recv from my right".
    left = Message(*window(halo, size - halo), left_hops,
                   n_parts=eff_parts, part_axis=part_axis)
    right = Message(*window(size - 2 * halo, 0), right_hops,
                    n_parts=eff_parts, part_axis=part_axis)
    return (left, right)


def sequential_message_groups(
    shape: tuple[int, ...],
    spec: HaloSpec,
    sizes: Mapping[str, int],
) -> tuple[tuple[Message, ...], ...]:
    """The sequential schedule: one message group per decomposed axis.

    Group *i+1* packs from the buffer group *i* unpacked into, so the
    full-extent slabs carry previously refreshed ghost rims — the D-pass
    corner trick.
    """
    return tuple(
        axis_message_group(
            shape, axis_name, array_axis, k=sizes[axis_name],
            halo=spec.halo, periodic=spec.periodic, n_parts=spec.n_parts,
        )
        for axis_name, array_axis in zip(spec.mesh_axes, spec.array_axes)
    )


def exchange_axis(
    x: jax.Array,
    axis_name: str,
    array_axis: int,
    *,
    halo: int,
    periodic: bool = True,
    n_parts: int = 1,
    packer: str = "slice",
    transport: str = "ppermute",
    coalesce: bool = True,
) -> jax.Array:
    """Exchange ghost rims along one decomposed axis (inside ``shard_map``)."""
    group = axis_message_group(
        x.shape, axis_name, array_axis, k=compat.axis_size(axis_name),
        halo=halo, periodic=periodic, n_parts=n_parts,
    )
    return exchange_messages(x, (group,), packer=packer, transport=transport,
                             coalesce=coalesce)


def exchange(x: jax.Array, spec: HaloSpec) -> jax.Array:
    """Full halo exchange (all decomposed axes, corners included).

    Must be called inside ``shard_map`` over the mesh axes in ``spec``.
    ``spec.n_parts`` alone selects whole-message vs partitioned transport —
    strategies that don't partition build their specs with ``n_parts=1``
    (``ExchangeStrategy.build_spec``), so custom registered strategies can
    opt in without being named "partitioned".  ``spec.packer`` and
    ``spec.transport`` select the registered backends every message goes
    through.
    """
    groups = sequential_message_groups(x.shape, spec, _mesh_sizes(spec))
    return exchange_messages(
        x, groups, packer=spec.packer, transport=spec.transport,
        coalesce=spec.coalesce,
    )


# ---------------------------------------------------------------------------
# fused multi-axis exchange (all faces/edges/corners in one pass)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedSlab:
    """One message of the fused exchange: a face, edge, or corner block.

    ``offsets[i]`` is the direction (-1/0/+1) along the i-th *decomposed*
    axis of ``HaloSpec``; the block travels one hop per non-zero offset
    (a corner message chains one ``ppermute`` per involved mesh axis).
    Starts/shape are in local ghosted-block coordinates.
    """

    offsets: tuple[int, ...]
    src_start: tuple[int, ...]
    dst_start: tuple[int, ...]
    shape: tuple[int, ...]


def fused_slab_table(
    shape: tuple[int, ...], spec: HaloSpec
) -> tuple[FusedSlab, ...]:
    """The fused-pass slab assembler: every neighbor message of one step.

    Where the sequential schedule exchanges D full-extent slabs (one per
    axis, each pass depending on the previous pass's ghosts), the fused
    schedule posts all ``3^D - 1`` face/edge/corner messages from the
    *original* buffer: for each direction vector the source block is the
    matching interior face/edge/corner and the destination is the opposite
    ghost region.  No message depends on another, so XLA is free to overlap
    all packs, sends, and unpacks — the fused analogue of Comb's single
    combined pack kernel.
    """
    h = spec.halo
    table = []
    for offs in itertools.product((-1, 0, 1), repeat=len(spec.array_axes)):
        if not any(offs):
            continue
        src = [0] * len(shape)
        dst = [0] * len(shape)
        size = list(shape)
        for o, a in zip(offs, spec.array_axes):
            s = shape[a]
            assert s >= 3 * h, (s, h)
            if o == +1:  # rightmost interior -> right neighbor's left ghost
                src[a], size[a], dst[a] = s - 2 * h, h, 0
            elif o == -1:  # leftmost interior -> left neighbor's right ghost
                src[a], size[a], dst[a] = h, h, s - h
            else:  # not travelling along this axis: span its interior
                src[a], size[a], dst[a] = h, s - 2 * h, h
        table.append(
            FusedSlab(offs, tuple(src), tuple(dst), tuple(size))
        )
    return tuple(table)


def fused_message_group(
    shape: tuple[int, ...],
    spec: HaloSpec,
    sizes: Mapping[str, int],
) -> tuple[Message, ...]:
    """The fused schedule as ONE independent message group.

    Every :class:`FusedSlab` becomes a :class:`Message` whose hop chain
    crosses one mesh axis per non-zero direction offset (edges/corners hop
    multiple times); a single-shard non-periodic axis elides the messages
    that would have to cross it.
    """
    perms = {
        name: _neighbor_perms(sizes[name], spec.periodic)
        for name in spec.mesh_axes
    }
    group = []
    for slab in fused_slab_table(shape, spec):
        if not spec.periodic and any(
            o != 0 and sizes[name] == 1
            for o, name in zip(slab.offsets, spec.mesh_axes)
        ):
            continue  # single-shard non-periodic axis: no neighbor to cross
        hops = []
        for o, name in zip(slab.offsets, spec.mesh_axes):
            if o == +1:
                hops.append((name, perms[name][1]))  # to_right
            elif o == -1:
                hops.append((name, perms[name][0]))  # to_left
        group.append(
            Message(slab.src_start, slab.dst_start, slab.shape, tuple(hops))
        )
    return tuple(group)


def exchange_fused(x: jax.Array, spec: HaloSpec) -> jax.Array:
    """Full halo exchange as ONE fused pass (corners sent directly).

    Must be called inside ``shard_map`` over the mesh axes in ``spec``.
    Produces bit-identical ghosts to the sequential :func:`exchange` (values
    are only copied, never combined), but with no inter-axis data
    dependency: all slabs are packed from the input buffer, every message is
    routed independently (edges/corners hop once per involved axis), and
    all unpacks land in disjoint ghost regions.
    """
    group = fused_message_group(x.shape, spec, _mesh_sizes(spec))
    return exchange_messages(
        x, (group,), packer=spec.packer, transport=spec.transport,
        coalesce=spec.coalesce,
    )


# ---------------------------------------------------------------------------
# outer drivers (build shard_map'd steps over a mesh)
# ---------------------------------------------------------------------------


def ghost_pspec(spec: HaloSpec, ndim: int) -> P:
    entries: list[str | None] = [None] * ndim
    for name, a in zip(spec.mesh_axes, spec.array_axes):
        entries[a] = name
    return P(*entries)


def build_exchange_step(
    mesh: Mesh,
    spec: HaloSpec,
    ndim: int,
    update_fn: Callable[[jax.Array], jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """One stencil iteration: halo exchange, then (optionally) local update.

    The returned callable maps a *globally sharded* array (each shard carrying
    its own ghost rims) to the updated array with refreshed ghosts.
    """

    pspec = ghost_pspec(spec, ndim)

    def step(x: jax.Array) -> jax.Array:
        x = exchange(x, spec)
        if update_fn is not None:
            x = update_fn(x)
        return x

    return compat.shard_map(step, mesh=mesh, in_specs=pspec, out_specs=pspec)


# ---------------------------------------------------------------------------
# 1-D sequence halo for LM sequence parallelism (conv / local-attention)
# ---------------------------------------------------------------------------


def seq_left_halo(
    x: jax.Array,
    axis_name: str,
    width: int,
    *,
    seq_axis: int = 1,
    n_parts: int = 1,
    packer: str = "slice",
    transport: str = "ppermute",
) -> jax.Array:
    """Prepend the last ``width`` positions of the left neighbor's shard
    (zeros for rank 0): the ghost cells a causal conv (zamba2's conv1d) needs
    under sequence parallelism.  Returns length ``width + local_seq``.
    """
    p = resolve_packer(packer)
    t = resolve_transport(transport)
    k = compat.axis_size(axis_name)
    size = x.shape[seq_axis]
    start = [0] * x.ndim
    start[seq_axis] = size - width
    slab_shape = list(x.shape)
    slab_shape[seq_axis] = width
    halo = jnp.zeros(tuple(slab_shape), x.dtype)
    if k > 1:
        perm = [(i, i + 1) for i in range(k - 1)]  # non-periodic: causal
        if n_parts > 1:
            # per-partition pack -> hop -> unpack-on-arrival (clipped windows
            # on the equal-size grid, as the halo transport does)
            t_axis = 0 if seq_axis != 0 else (1 if x.ndim > 1 else 0)
            for off, w in Partitioner(n_parts, t_axis).slices(
                slab_shape[t_axis]
            ):
                if w <= 0:
                    continue
                sub_start = list(start)
                sub_start[t_axis] += off
                sub_shape = list(slab_shape)
                sub_shape[t_axis] = w
                buf = t.permute(p.pack(x, sub_start, sub_shape),
                                axis_name, perm)
                dst = [0] * x.ndim
                dst[t_axis] = off
                halo = p.unpack(halo, buf, dst, sub_shape)
        else:
            buf = t.permute(p.pack(x, start, slab_shape), axis_name, perm)
            halo = p.unpack(halo, buf, [0] * x.ndim, slab_shape)
        idx = jax.lax.axis_index(axis_name)
        halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
    return jnp.concatenate([halo, x], axis=seq_axis)
