"""N-dimensional halo (ghost-cell) exchange on a named device mesh.

This is the JAX port of the paper's stencil boundary exchange (Comb's
communication core), with the three strategies under study:

* ``standard``   — the non-blocking baseline: slabs sliced ("packed") and sent
  as whole messages each iteration; the driver re-derives the plan per call
  (``core.plan.dispatch_standard``).
* ``persistent`` — identical data movement, but the whole exchange step is an
  AOT-compiled :class:`~repro.core.plan.CommPlan` with permutation tables
  precomputed at init (``MPI_Send_init`` analogue).
* ``partitioned``— every face slab is split into ``n_parts`` equal partitions
  (padding per the paper's equal-size rule); each partition is packed, sent,
  and **unpacked into the ghost region immediately on arrival** (early work /
  ``MPI_Parrived``), giving XLA per-partition overlap freedom.

Corner/edge handling uses the axis-by-axis trick: exchanging full-extent slabs
(including already-filled ghost rims of previously exchanged axes) propagates
edge and corner values in D passes instead of 3^D - 1 point-to-point
messages.  On a TPU torus this maps each face exchange onto a neighbor
``ppermute`` — the native ICI transport (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.partitioned import Partitioner

STRATEGIES = ("standard", "persistent", "partitioned")


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Describes one halo exchange.

    ``mesh_axes[i]`` is the named mesh axis that decomposes array axis
    ``array_axes[i]``.  ``halo`` is the ghost width (paper: 1).
    """

    mesh_axes: tuple[str, ...]
    array_axes: tuple[int, ...]
    halo: int = 1
    periodic: bool = True
    #: label only — any registered strategy name (the paper trio is
    #: STRATEGIES); transport behavior is carried by ``n_parts``.
    strategy: str = "standard"
    n_parts: int = 1

    def __post_init__(self):
        assert len(self.mesh_axes) == len(self.array_axes)
        assert self.strategy, "strategy label must be non-empty"
        assert self.n_parts >= 1, self.n_parts

    def with_(self, **kw) -> "HaloSpec":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# the exchange (runs inside shard_map)
# ---------------------------------------------------------------------------


def _neighbor_perms(axis_name: str, periodic: bool) -> tuple[list, list]:
    """(to_left, to_right) source-target tables — precomputed at trace time,
    i.e. once per plan: the persistent 'envelope'."""
    k = compat.axis_size(axis_name)
    to_left = [(i, (i - 1) % k) for i in range(k) if periodic or i > 0]
    to_right = [(i, (i + 1) % k) for i in range(k) if periodic or i < k - 1]
    return to_left, to_right


def _tangent_axis(x: jax.Array, array_axis: int) -> int:
    """Pick the largest non-exchange axis to partition a slab along."""
    best, best_size = (array_axis + 1) % x.ndim, -1
    for a in range(x.ndim):
        if a != array_axis and x.shape[a] > best_size:
            best, best_size = a, x.shape[a]
    return best


def exchange_axis(
    x: jax.Array,
    axis_name: str,
    array_axis: int,
    *,
    halo: int,
    periodic: bool = True,
    n_parts: int = 1,
) -> jax.Array:
    """Exchange ghost rims along one decomposed axis.

    The local block layout along ``array_axis`` is
    ``[left ghost | interior ... interior | right ghost]`` with ghost width
    ``halo``.  Slabs span the *full* extent of all other axes (ghosts
    included) so sequential per-axis passes fill edges/corners.
    """
    k = compat.axis_size(axis_name)
    size = x.shape[array_axis]
    assert size >= 3 * halo, (size, halo)
    to_left, to_right = _neighbor_perms(axis_name, periodic)

    if k == 1:
        if not periodic:
            return x
        # self-exchange: wrap interior edges into own ghosts
        left_int = lax.slice_in_dim(x, halo, 2 * halo, axis=array_axis)
        right_int = lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=array_axis)
        x = _write(x, right_int, array_axis, 0)
        x = _write(x, left_int, array_axis, size - halo)
        return x

    # pack: interior edge slabs (the contiguous-buffer copy in the paper)
    left_int = lax.slice_in_dim(x, halo, 2 * halo, axis=array_axis)
    right_int = lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=array_axis)

    if n_parts <= 1 or x.ndim == 1:
        # whole-message exchange (standard & persistent strategies).  1-D
        # blocks also land here: a face is a width-``halo`` point with no
        # tangent axis to partition along, so partitioned degenerates to the
        # persistent single-message exchange (the paper's 1-partition case).
        from_right = lax.ppermute(left_int, axis_name, to_left)
        from_left = lax.ppermute(right_int, axis_name, to_right)
        x = _write(x, from_left, array_axis, 0)
        x = _write(x, from_right, array_axis, size - halo)
        return x

    # partitioned: split each face along a tangent axis; each partition is
    # packed -> sent -> unpacked-on-arrival independently.
    t_axis = _tangent_axis(x, array_axis)
    part = Partitioner(n_parts, t_axis)
    t_size = x.shape[t_axis]
    csize = part.part_size(t_size)
    bounds = part.slices(t_size)  # equal-size rule; tail width clipped
    for dir_slab, perm, ghost_start in (
        (left_int, to_left, size - halo),  # left interiors fill right ghosts
        (right_int, to_right, 0),  # right interiors fill left ghosts
    ):
        for chunk, (off, width) in zip(part.split(dir_slab), bounds):
            arrived = lax.ppermute(chunk, axis_name, perm)  # Pstart/Pready
            if width <= 0:
                continue  # all-padding tail partition: sent (the partition
                # count is fixed at init, as in MPI), nothing to unpack
            if width < csize:  # unpad tail partition
                arrived = lax.slice_in_dim(arrived, 0, width, axis=t_axis)
            x = _write(x, arrived, array_axis, ghost_start, t_axis, off)  # Parrived
    return x


def _write(
    x: jax.Array,
    slab: jax.Array,
    array_axis: int,
    start: int,
    t_axis: int | None = None,
    t_start: int = 0,
) -> jax.Array:
    starts = [0] * x.ndim
    starts[array_axis] = start
    if t_axis is not None:
        starts[t_axis] = t_start
    return lax.dynamic_update_slice(x, slab, tuple(starts))


def exchange(x: jax.Array, spec: HaloSpec) -> jax.Array:
    """Full halo exchange (all decomposed axes, corners included).

    Must be called inside ``shard_map`` over the mesh axes in ``spec``.
    ``spec.n_parts`` alone selects whole-message vs partitioned transport —
    strategies that don't partition build their specs with ``n_parts=1``
    (``ExchangeStrategy.build_spec``), so custom registered strategies can
    opt in without being named "partitioned".
    """
    n_parts = spec.n_parts
    for axis_name, array_axis in zip(spec.mesh_axes, spec.array_axes):
        x = exchange_axis(
            x,
            axis_name,
            array_axis,
            halo=spec.halo,
            periodic=spec.periodic,
            n_parts=n_parts,
        )
    return x


# ---------------------------------------------------------------------------
# fused multi-axis exchange (all faces/edges/corners in one pass)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedSlab:
    """One message of the fused exchange: a face, edge, or corner block.

    ``offsets[i]`` is the direction (-1/0/+1) along the i-th *decomposed*
    axis of ``HaloSpec``; the block travels one hop per non-zero offset
    (a corner message chains one ``ppermute`` per involved mesh axis).
    Starts/shape are in local ghosted-block coordinates.
    """

    offsets: tuple[int, ...]
    src_start: tuple[int, ...]
    dst_start: tuple[int, ...]
    shape: tuple[int, ...]


def fused_slab_table(
    shape: tuple[int, ...], spec: HaloSpec
) -> tuple[FusedSlab, ...]:
    """The fused-pass slab assembler: every neighbor message of one step.

    Where the sequential schedule exchanges D full-extent slabs (one per
    axis, each pass depending on the previous pass's ghosts), the fused
    schedule posts all ``3^D - 1`` face/edge/corner messages from the
    *original* buffer: for each direction vector the source block is the
    matching interior face/edge/corner and the destination is the opposite
    ghost region.  No message depends on another, so XLA is free to overlap
    all packs, sends, and unpacks — the fused analogue of Comb's single
    combined pack kernel.
    """
    h = spec.halo
    table = []
    for offs in itertools.product((-1, 0, 1), repeat=len(spec.array_axes)):
        if not any(offs):
            continue
        src = [0] * len(shape)
        dst = [0] * len(shape)
        size = list(shape)
        for o, a in zip(offs, spec.array_axes):
            s = shape[a]
            assert s >= 3 * h, (s, h)
            if o == +1:  # rightmost interior -> right neighbor's left ghost
                src[a], size[a], dst[a] = s - 2 * h, h, 0
            elif o == -1:  # leftmost interior -> left neighbor's right ghost
                src[a], size[a], dst[a] = h, h, s - h
            else:  # not travelling along this axis: span its interior
                src[a], size[a], dst[a] = h, s - 2 * h, h
        table.append(
            FusedSlab(offs, tuple(src), tuple(dst), tuple(size))
        )
    return tuple(table)


def exchange_fused(x: jax.Array, spec: HaloSpec) -> jax.Array:
    """Full halo exchange as ONE fused pass (corners sent directly).

    Must be called inside ``shard_map`` over the mesh axes in ``spec``.
    Produces bit-identical ghosts to the sequential :func:`exchange` (values
    are only copied, never combined), but with no inter-axis data
    dependency: all slabs are packed from the input buffer, every message is
    ppermuted independently (edges/corners hop once per involved axis), and
    all unpacks land in disjoint ghost regions.
    """
    perms = {
        name: _neighbor_perms(name, spec.periodic) for name in spec.mesh_axes
    }
    sizes = {name: compat.axis_size(name) for name in spec.mesh_axes}
    arrived: list[tuple[FusedSlab, jax.Array]] = []
    for slab in fused_slab_table(x.shape, spec):
        if not spec.periodic and any(
            o != 0 and sizes[name] == 1
            for o, name in zip(slab.offsets, spec.mesh_axes)
        ):
            continue  # single-shard non-periodic axis: no neighbor to cross
        limits = [st + sz for st, sz in zip(slab.src_start, slab.shape)]
        chunk = lax.slice(x, slab.src_start, limits)  # pack
        for o, name in zip(slab.offsets, spec.mesh_axes):
            if o == +1:
                chunk = lax.ppermute(chunk, name, perms[name][1])  # to_right
            elif o == -1:
                chunk = lax.ppermute(chunk, name, perms[name][0])  # to_left
        arrived.append((slab, chunk))
    for slab, chunk in arrived:  # unpack (disjoint ghost regions)
        x = lax.dynamic_update_slice(x, chunk, slab.dst_start)
    return x


# ---------------------------------------------------------------------------
# outer drivers (build shard_map'd steps over a mesh)
# ---------------------------------------------------------------------------


def ghost_pspec(spec: HaloSpec, ndim: int) -> P:
    entries: list[str | None] = [None] * ndim
    for name, a in zip(spec.mesh_axes, spec.array_axes):
        entries[a] = name
    return P(*entries)


def build_exchange_step(
    mesh: Mesh,
    spec: HaloSpec,
    ndim: int,
    update_fn: Callable[[jax.Array], jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """One stencil iteration: halo exchange, then (optionally) local update.

    The returned callable maps a *globally sharded* array (each shard carrying
    its own ghost rims) to the updated array with refreshed ghosts.
    """

    pspec = ghost_pspec(spec, ndim)

    def step(x: jax.Array) -> jax.Array:
        x = exchange(x, spec)
        if update_fn is not None:
            x = update_fn(x)
        return x

    return compat.shard_map(step, mesh=mesh, in_specs=pspec, out_specs=pspec)


# ---------------------------------------------------------------------------
# 1-D sequence halo for LM sequence parallelism (conv / local-attention)
# ---------------------------------------------------------------------------


def seq_left_halo(
    x: jax.Array,
    axis_name: str,
    width: int,
    *,
    seq_axis: int = 1,
    n_parts: int = 1,
) -> jax.Array:
    """Prepend the last ``width`` positions of the left neighbor's shard
    (zeros for rank 0): the ghost cells a causal conv (zamba2's conv1d) needs
    under sequence parallelism.  Returns length ``width + local_seq``.
    """
    k = compat.axis_size(axis_name)
    size = x.shape[seq_axis]
    tail = lax.slice_in_dim(x, size - width, size, axis=seq_axis)
    if k == 1:
        halo = jnp.zeros_like(tail)
    else:
        perm = [(i, i + 1) for i in range(k - 1)]  # non-periodic: causal
        if n_parts > 1:
            t_axis = 0 if seq_axis != 0 else (1 if x.ndim > 1 else 0)
            part = Partitioner(n_parts, t_axis)
            chunks = [lax.ppermute(c, axis_name, perm) for c in part.split(tail)]
            halo = part.merge(chunks, tail.shape[t_axis])
        else:
            halo = lax.ppermute(tail, axis_name, perm)
        idx = lax.axis_index(axis_name)
        halo = jnp.where(idx == 0, jnp.zeros_like(halo), halo)
    return jnp.concatenate([halo, x], axis=seq_axis)
