# The paper's primary contribution, as composable JAX modules:
#   transport    — unified pack/transport layer (Message/Packer/Transport)
#   plan         — persistent communication/step plans (MPI_Send_init analogue)
#   partitioned  — chunked early-consume collectives (MPI partitioned analogue)
#   halo         — N-D ghost-cell exchange with standard/persistent/partitioned
#   ring         — ring attention + recurrent-state passing (LM integrations)
#   model_comm   — analytic LogGP-style model of the paper's measurements
#   hlo_analysis — collective wire-byte parsing + roofline terms

from repro.core.transport import (
    Message,
    Packer,
    ScheduleInfo,
    Transport,
    available_packers,
    available_transports,
    get_packer,
    get_transport,
    register_packer,
    register_transport,
)
from repro.core.plan import CommPlan, PlanCache, PLANS, persistent, dispatch_standard
from repro.core.partitioned import (
    Partitioner,
    partitioned_ppermute,
    partitioned_all_to_all,
    partitioned_psum,
    partitioned_psum_scatter,
    ring_all_gather,
    ring_all_gather_matmul,
    ring_matmul_reduce_scatter,
    bucketed_psum_tree,
    ring_perm,
)
from repro.core.halo import HaloSpec, exchange, exchange_axis, build_exchange_step, seq_left_halo
from repro.core.ring import ring_attention, state_passing
from repro.core.model_comm import MachineModel, StencilWorkload, TimeBreakdown, simulate, speedup
from repro.core.hlo_analysis import parse_collectives, roofline, RooflineTerms, Hardware, V5E

__all__ = [
    "Message", "Packer", "Transport", "ScheduleInfo",
    "available_packers", "available_transports", "get_packer",
    "get_transport", "register_packer", "register_transport",
    "CommPlan", "PlanCache", "PLANS", "persistent", "dispatch_standard",
    "Partitioner", "partitioned_ppermute", "partitioned_all_to_all",
    "partitioned_psum", "partitioned_psum_scatter", "ring_all_gather",
    "ring_all_gather_matmul", "ring_matmul_reduce_scatter", "bucketed_psum_tree",
    "ring_perm", "HaloSpec", "exchange", "exchange_axis", "build_exchange_step",
    "seq_left_halo", "ring_attention", "state_passing",
    "MachineModel", "StencilWorkload", "TimeBreakdown", "simulate", "speedup",
    "parse_collectives", "roofline", "RooflineTerms", "Hardware", "V5E",
]
