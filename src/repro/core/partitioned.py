"""Partitioned (chunked, early-consume) collectives — the MPI-partitioned analogue.

The paper's partitioned communication (`MPI_Psend_init`/`Pstart`/`Pready`/
`Parrived`) splits one persistent message into equal partitions so that

  1. the transfer of partition *k* overlaps the packing of partition *k+1*, and
  2. the receiver can do *early work* on any partition that has arrived.

The TPU/XLA-native realization: every primitive below decomposes a collective
into ``n_parts`` independent chunk-collectives interleaved with their
producer/consumer compute, expressed as an *unrolled* chunk sequence so XLA's
latency-hiding scheduler can overlap each chunk's DMA with the neighboring
chunks' compute.  ``consume_fn`` is the ``MPI_Parrived`` early-work hook: it is
applied per chunk, inside the pipeline, instead of after the full message.

All point-to-point movement goes through the transport layer
(:mod:`repro.core.transport`): the partition policy (:class:`Partitioner`,
equal-partition padding per paper §II-B) and the neighbor-permute backend
live there, so these primitives accept a ``transport`` name and never touch
``lax.ppermute`` directly.  The many-to-many reductions (``psum``/
``psum_scatter``) keep their native XLA collectives — they have no per-hop
peer table for a transport backend to reroute.  ``all_to_all`` exists in both
forms: :func:`partitioned_all_to_all` keeps the native XLA collective, while
:func:`message_all_to_all` decomposes the same exchange into a ring-shift
:class:`Message` table routed through
:func:`repro.core.transport.exchange_messages` — bitwise-equivalent for exact
packers, and the form that lets ``bf16``/``scaled-int8`` wire compression
apply to MoE token buffers.

All functions are written for use **inside ``jax.shard_map``** (they reference
a named mesh axis).  Every partitioned primitive is numerically equivalent to
its fused reference (tested in ``tests/distributed_progs``); only the schedule
differs.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compat
from repro.core.transport import (  # re-exported: historical home
    Message,
    Packer,
    Partitioner,
    Transport,
    exchange_messages,
    resolve_packer,
    resolve_transport,
    ring_perm,
)

__all__ = [
    "Partitioner", "ring_perm", "partitioned_ppermute", "ring_all_gather",
    "ring_all_gather_matmul", "ring_matmul_reduce_scatter",
    "partitioned_all_to_all", "all_to_all_messages", "message_all_to_all",
    "partitioned_psum_scatter", "partitioned_psum",
    "bucket_tree", "bucketed_psum_tree",
]


def _identity(x: jax.Array) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# partitioned point-to-point (the halo-exchange transport)
# ---------------------------------------------------------------------------


def partitioned_ppermute(
    slab: jax.Array,
    axis_name: str,
    perm: Sequence[tuple[int, int]],
    *,
    n_parts: int = 1,
    split_axis: int = 0,
    pack_fn: Callable[[jax.Array], jax.Array] | None = None,
    consume_fn: Callable[[jax.Array], jax.Array] | None = None,
    transport: str | Transport = "ppermute",
) -> jax.Array:
    """Neighbor permute of ``slab`` split into ``n_parts`` partitions.

    ``pack_fn`` models the per-partition pack (MPI_Pready after a thread packs
    its partition); ``consume_fn`` is per-partition early work on arrival
    (MPI_Parrived).  With ``n_parts=1`` this degenerates to the standard
    single-message exchange.  ``transport`` selects the registered backend
    the hop goes through.
    """
    t = resolve_transport(transport)
    pack = pack_fn or _identity
    consume = consume_fn or _identity
    perm = list(perm)
    if n_parts <= 1:
        return consume(t.permute(pack(slab), axis_name, perm))
    part = Partitioner(n_parts, split_axis)
    out_parts = []
    for chunk in part.split(slab):
        # pack(k) -> start(k): each partition is sent as soon as it is packed,
        # leaving XLA free to overlap chunk k's transfer with chunk k+1's pack.
        sent = t.permute(pack(chunk), axis_name, perm)
        out_parts.append(consume(sent))
    return part.merge(out_parts, slab.shape[split_axis])


# ---------------------------------------------------------------------------
# ring all-gather (+ fused early-consume matmul)
# ---------------------------------------------------------------------------


def ring_all_gather(
    x: jax.Array,
    axis_name: str,
    *,
    gather_axis: int = 0,
    n_parts: int = 1,
    transport: str | Transport = "ppermute",
) -> jax.Array:
    """All-gather via ring hops; equivalent to
    ``lax.all_gather(x, axis_name, axis=gather_axis, tiled=True)``.

    With ``n_parts > 1`` each ring hop moves ``n_parts`` sub-chunks
    independently (finer overlap granularity — partitioned communication).
    """
    t = resolve_transport(transport)
    k = compat.axis_size(axis_name)
    if k == 1:
        return x
    idx = lax.axis_index(axis_name)
    m = x.shape[gather_axis]
    out_shape = list(x.shape)
    out_shape[gather_axis] = m * k
    out = jnp.zeros(out_shape, x.dtype)

    def place(buf: jax.Array, chunk: jax.Array, owner: jax.Array) -> jax.Array:
        start = [0] * buf.ndim
        start[gather_axis] = owner * m
        return lax.dynamic_update_slice(buf, chunk, tuple(start))

    perm = ring_perm(axis_name)
    part = Partitioner(n_parts, gather_axis) if n_parts > 1 else None
    cur = x
    for s in range(k):
        owner = (idx - s) % k
        out = place(out, cur, owner)
        if s < k - 1:
            if part is None:
                cur = t.permute(cur, axis_name, perm)
            else:
                chunks = [
                    t.permute(c, axis_name, perm) for c in part.split(cur)
                ]
                cur = part.merge(chunks, m)
    return out


def ring_all_gather_matmul(
    x: jax.Array,
    w: jax.Array | Sequence[jax.Array],
    axis_name: str,
    *,
    precision: Any = None,
    accum_dtype: Any = None,
    transport: str | Transport = "ppermute",
) -> jax.Array | list[jax.Array]:
    """``all_gather(x, axis=0) @ w`` with the matmul consuming each chunk on
    arrival (early work): ring collective-matmul.

    x: (m, d) local rows; w: (d, n) [typically the column-parallel shard], or
    a sequence of such weights — the gathered chunk is consumed by *all* of
    them while in flight (gated MLPs gather x once for gate+up).
    Returns (k*m, n) (or a list).  Each ring step overlaps one chunk-matmul
    with the next chunk's transfer — partition count == ring size.
    """
    t = resolve_transport(transport)
    ws = list(w) if isinstance(w, (list, tuple)) else [w]
    k = compat.axis_size(axis_name)
    dtype = accum_dtype or x.dtype
    if k == 1:
        outs = [jnp.dot(x, wi, precision=precision).astype(dtype) for wi in ws]
        return outs if isinstance(w, (list, tuple)) else outs[0]
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    outs = [jnp.zeros((k * m, wi.shape[1]), dtype) for wi in ws]
    perm = ring_perm(axis_name)
    cur = x
    for s in range(k):
        owner = (idx - s) % k
        for i, wi in enumerate(ws):
            y = jnp.dot(cur, wi, precision=precision).astype(dtype)
            outs[i] = lax.dynamic_update_slice(outs[i], y, (owner * m, 0))
        if s < k - 1:
            cur = t.permute(cur, axis_name, perm)
    return outs if isinstance(w, (list, tuple)) else outs[0]


def ring_matmul_reduce_scatter(
    x: jax.Array,
    w: jax.Array,
    axis_name: str,
    *,
    precision: Any = None,
    accum_dtype: Any = None,
    transport: str | Transport = "ppermute",
) -> jax.Array:
    """``psum_scatter(x @ w, scatter_dim=0)`` as a ring with per-step partial
    matmuls (the producer side of partitioned communication: each partition of
    the output is computed immediately before its hop).

    x: (M, f) local activation with row count M divisible by the axis size;
    w: (f, n) row-parallel shard.  Returns (M/k, n) = row-block ``idx`` of the
    full sum.  Equivalent to ``lax.psum_scatter(x @ w, axis_name,
    scatter_dimension=0, tiled=True)``.
    """
    t = resolve_transport(transport)
    k = compat.axis_size(axis_name)
    dtype = accum_dtype or x.dtype
    full = jnp.dot(x, w, precision=precision).astype(dtype) if k == 1 else None
    if k == 1:
        return full
    idx = lax.axis_index(axis_name)
    M = x.shape[0]
    assert M % k == 0, (M, k)
    mb = M // k
    perm = ring_perm(axis_name)

    def partial_block(b: jax.Array) -> jax.Array:
        rows = lax.dynamic_slice_in_dim(x, b * mb, mb, axis=0)
        return jnp.dot(rows, w, precision=precision).astype(dtype)

    # acc for block (idx-1) starts here and ends, fully summed, at its owner.
    acc = partial_block((idx - 1) % k)
    for s in range(1, k):
        acc = t.permute(acc, axis_name, perm)
        acc = acc + partial_block((idx - 1 - s) % k)
    return acc  # block ``idx`` of the reduced result


# ---------------------------------------------------------------------------
# partitioned all-to-all (MoE expert dispatch with early expert compute)
# ---------------------------------------------------------------------------


def partitioned_all_to_all(
    x: jax.Array,
    axis_name: str,
    *,
    split_axis: int,
    concat_axis: int,
    n_parts: int = 1,
    chunk_axis: int | None = None,
    consume_fn: Callable[[jax.Array], jax.Array] | None = None,
) -> jax.Array:
    """Tiled ``all_to_all`` split into ``n_parts`` chunks along ``chunk_axis``
    with per-chunk early work (``consume_fn``).

    For MoE: ``x`` is the (experts, capacity, d) dispatch buffer, split/concat
    over the expert axis, chunked over *capacity*, and ``consume_fn`` is the
    expert FFN — expert compute on chunk *k* overlaps the transfer of chunk
    *k+1*, exactly the paper's partitioned pipeline.
    """
    consume = consume_fn or _identity
    if chunk_axis is None:
        chunk_axis = (split_axis + 1) % x.ndim
    if n_parts <= 1:
        arrived = lax.all_to_all(
            x, axis_name, split_axis=split_axis, concat_axis=concat_axis, tiled=True
        )
        return consume(arrived)
    assert chunk_axis != split_axis
    orig = x.shape[chunk_axis]
    part = Partitioner(n_parts, chunk_axis)
    out_parts = []
    for chunk in part.split(x):
        arrived = lax.all_to_all(
            chunk, axis_name, split_axis=split_axis, concat_axis=concat_axis, tiled=True
        )
        out_parts.append(consume(arrived))
    # consume may rescale the chunk axis (must do so uniformly); un-pad on merge.
    padded = part.n_parts * part.part_size(orig)
    out_total = sum(p.shape[chunk_axis] for p in out_parts)
    final_size = int(round(orig * out_total / padded))
    return part.merge(out_parts, final_size)


def all_to_all_messages(
    shape: tuple[int, ...],
    axis_name: str,
    ring_size: int,
    *,
    split_axis: int = 0,
) -> tuple[Message, ...]:
    """Message table for a tiled all-to-all as ``ring_size`` ring shifts.

    Operates on the PRE-ROLLED buffer (see :func:`message_all_to_all`):
    message ``s`` ships block ``s`` of ``split_axis`` to the peer ``s`` steps
    around the ring (``s = 0`` is the hop-free local self-copy, which costs
    no collective).  ``ring_size`` is explicit so the same table serves both
    in-``shard_map`` delivery and static wire accounting.
    """
    size = shape[split_axis]
    assert size % ring_size == 0, (size, ring_size)
    m = size // ring_size
    msgs = []
    for s in range(ring_size):
        start = [0] * len(shape)
        start[split_axis] = s * m
        blk = list(shape)
        blk[split_axis] = m
        if s == 0:
            hops: tuple = ()
        else:
            perm = tuple((i, (i + s) % ring_size) for i in range(ring_size))
            hops = ((axis_name, perm),)
        msgs.append(Message(tuple(start), tuple(start), tuple(blk), hops))
    return tuple(msgs)


def message_all_to_all(
    x: jax.Array,
    axis_name: str,
    *,
    split_axis: int,
    concat_axis: int,
    n_parts: int = 1,
    chunk_axis: int | None = None,
    consume_fn: Callable[[jax.Array], jax.Array] | None = None,
    packer: str | Packer = "slice",
    transport: str | Transport = "ppermute",
    coalesce: bool = True,
) -> jax.Array:
    """:func:`partitioned_all_to_all` routed through the transport layer.

    The tiled all-to-all decomposes into ``k-1`` ring-shift messages plus a
    hop-free self-copy: device ``j`` pre-rolls its split blocks by ``-j`` so
    that the block bound for the peer ``s`` steps away always sits in window
    ``s``, ships window ``s`` with ring shift ``s``
    (:func:`all_to_all_messages`), and un-permutes on arrival.  Values are
    bitwise-equal to ``lax.all_to_all(..., tiled=True)`` for exact-wire
    packers; the payoff is that the registered ``packer``
    (``bf16``/``scaled-int8`` wire compression — opt-in, tolerance-aware)
    and the plan-keyed schedule now apply to MoE token buffers.  Same
    chunking contract as :func:`partitioned_all_to_all`: ``consume_fn`` runs
    per ``chunk_axis`` chunk as early work.
    """
    assert split_axis == concat_axis, (
        "message_all_to_all requires split_axis == concat_axis "
        "(the MoE dispatch form)"
    )
    consume = consume_fn or _identity
    t = resolve_transport(transport)
    p = resolve_packer(packer)
    k = compat.axis_size(axis_name)

    def blocks(y: jax.Array) -> jax.Array:
        y = jnp.moveaxis(y, split_axis, 0)
        return y.reshape((k, y.shape[0] // k) + y.shape[1:])

    def unblocks(y: jax.Array) -> jax.Array:
        y = y.reshape((y.shape[0] * y.shape[1],) + y.shape[2:])
        return jnp.moveaxis(y, 0, split_axis)

    def one_chunk(xc: jax.Array) -> jax.Array:
        if k == 1:
            return consume(xc)
        idx = lax.axis_index(axis_name)
        w = unblocks(jnp.roll(blocks(xc), -idx, axis=0))
        msgs = all_to_all_messages(w.shape, axis_name, k,
                                   split_axis=split_axis)
        tmp = exchange_messages(
            w, (msgs,), packer=p, transport=t, coalesce=coalesce
        )
        # window s now holds the block from the peer s steps BEHIND us;
        # flip+roll re-sorts windows into source-rank order (= tiled concat)
        out = jnp.roll(jnp.flip(blocks(tmp), axis=0), idx + 1, axis=0)
        return consume(unblocks(out))

    if chunk_axis is None:
        chunk_axis = (split_axis + 1) % x.ndim
    if n_parts <= 1:
        return one_chunk(x)
    assert chunk_axis != split_axis
    orig = x.shape[chunk_axis]
    part = Partitioner(n_parts, chunk_axis)
    out_parts = [one_chunk(chunk) for chunk in part.split(x)]
    padded = part.n_parts * part.part_size(orig)
    out_total = sum(pc.shape[chunk_axis] for pc in out_parts)
    final_size = int(round(orig * out_total / padded))
    return part.merge(out_parts, final_size)


# ---------------------------------------------------------------------------
# partitioned reduce-scatter / all-reduce (gradient bucketing)
# ---------------------------------------------------------------------------


def partitioned_psum_scatter(
    x: jax.Array,
    axis_name: str,
    *,
    scatter_axis: int = 0,
    n_parts: int = 1,
    chunk_axis: int | None = None,
) -> jax.Array:
    """``psum_scatter`` chunked along a non-scattered axis (gradient buckets)."""
    if n_parts <= 1:
        return lax.psum_scatter(x, axis_name, scatter_dimension=scatter_axis, tiled=True)
    if chunk_axis is None:
        chunk_axis = (scatter_axis + 1) % x.ndim
    assert chunk_axis != scatter_axis
    part = Partitioner(n_parts, chunk_axis)
    outs = [
        lax.psum_scatter(c, axis_name, scatter_dimension=scatter_axis, tiled=True)
        for c in part.split(x)
    ]
    return part.merge(outs, x.shape[chunk_axis])


def partitioned_psum(
    x: jax.Array,
    axis_name: str,
    *,
    n_parts: int = 1,
    chunk_axis: int = 0,
) -> jax.Array:
    """All-reduce chunked into ``n_parts`` bucket collectives."""
    if n_parts <= 1:
        return lax.psum(x, axis_name)
    part = Partitioner(n_parts, chunk_axis)
    outs = [lax.psum(c, axis_name) for c in part.split(x)]
    return part.merge(outs, x.shape[chunk_axis])


# ---------------------------------------------------------------------------
# gradient-tree bucketing (ZeRO-1 companion; beyond-paper)
# ---------------------------------------------------------------------------


def bucket_tree(tree: Any, n_buckets: int) -> list[list[tuple[int, jax.Array]]]:
    """Greedy size-balanced bucketing of tree leaves (index, leaf) pairs."""
    leaves = list(enumerate(jax.tree.leaves(tree)))
    leaves.sort(key=lambda kv: -kv[1].size)
    buckets: list[list[tuple[int, jax.Array]]] = [[] for _ in range(max(1, n_buckets))]
    fill = [0] * len(buckets)
    for i, leaf in leaves:
        b = fill.index(min(fill))
        buckets[b].append((i, leaf))
        fill[b] += leaf.size
    return [b for b in buckets if b]


def bucketed_psum_tree(tree: Any, axis_name: str, n_buckets: int) -> Any:
    """All-reduce a gradient tree as ``n_buckets`` fused flat collectives.

    Fewer, larger messages than per-leaf psum (amortized α), but more, smaller
    than one fused blob (overlap granularity) — the partitioned trade-off
    applied to data-parallel gradient sync.
    """
    leaves = jax.tree.leaves(tree)
    treedef = jax.tree.structure(tree)
    out: list[jax.Array | None] = [None] * len(leaves)
    for bucket in bucket_tree(tree, n_buckets):
        flat = jnp.concatenate([leaf.reshape(-1) for _, leaf in bucket])
        summed = lax.psum(flat, axis_name)
        off = 0
        for i, leaf in bucket:
            out[i] = lax.dynamic_slice_in_dim(summed, off, leaf.size, 0).reshape(
                leaf.shape
            )
            off += leaf.size
    return jax.tree.unflatten(treedef, out)
