"""Unified pack/transport layer beneath every exchange path.

The paper's measured wins come from how messages are *packed* (contiguous
staging buffers, one per neighbor or partition) and *moved* (persistent
channels, partitioned sends).  This module is the one seam where both
concerns live, pMR-style: every communication path in the repo — the
sequential, fused, and partitioned halo exchanges, the LM ring primitives,
the sequence-parallel ghost pulls — describes its data movement as
:class:`Message` values and delegates the pack -> send -> unpack pipeline to
a :class:`Packer` and a :class:`Transport` chosen by *name*:

* **Message** — one neighbor message: the source slab window in the local
  ghosted block, the destination ghost window, the peer permutation chain
  (one hop per mesh axis crossed), and the partition policy (``n_parts``
  partitions split along ``part_axis``, the paper's ``MPI_Psend_init``
  analogue).
* **Packer** — how a slab window becomes a contiguous wire buffer and back.
  ``"slice"`` is the inline ``lax.slice``/``dynamic_update_slice`` staging
  the halo code historically did; ``"pallas"`` routes through the
  :mod:`repro.kernels.pack` VMEM-tiled copy kernel (Comb's OpenMP pack
  kernels), falling back to its jnp oracle off-TPU so CPU CI exercises
  identical semantics.  ``"bf16"`` and ``"scaled-int8"`` are the
  wire-compressed packers: the slab is re-encoded for the wire (bf16 cast /
  fixed-scale int8 quantization) and the block dtype restored on unpack —
  lossy within :meth:`Packer.wire_tolerance`, shrinking
  :meth:`Packer.wire_itemsize` (the sweep's wire-bytes axis).
* **Transport** — how a packed buffer crosses the mesh.  ``"ppermute"`` is
  the in-process XLA backend (one ``lax.ppermute`` per hop — the native ICI
  neighbor transport on a TPU torus).  ``"multihost"`` is the registered
  seam for multi-process meshes: the same schedule lowers to DCN/ICI
  collectives when the mesh spans hosts, so a real multi-host sweep backend
  plugs in here without touching any caller.

Registering a new packer or transport::

    register_packer(MyPacker(name="zstd-wire"))
    register_transport(MyTransport(name="nccl"))

and every registered exchange strategy, ``comb_measure``, and the §VI sweep
can select it through ``StrategyConfig(packer=..., transport=...)``.

The partition policy (equal-size rule, paper §II-B) lives here as
:class:`Partitioner`; the transport layer sends each partition's *clipped*
window (offsets on the equal-size grid, the zero-padding never crosses the
wire) and unpacks it into the ghost region on arrival (``MPI_Parrived``).

**Coalescing** (the pMR / MPI-Advance message-aggregation optimization) is
the third knob: with ``coalesce=True`` a delivery group's messages are
grouped by hop chain, every slab bound for one neighbor is packed into ONE
contiguous wire buffer (a static :class:`WireLayout` offset table, computed
at trace time and recorded in the persistent plan — the ``MPI_Send_init``
buffer-amortization analogue), and the whole chain is routed with a SINGLE
collective (multi-hop corner chains compose into one joint multi-axis
permutation).  Partitioned messages stay pipelined: round *k+1* packs from
the original buffer while round *k*'s coalesced buffer is in flight, and
each round's buffers unpack on arrival (``MPI_Parrived``).

All delivery functions run **inside** ``jax.shard_map``; message tables are
built at trace time, so permutation tables and slab geometry are baked into
the compiled plan — the "tag matching at init" the paper's persistent mode
amortizes.
"""

from __future__ import annotations

import abc
import contextlib
import dataclasses
import itertools
import math
import os
import warnings
from typing import Any, Callable, ClassVar, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Partitioner: the equal-partition (+padding) rule from the paper
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partitioner:
    """Splits an array axis into ``n_parts`` equal partitions, zero-padding the
    tail when the size does not divide (the paper's equal-size constraint)."""

    n_parts: int
    axis: int = 0

    def pad_amount(self, size: int) -> int:
        return (-size) % self.n_parts

    def part_size(self, size: int) -> int:
        return (size + self.pad_amount(size)) // self.n_parts

    def split(self, x: jax.Array) -> list[jax.Array]:
        size = x.shape[self.axis]
        pad = self.pad_amount(size)
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[self.axis] = (0, pad)
            x = jnp.pad(x, widths)
        return jnp.split(x, self.n_parts, axis=self.axis)

    def merge(self, parts: Sequence[jax.Array], orig_size: int) -> jax.Array:
        x = jnp.concatenate(list(parts), axis=self.axis)
        if x.shape[self.axis] != orig_size:
            x = lax.slice_in_dim(x, 0, orig_size, axis=self.axis)
        return x

    def slices(self, size: int) -> list[tuple[int, int]]:
        """(offset, valid width) of each partition within the *un-padded*
        axis; the tail partition's width is clipped (0 when fully padding)."""
        c = self.part_size(size)
        return [
            (i * c, max(0, min(c, size - i * c))) for i in range(self.n_parts)
        ]


def ring_perm(axis_name: str, shift: int = 1) -> list[tuple[int, int]]:
    """Ring source->target table over a named mesh axis."""
    from repro.core import compat

    k = compat.axis_size(axis_name)
    return [(i, (i + shift) % k) for i in range(k)]


# ---------------------------------------------------------------------------
# Message: one neighbor message of an exchange schedule
# ---------------------------------------------------------------------------

#: one transport hop: (mesh axis name, source->target permutation table)
Hop = tuple[str, tuple[tuple[int, int], ...]]


@dataclasses.dataclass(frozen=True)
class Message:
    """One message of an exchange: src slab -> (hops) -> dst ghost window.

    ``src_start``/``shape`` window the source slab in the local ghosted
    block; ``dst_start`` is where the (identically shaped) payload lands on
    the receiving shard.  ``hops`` is the peer permutation chain — one
    ``(axis_name, perm)`` per mesh axis the message crosses (a corner
    message hops once per involved axis; an empty chain is a local
    self-copy, the single-shard periodic wrap).  ``n_parts > 1`` splits the
    slab along ``part_axis`` into equal partitions (paper §II-B), each
    packed, sent, and unpacked independently.
    """

    src_start: tuple[int, ...]
    dst_start: tuple[int, ...]
    shape: tuple[int, ...]
    hops: tuple[Hop, ...] = ()
    n_parts: int = 1
    part_axis: int | None = None

    def __post_init__(self):
        assert len(self.src_start) == len(self.dst_start) == len(self.shape)
        assert self.n_parts >= 1, self.n_parts
        if self.n_parts > 1:
            assert self.part_axis is not None, "partitioned message needs axis"

    def partitions(self) -> tuple["Message", ...]:
        """Expand into per-partition single messages (equal-size grid).

        Offsets follow the paper's equal-partition rule; each partition's
        window is clipped to the slab, so the zero-padding of a
        non-dividing tail never crosses the wire and an all-padding tail
        partition (``n_parts`` beyond the axis extent) is elided entirely.
        MPI would still post the fixed partition count; under XLA an
        arrival nobody consumes is dead code (the historical inline path's
        padding sends were eliminated the same way), so the wire-level
        cost of surplus partitions is a :mod:`repro.core.model_comm`
        concern, not something this backend can measure.
        """
        if self.n_parts <= 1:
            return (self,)
        a = self.part_axis
        out = []
        for off, width in Partitioner(self.n_parts, a).slices(self.shape[a]):
            if width <= 0:
                continue
            src = list(self.src_start)
            dst = list(self.dst_start)
            shape = list(self.shape)
            src[a] += off
            dst[a] += off
            shape[a] = width
            out.append(
                Message(tuple(src), tuple(dst), tuple(shape), self.hops)
            )
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class WireSegment:
    """One slab's place inside a coalesced wire buffer.

    ``offset`` is the segment's start in wire *elements* (the wire dtype is
    uniform across a buffer, so element offsets are itemsize-free);
    ``src_start``/``dst_start``/``shape`` are the slab windows exactly as on
    :class:`Message`.  All fields are trace-time python ints — the layout is
    a static table baked into the compiled plan.
    """

    offset: int
    src_start: tuple[int, ...]
    dst_start: tuple[int, ...]
    shape: tuple[int, ...]

    @property
    def numel(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class WireLayout:
    """Static offset table of ONE coalesced wire buffer (one hop chain).

    Every segment's slab is packed at ``segments[i].offset`` into a single
    contiguous buffer of ``total`` wire elements, routed with one composed
    collective along ``hops``, and scatter-unpacked on arrival.
    ``wire_itemsize`` records what one element costs on the wire under the
    packer the layout was built for (compressed packers shrink it), so
    ``wire_bytes`` is the buffer's true wire footprint.
    """

    hops: tuple[Hop, ...]
    segments: tuple[WireSegment, ...]
    total: int
    wire_itemsize: int

    @property
    def wire_bytes(self) -> int:
        return self.total * self.wire_itemsize


def coalesced_layout(
    parts: Sequence[Message], hops: tuple[Hop, ...], packer: "Packer",
    dtype: Any,
) -> WireLayout:
    """Lay single-partition messages sharing ``hops`` end-to-end in one wire
    buffer (segment order = message order, offsets in wire elements)."""
    segments, offset = [], 0
    for m in parts:
        assert m.hops == hops, (m.hops, hops)
        assert m.n_parts == 1, "layouts are built from expanded partitions"
        segments.append(
            WireSegment(offset, m.src_start, m.dst_start, m.shape)
        )
        offset += math.prod(m.shape)
    return WireLayout(
        hops=tuple(hops), segments=tuple(segments), total=offset,
        wire_itemsize=packer.wire_itemsize(dtype),
    )


def coalesced_rounds(
    messages: Iterable[Message],
) -> list[list[tuple[tuple[Hop, ...], list[Message]]]]:
    """The pipelined partition schedule of one delivery group.

    Round *r* holds every message's *r*-th (clipped) partition, grouped by
    hop chain in first-seen order: each ``(chain, parts)`` cell becomes one
    coalesced buffer and one composed collective, and successive rounds
    pack/fly/unpack independently (the threaded-partitioned-send analogue —
    round *k+1* may pack while round *k* is in flight)."""
    per_msg = [m.partitions() for m in messages]
    n_rounds = max((len(p) for p in per_msg), default=0)
    rounds = []
    for r in range(n_rounds):
        chains: dict[tuple[Hop, ...], list[Message]] = {}
        for parts in per_msg:
            if r < len(parts):
                chains.setdefault(parts[r].hops, []).append(parts[r])
        rounds.append(list(chains.items()))
    return rounds


def composed_hop(hops: Sequence[Hop]) -> Hop | None:
    """Compose a hop chain into ONE joint permutation (a single collective).

    Per-axis neighbor tables act independently, so the chain equals the
    product map over the tuple of axis names: source coords ``(i_1..i_d)``
    reach ``(p_1(i_1)..p_d(i_d))`` iff every per-axis table defines the hop
    (clipped non-periodic edges drop the whole path — identical to what
    chained per-hop permutes deliver, where a missing hop zeros the buffer).
    Indices linearize row-major over the axis tuple, ``lax.ppermute``'s rule
    for multi-axis collectives.  Must run at trace time inside ``shard_map``
    (axis sizes come from the mesh).  ``None`` means a hop-free self-copy.
    """
    hops = tuple(hops)
    if not hops:
        return None
    if len(hops) == 1:
        return hops[0]
    from repro.core import compat

    names = tuple(name for name, _ in hops)
    sizes = [compat.axis_size(name) for name in names]
    maps = [dict(perm) for _, perm in hops]

    def lin(coords: Sequence[int]) -> int:
        idx = 0
        for c, k in zip(coords, sizes):
            idx = idx * k + c
        return idx

    pairs = []
    for coords in itertools.product(*[range(k) for k in sizes]):
        if all(c in m for c, m in zip(coords, maps)):
            pairs.append(
                (lin(coords), lin([m[c] for c, m in zip(coords, maps)]))
            )
    return (names, tuple(pairs))


def scheduled_collective_count(
    groups: Sequence[Sequence[Message]], *, coalesce: bool
) -> int:
    """Collectives one schedule launches per step (hop-free self-copies are
    free).  Uncoalesced: one per hop of every partition of every message.
    Coalesced: one per non-empty (round, hop chain) cell — the composed
    joint permutation — exactly mirroring the delivery choreography."""
    total = 0
    for group in groups:
        if coalesce:
            for chains in coalesced_rounds(group):
                total += sum(1 for hops, _ in chains if hops)
        else:
            for msg in group:
                for part in msg.partitions():
                    total += len(part.hops)
    return total


def schedule_layouts(
    groups: Sequence[Sequence[Message]],
    packer: "str | Packer",
    dtype: Any,
) -> tuple[WireLayout, ...]:
    """All wire-buffer offset tables of a coalesced schedule, in delivery
    order (group, partition round, hop chain) — what a persistent plan
    records at init (:func:`repro.core.plan.transport_plan`)."""
    p = resolve_packer(packer)
    out = []
    for group in groups:
        for chains in coalesced_rounds(group):
            for hops, parts in chains:
                out.append(coalesced_layout(parts, hops, p, dtype))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ScheduleInfo:
    """Identity of one compiled transport schedule (for plan names/keys).

    ``kind`` names the choreography (``"sequential"`` axis passes,
    ``"fused"`` single pass, ...); ``mesh_axes`` the axes it spans;
    ``packer``/``transport`` the registered backends it resolves;
    ``coalesce`` whether messages aggregate into per-neighbor wire buffers;
    and ``mapping`` the registered process-to-node placement the mesh was
    built under (:mod:`repro.launch.mapping`) — two meshes of identical
    shape but different rank placement are different plans, never a silent
    cache hit.
    """

    kind: str
    mesh_axes: tuple[str, ...]
    packer: str = "slice"
    transport: str = "ppermute"
    coalesce: bool = False
    mapping: str = "row-major"
    #: how this cell was chosen when the autotuner picked it
    #: (:mod:`repro.core.autotune`); ``None`` for hand-pinned cells
    selected_by: str | None = None
    #: membership epoch of the mesh this schedule was compiled against
    #: (:mod:`repro.launch.membership`).  Every JOIN or in-grid LOSS
    #: recovery bumps the grid's epoch, so a plan built before the
    #: re-formation can never alias one built after it — stale plans
    #: cannot deliver into a re-formed mesh.  ``None`` (the default) means
    #: the caller lives outside the membership domain entirely: such plans
    #: are never epoch-invalidated and their tags/keys are byte-identical
    #: to before epochs existed.  0 is a *stamped* formation epoch.
    epoch: int | None = None

    def tag(self) -> str:
        axes = "x".join(self.mesh_axes) or "-"
        base = f"{self.kind}[{axes}]@{self.packer}/{self.transport}"
        if self.mapping != "row-major":
            base += f"%{self.mapping}"
        if self.selected_by is not None:
            base += f"?{self.selected_by}"
        if self.epoch is not None:
            base += f"!e{self.epoch}"
        return base + ("+coalesced" if self.coalesce else "")


# ---------------------------------------------------------------------------
# hop locality: which scheduled sends cross a node boundary
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HopLocality:
    """Inter- vs intra-node tally of one schedule's directed sends.

    Counted per *shard-level directed send*: every mesh coordinate sends
    each (expanded-partition) message once, so one message contributes one
    send per coordinate whose full hop chain is defined (clipped
    non-periodic edges drop the send, exactly as the transport drops the
    path).  Hop-free self-copies never touch a wire and are not counted.
    ``*_elems`` weight each send by its slab element count — the
    wire-volume view of the same classification.  Derived purely from the
    static :class:`Message` tables plus a node-id vector, no timing.
    """

    intra_sends: int = 0
    inter_sends: int = 0
    intra_elems: int = 0
    inter_elems: int = 0

    @property
    def total_sends(self) -> int:
        return self.intra_sends + self.inter_sends

    def __add__(self, other: "HopLocality") -> "HopLocality":
        return HopLocality(
            self.intra_sends + other.intra_sends,
            self.inter_sends + other.inter_sends,
            self.intra_elems + other.intra_elems,
            self.inter_elems + other.inter_elems,
        )


def message_locality(
    msg: Message,
    *,
    axis_order: Sequence[str],
    axis_sizes: Mapping[str, int],
    node_of: Sequence[int],
) -> HopLocality:
    """Classify one message's per-shard sends as intra- vs inter-node.

    ``axis_order`` is the mesh's axis-name tuple in mesh-shape order;
    ``node_of[flat_coord]`` is the node id at each row-major mesh
    coordinate (:meth:`repro.launch.mapping.Mapping.node_of`, or
    :func:`repro.launch.mapping.mesh_node_ids` for a live mesh).  Each
    partition of the message is walked over every source coordinate: the
    composed hop chain maps the coordinate to its destination, and the send
    is inter-node iff the two coordinates live on different nodes.
    """
    shape = tuple(axis_sizes[name] for name in axis_order)
    assert len(node_of) == math.prod(shape), (len(node_of), shape)
    index = {name: i for i, name in enumerate(axis_order)}

    def flat(coords: Sequence[int]) -> int:
        idx = 0
        for c, k in zip(coords, shape):
            idx = idx * k + c
        return idx

    out = HopLocality()
    for part in msg.partitions():
        if not part.hops:
            continue  # self-copy: nothing crosses any boundary
        maps = [(index[name], dict(perm)) for name, perm in part.hops]
        elems = math.prod(part.shape)
        intra = inter = 0
        for coords in itertools.product(*[range(k) for k in shape]):
            dst = list(coords)
            for a, m in maps:
                if coords[a] not in m:
                    dst = None  # clipped edge: this shard sends nothing
                    break
                dst[a] = m[coords[a]]
            if dst is None:
                continue
            if node_of[flat(coords)] == node_of[flat(dst)]:
                intra += 1
            else:
                inter += 1
        out = out + HopLocality(intra, inter, intra * elems, inter * elems)
    return out


def schedule_locality(
    groups: Sequence[Sequence[Message]],
    *,
    axis_order: Sequence[str],
    axis_sizes: Mapping[str, int],
    node_of: Sequence[int],
) -> HopLocality:
    """Whole-schedule hop-locality tally (sum over every group's messages).

    This is what the §VI sweep records per cell (``intra_node_sends`` /
    ``inter_node_sends``) and what the mapping acceptance test asserts on:
    a blocked placement must strictly reduce ``inter_sends`` vs row-major
    on a multi-node 2-D grid — from the static tables alone.
    """
    out = HopLocality()
    for group in groups:
        for msg in group:
            out = out + message_locality(
                msg, axis_order=axis_order, axis_sizes=axis_sizes,
                node_of=node_of,
            )
    return out


# ---------------------------------------------------------------------------
# Packer: slab window <-> contiguous wire buffer
# ---------------------------------------------------------------------------


class Packer(abc.ABC):
    """Packs a slab window into a contiguous wire buffer and back.

    ``pack`` reads the window ``[start, start+shape)`` of the local block;
    ``unpack`` writes the received buffer into the (same-shaped) ghost
    window at ``dst_start``.  A packer may re-layout or re-encode the wire
    buffer (dtype conversion, scaling, compression) as long as
    ``unpack(pack(...))`` restores the slab values.
    """

    #: registry key (instances may override per-instance)
    name: ClassVar[str] = ""

    @abc.abstractmethod
    def pack(
        self, x: jax.Array, start: Sequence[int], shape: Sequence[int]
    ) -> jax.Array:
        """Stage the slab window as one contiguous wire buffer."""

    @abc.abstractmethod
    def unpack(
        self,
        x: jax.Array,
        buf: jax.Array,
        dst_start: Sequence[int],
        shape: Sequence[int],
    ) -> jax.Array:
        """Write a received wire buffer into the ghost window of ``x``."""

    # -- coalesced wire buffers (one buffer per neighbor) -------------------
    def pack_coalesced(self, x: jax.Array, layout: WireLayout) -> jax.Array:
        """Fill one coalesced 1-D wire buffer: every segment's slab packed
        at its static offset.  The default stages each segment through
        :meth:`pack` and concatenates (offsets are consecutive by
        construction); kernel-backed packers override this with a single
        fused gather-pack launch."""
        bufs = [
            jnp.ravel(self.pack(x, s.src_start, s.shape))
            for s in layout.segments
        ]
        return bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs)

    def unpack_coalesced(
        self, x: jax.Array, buf: jax.Array, layout: WireLayout
    ) -> jax.Array:
        """Scatter an arrived coalesced buffer into its ghost windows."""
        flat = jnp.ravel(buf)
        for s in layout.segments:
            seg = lax.slice(flat, (s.offset,), (s.offset + s.numel,))
            x = self._unpack_segment(x, seg, s)
        return x

    def _unpack_segment(
        self, x: jax.Array, seg: jax.Array, s: WireSegment
    ) -> jax.Array:
        """One segment of :meth:`unpack_coalesced`; ``seg`` is the 1-D wire
        slice.  Packers whose :meth:`unpack` expects a non-slab wire view
        (the 2-D kernel form) override this reshape."""
        return self.unpack(x, seg.reshape(s.shape), s.dst_start, s.shape)

    # -- wire-format introspection (the sweep's wire-bytes axis) ------------
    def wire_itemsize(self, dtype: Any) -> int:
        """Bytes one block element occupies on the wire (compressed packers
        override; exact packers ship the block dtype unchanged)."""
        return jnp.dtype(dtype).itemsize

    def wire_tolerance(self, dtype: Any) -> tuple[float, float]:
        """``(rtol, atol)`` bound on ``unpack(pack(window))`` vs the window
        for blocks of ``dtype``; ``(0.0, 0.0)`` means the wire is bit-exact
        (the equivalence harness then asserts full bitwise equality)."""
        return (0.0, 0.0)


@dataclasses.dataclass(frozen=True)
class SlicePacker(Packer):
    """The historical inline staging: ``lax.slice`` out, ``lax.
    dynamic_update_slice`` back.  The wire buffer *is* the slab."""

    name: str = "slice"

    def pack(self, x, start, shape):
        limits = [s + n for s, n in zip(start, shape)]
        return lax.slice(x, list(start), limits)

    def unpack(self, x, buf, dst_start, shape):
        assert tuple(buf.shape) == tuple(shape), (buf.shape, shape)
        return lax.dynamic_update_slice(x, buf, tuple(dst_start))


@dataclasses.dataclass(frozen=True)
class PallasPacker(Packer):
    """Comb-pack-kernel analogue: the VMEM-tiled contiguous copy of
    :mod:`repro.kernels.pack`, extended to the N-D slabs the halo schedules
    emit (faces, edges, corners, partitions) via a 2-D (lead, lane) view.

    Off-TPU the kernel wrappers fall back to their jnp oracle, so the
    packer is CI-runnable on virtual CPU devices with bit-identical
    results; ``force_kernel``/``interpret`` pin the Pallas interpreter path
    for kernel-parity tests.
    """

    name: str = "pallas"
    force_kernel: bool = False
    interpret: bool = False

    def pack(self, x, start, shape):
        from repro.kernels.pack.ops import pack_slab

        limits = [s + n for s, n in zip(start, shape)]
        slab = lax.slice(x, list(start), limits)
        return pack_slab(
            slab, force_kernel=self.force_kernel, interpret=self.interpret
        )

    def unpack(self, x, buf, dst_start, shape):
        from repro.kernels.pack.ops import unpack_slab

        ghost = unpack_slab(
            buf, tuple(shape), out_dtype=x.dtype,
            force_kernel=self.force_kernel, interpret=self.interpret,
        )
        return lax.dynamic_update_slice(x, ghost, tuple(dst_start))

    def pack_coalesced(self, x, layout):
        # Comb's combined pack: ONE kernel launch fills the whole coalesced
        # buffer instead of one tiled copy per slab.
        from repro.kernels.pack.ops import gather_pack

        return gather_pack(
            x, layout.segments, total=layout.total,
            force_kernel=self.force_kernel, interpret=self.interpret,
        )

    def _unpack_segment(self, x, seg, s):
        # unpack_slab consumes the kernel's 2-D (lead, lane) wire view
        lead = s.numel // s.shape[-1] if len(s.shape) > 1 else 1
        return self.unpack(x, seg.reshape(lead, -1), s.dst_start, s.shape)


@dataclasses.dataclass(frozen=True)
class Bf16Packer(Packer):
    """Wire-compressed packer: the slab crosses the wire as ``bfloat16``.

    ``pack`` stages the window through the :mod:`repro.kernels.pack` slab
    kernel with a bf16 wire dtype (halving wire bytes for f32 fields);
    ``unpack`` restores the block dtype exactly.  Lossy for dtypes wider
    than bf16: one round-trip keeps 8 bits of significand (round-to-nearest
    error <= 2^-8 relative — half an ulp), and :meth:`wire_tolerance`
    documents 2x that bound (2^-7).
    """

    name: str = "bf16"

    def pack(self, x, start, shape):
        from repro.kernels.pack.ops import pack_slab

        limits = [s + n for s, n in zip(start, shape)]
        slab = lax.slice(x, list(start), limits)
        return pack_slab(slab, out_dtype=jnp.bfloat16)

    def unpack(self, x, buf, dst_start, shape):
        from repro.kernels.pack.ops import unpack_slab

        ghost = unpack_slab(buf, tuple(shape), out_dtype=x.dtype)
        return lax.dynamic_update_slice(x, ghost, tuple(dst_start))

    def pack_coalesced(self, x, layout):
        # one fused gather-pack launch, casting to the bf16 wire on the fly
        from repro.kernels.pack.ops import gather_pack

        return gather_pack(x, layout.segments, total=layout.total,
                           out_dtype=jnp.bfloat16)

    def _unpack_segment(self, x, seg, s):
        lead = s.numel // s.shape[-1] if len(s.shape) > 1 else 1
        return self.unpack(x, seg.reshape(lead, -1), s.dst_start, s.shape)

    def wire_itemsize(self, dtype):
        return 2  # the wire dtype is always bfloat16

    def wire_tolerance(self, dtype):
        if jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16):
            return (0.0, 0.0)  # the cast is the identity
        return (1.0 / 128.0, 1e-6)  # 2x the bf16 half-ulp relative error


@dataclasses.dataclass(frozen=True)
class ScaledInt8Packer(Packer):
    """Wire-compressed packer: fixed-scale symmetric int8 quantization.

    ``pack`` maps the slab onto the int8 grid ``round(x * 127 / amax)``
    (clipped to ±127); ``unpack`` rescales and restores the block dtype.
    The wire carries one byte per element — a 4x reduction for f32 fields.
    Quantization error is <= ``amax/254`` per element for ``|x| <= amax``;
    values beyond ``±amax`` saturate, so ``amax`` must cover the field's
    dynamic range (the default spans the unit-normal test fields by 8
    standard deviations).
    """

    name: str = "scaled-int8"
    amax: float = 8.0

    def pack(self, x, start, shape):
        limits = [s + n for s, n in zip(start, shape)]
        slab = lax.slice(x, list(start), limits).astype(jnp.float32)
        q = jnp.clip(jnp.round(slab * (127.0 / self.amax)), -127.0, 127.0)
        return q.astype(jnp.int8)

    def unpack(self, x, buf, dst_start, shape):
        assert tuple(buf.shape) == tuple(shape), (buf.shape, shape)
        vals = (buf.astype(jnp.float32) * (self.amax / 127.0)).astype(x.dtype)
        return lax.dynamic_update_slice(x, vals, tuple(dst_start))

    def wire_itemsize(self, dtype):
        return 1

    def wire_tolerance(self, dtype):
        return (0.0, self.amax / 127.0)  # 2x the half-step rounding bound


# ---------------------------------------------------------------------------
# Transport: how packed buffers cross the mesh
# ---------------------------------------------------------------------------


class Transport(abc.ABC):
    """Moves packed buffers between shards along named mesh axes."""

    name: ClassVar[str] = ""

    @abc.abstractmethod
    def permute(
        self,
        buf: jax.Array,
        axis_name: str | tuple[str, ...],
        perm: Sequence[tuple[int, int]],
    ) -> jax.Array:
        """One collective: send ``buf`` along ``axis_name`` per the
        (src, dst) table; shards receiving nothing get zeros (XLA ppermute
        rule).  ``axis_name`` may be a tuple of mesh axes — a composed
        multi-hop chain as ONE joint permutation over the row-major
        linearization of those axes (the coalesced corner route)."""

    def validate(self) -> None:
        """Runtime sanity check, run when the backend is resolved for a
        schedule (cheap: called once per exchange trace, never per group
        or per message)."""

    def route(self, buf: jax.Array, hops: Iterable[Hop]) -> jax.Array:
        """Chain the hops of one message (edges/corners hop per axis)."""
        for axis_name, perm in hops:
            buf = self.permute(buf, axis_name, list(perm))
        return buf

    def route_composed(self, buf: jax.Array, hops: Sequence[Hop]) -> jax.Array:
        """Route a whole hop chain as a SINGLE collective (the coalesced
        path): multi-axis chains compose into one joint permutation via
        :func:`composed_hop`; an empty chain is the hop-free self-copy."""
        hop = composed_hop(hops)
        if hop is None:
            return buf
        axis_name, perm = hop
        return self.permute(buf, axis_name, list(perm))


@dataclasses.dataclass(frozen=True)
class PpermuteTransport(Transport):
    """In-process backend: one ``lax.ppermute`` per hop — XLA's native
    neighbor transport (ICI on a TPU torus, shared-memory copies on the
    virtual-device CPU meshes CI runs)."""

    name: str = "ppermute"

    def permute(self, buf, axis_name, perm):
        return lax.ppermute(buf, axis_name, list(perm))


@dataclasses.dataclass(frozen=True)
class MultiHostTransport(PpermuteTransport):
    """The multi-host backend: same schedule, mesh spanning processes.

    ``lax.ppermute`` inside a global ``shard_map`` lowers to cross-process
    collective-permutes (DCN/ICI on real clusters, gloo on the CPU grids
    ``repro.launch.stencil`` boots) when the mesh's devices belong to
    several processes, so this backend runs today's schedules unchanged
    under ``jax.distributed``; a dedicated backend (e.g. per-hop NCCL rings
    or MPI partitioned sends) overrides :meth:`permute` and registers under
    its own name.  :meth:`is_multihost` reports whether the current runtime
    actually spans processes; the sweep stamps it into the BENCH records
    and config block (``repro.stencil.sweep.config_block``).

    Selecting ``multihost`` in a single-process runtime outside tests warns
    once (:meth:`validate`): the schedule still runs — it degenerates to
    in-process ``ppermute`` — but nothing crosses a host boundary, which is
    almost never what a caller asking for this backend means.  Launch a
    real grid with ``repro.launch.stencil`` (or set
    ``REPRO_ALLOW_SINGLE_PROCESS_MULTIHOST=1`` to silence deliberately).
    """

    name: str = "multihost"

    #: one warning per process, not one per exchange trace
    _warned_single_process: ClassVar[bool] = False

    @staticmethod
    def is_multihost() -> bool:
        return jax.process_count() > 1

    def validate(self) -> None:
        if self.is_multihost() or MultiHostTransport._warned_single_process:
            return
        if (os.environ.get("PYTEST_CURRENT_TEST")
                or os.environ.get("REPRO_ALLOW_SINGLE_PROCESS_MULTIHOST")):
            return
        MultiHostTransport._warned_single_process = True
        warnings.warn(
            "transport='multihost' selected but jax.process_count() == 1: "
            "no message will cross a process boundary (the schedule runs "
            "as in-process ppermute).  Boot a real process grid with "
            "`python -m repro.launch.stencil --processes N ...` or the "
            "sweep's --processes flag; set "
            "REPRO_ALLOW_SINGLE_PROCESS_MULTIHOST=1 if this is deliberate.",
            RuntimeWarning,
            stacklevel=3,
        )


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

_PACKERS: dict[str, Packer] = {}
_TRANSPORTS: dict[str, Transport] = {}


def register_packer(packer: Packer) -> Packer:
    """Add a packer instance to the registry under ``packer.name``."""
    if not packer.name:
        raise ValueError(f"{type(packer).__name__} must carry a name")
    if packer.name in _PACKERS:
        raise ValueError(f"packer {packer.name!r} already registered")
    _PACKERS[packer.name] = packer
    return packer


def register_transport(transport: Transport) -> Transport:
    """Add a transport instance to the registry under ``transport.name``."""
    if not transport.name:
        raise ValueError(f"{type(transport).__name__} must carry a name")
    if transport.name in _TRANSPORTS:
        raise ValueError(f"transport {transport.name!r} already registered")
    _TRANSPORTS[transport.name] = transport
    return transport


def available_packers() -> tuple[str, ...]:
    return tuple(_PACKERS)


def available_transports() -> tuple[str, ...]:
    return tuple(_TRANSPORTS)


def get_packer(name: str) -> Packer:
    try:
        return _PACKERS[name]
    except KeyError:
        raise KeyError(
            f"unknown packer {name!r}; registered: "
            f"{', '.join(_PACKERS) or '(none)'}"
        ) from None


def get_transport(name: str) -> Transport:
    try:
        return _TRANSPORTS[name]
    except KeyError:
        raise KeyError(
            f"unknown transport {name!r}; registered: "
            f"{', '.join(_TRANSPORTS) or '(none)'}"
        ) from None


def resolve_packer(packer: str | Packer) -> Packer:
    return packer if isinstance(packer, Packer) else get_packer(packer)


def resolve_transport(transport: str | Transport) -> Transport:
    t = transport if isinstance(transport, Transport) else get_transport(transport)
    t.validate()
    return t


register_packer(SlicePacker())
register_packer(PallasPacker())
register_packer(Bf16Packer())
register_packer(ScaledInt8Packer())
register_transport(PpermuteTransport())
register_transport(MultiHostTransport())


# ---------------------------------------------------------------------------
# delivery choreography (runs inside shard_map)
# ---------------------------------------------------------------------------

#: trace-time chaos seam: when set (via :func:`chaos_scope`), the delivery
#: choreography calls it at labeled points — ``"group"`` on entering a
#: delivery group, ``"round"`` before each pipelined partition round.  The
#: points fire while the step is being *traced* (message tables are built at
#: trace time), so a probe raising ``SimulatedFailure`` aborts a plan build
#: mid-assembly — exactly the adversarial window the elastic chaos tests
#: inject into.  ``None`` (the default) is a zero-cost no-op.
_CHAOS_PROBE: Callable[[str], None] | None = None


@contextlib.contextmanager
def chaos_scope(probe: Callable[[str], None] | None):
    """Install ``probe`` as the delivery chaos hook for the dynamic extent
    of the block (``None`` leaves the seam disabled — callers can pass
    their maybe-configured injector through unconditionally)."""
    global _CHAOS_PROBE
    prev, _CHAOS_PROBE = _CHAOS_PROBE, probe
    try:
        yield
    finally:
        _CHAOS_PROBE = prev


def _chaos(point: str) -> None:
    if _CHAOS_PROBE is not None:
        _CHAOS_PROBE(point)


def _deliver_group(
    x: jax.Array,
    messages: Iterable[Message],
    p: Packer,
    t: Transport,
    coalesce: bool,
) -> jax.Array:
    """One delivery group with *resolved* backends (no registry lookups,
    no re-validation — :func:`exchange_messages` hoists those once per
    schedule)."""
    _chaos("group")
    if not coalesce:
        arrived: list[tuple[Message, jax.Array]] = []
        for msg in messages:
            for part in msg.partitions():
                buf = p.pack(x, part.src_start, part.shape)  # pack
                buf = t.route(buf, part.hops)  # start/send
                arrived.append((part, buf))
        for part, buf in arrived:  # unpack (disjoint ghost windows)
            x = p.unpack(x, buf, part.dst_start, part.shape)
        return x

    # Coalesced: one wire buffer and ONE composed collective per (partition
    # round, hop chain) cell.  Every round packs from the group's ORIGINAL
    # buffer — round k+1's pack has no data dependency on round k's route
    # or unpack, so XLA may pack the next partition while the previous
    # coalesced buffer is in flight (the threaded-partitioned-send
    # analogue), and each round's arrivals unpack immediately
    # (``MPI_Parrived``).  Src slabs and dst ghost windows are disjoint
    # within a group, so packing from ``x0`` equals the uncoalesced order.
    x0 = x
    for chains in coalesced_rounds(messages):
        _chaos("round")
        for hops, parts in chains:
            layout = coalesced_layout(parts, hops, p, x0.dtype)
            buf = p.pack_coalesced(x0, layout)
            buf = t.route_composed(buf, hops)
            x = p.unpack_coalesced(x, buf, layout)
    return x


def deliver(
    x: jax.Array,
    messages: Iterable[Message],
    *,
    packer: str | Packer = "slice",
    transport: str | Transport = "ppermute",
    coalesce: bool = False,
) -> jax.Array:
    """Deliver one *group* of independent messages: pack and route every
    message (and every partition, ``MPI_Pready``-style), then unpack all
    arrivals into their disjoint ghost windows (``MPI_Parrived``).

    Within a group no message depends on another, so XLA is free to overlap
    all packs, transfers, and unpacks; sequencing *between* groups (the
    sequential schedule's axis passes) is the caller's ``exchange_messages``.
    With ``coalesce=True`` messages aggregate into one wire buffer and one
    composed collective per hop chain (partitions stay pipelined rounds).
    """
    return _deliver_group(
        x, messages, resolve_packer(packer), resolve_transport(transport),
        coalesce,
    )


def exchange_messages(
    x: jax.Array,
    groups: Sequence[Sequence[Message]],
    *,
    packer: str | Packer = "slice",
    transport: str | Transport = "ppermute",
    coalesce: bool = False,
) -> jax.Array:
    """Deliver a full schedule: groups run in order (group *i+1* packs from
    the buffer group *i* unpacked into — the sequential corner trick),
    messages within a group are independent.  Backends resolve (and the
    transport validates) exactly ONCE per schedule, not per group."""
    p = resolve_packer(packer)
    t = resolve_transport(transport)
    for group in groups:
        x = _deliver_group(x, group, p, t, coalesce)
    return x
