"""Online autotuner: pick the best exchange cell for the current topology.

The paper's own §VI shows the winning communication variant flips between
persistent and partitioned depending on scale and message size — and its
core argument (persistent plans amortize setup) makes in-situ re-measurement
nearly free.  This module is the plan-*selection* layer built on both
observations: at plan-build time it picks the best ``(strategy, packer,
coalesce, n_parts)`` cell for the current ``(topology, message size,
node_size)`` instead of requiring the caller to hard-code one.  Gillis et
al. (arXiv:2308.03930) show partitioned speedup is a predictable function of
message size and partition count — i.e. modelable — which is exactly what
the trace-driven backend exploits.

Two selection backends behind one interface (:class:`Tuner`):

* **trace-driven** — a recorded ``BENCH_stencil_sweep.json`` trajectory is
  the ground truth.  A candidate whose cell was measured verbatim is scored
  by its recorded ``us_per_cycle`` (``selected_by="trace"``); a candidate
  whose coordinates match but whose message size was never swept is scored
  from the nearest swept size plus a model-predicted delta
  (``"trace-nearest"``); an unswept candidate falls back to the fitted
  per-strategy cost model alone (``"model"``).  Measurements outrank
  extrapolation: selection happens within the best available tier, so a
  modeled cell can never shadow a measured one.
* **in-situ calibration** — when no usable trace exists, each candidate is
  probed with a short timed run through the caller's :class:`~repro.core.
  plan.PlanCache` (the winning probe's compiled plan is reused by the real
  driver — the paper's amortization argument applied to tuning itself) and
  the verdict is memoized in a persistent :class:`AutotuneCache` keyed like
  plan keys, so the *next* process skips the probes entirely
  (``selected_by="cache"``).

The cost model is the PR 7 ROADMAP hook made real: a per-strategy linear
model ``us ~ c0 + c_w*wire_bytes + c_c*collective_count +
alpha*intra_node_sends + beta*inter_node_sends`` with ``beta >= alpha >= 0``
enforced structurally (an inter-node send costs at least as much as an
intra-node one) and every non-intercept coefficient clamped nonnegative, so
predictions are monotone in ``wire_bytes`` and in ``inter_node_sends``.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import time
from typing import Any, Callable, Mapping, Sequence

import numpy as np

#: the sentinel value `StrategyConfig`/CLIs use to request autotuning
AUTO = "auto"

#: env vars naming the trace file the cost model fits from and the
#: persistent calibration-verdict cache (both optional; the sweep CLI's
#: ``--autotune-trace``/``--autotune-cache`` set them so worker subprocesses
#: inherit the same selection inputs)
TRACE_ENV = "REPRO_AUTOTUNE_TRACE"
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

#: partition counts the candidate grid tries for partitioning strategies
DEFAULT_PART_COUNTS = (1, 2, 4)

#: timed-probe shape: short, Comb-style (warmup then a timed run)
PROBE_CYCLES = 3
PROBE_WARMUP = 1


# ---------------------------------------------------------------------------
# candidates and their static features
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One selectable exchange cell (the §VI coordinates autotuning ranges
    over; mapping/transport stay pinned by the caller — a driver cannot
    re-place an already-built mesh)."""

    strategy: str
    packer: str
    coalesce: bool
    n_parts: int = 1


@dataclasses.dataclass(frozen=True)
class CellFeatures:
    """Static cost-model inputs of one candidate on one topology — pure
    table math (:func:`repro.core.transport.schedule_locality`), no timing."""

    wire_bytes: int
    collective_count: int
    intra_sends: int
    inter_sends: int

    @property
    def total_sends(self) -> int:
        return self.intra_sends + self.inter_sends

    def vector(self) -> tuple[float, ...]:
        """The regression row: ``[1, wire, collectives, total, inter]`` —
        parameterizing locality as ``alpha*total + delta*inter`` makes the
        fitted inter-node cost ``alpha + delta >= alpha`` by construction."""
        return (1.0, float(self.wire_bytes), float(self.collective_count),
                float(self.total_sends), float(self.inter_sends))


def max_face_elems(
    ghosted_shape: Sequence[int], array_axes: Sequence[int], halo: int
) -> int:
    """Largest face-slab element count of an exchange: ``halo`` thick along
    the exchanged axis, full ghosted extent along every other axis (the
    sequential corner-trick slab — matches ``Domain.max_face_bytes``)."""
    assert array_axes, "no decomposed axes"
    best = 0
    for a in array_axes:
        elems = halo * math.prod(
            g for i, g in enumerate(ghosted_shape) if i != a
        )
        best = max(best, elems)
    return best


def default_candidates(
    *,
    dtype: Any = "float32",
    strategies: Sequence[str] | None = None,
    packers: Sequence[str] | None = None,
    coalesce_modes: Sequence[bool] | None = None,
    part_counts: Sequence[int] = DEFAULT_PART_COUNTS,
) -> tuple[Candidate, ...]:
    """The candidate grid, honoring any caller-pinned axis.

    ``packers=None`` enumerates only the *exact* registered packers
    (``wire_tolerance == (0, 0)`` for ``dtype``): autotuning must never
    silently pick lossy wire compression — bf16/scaled-int8 stay opt-in by
    explicit pin, exactly as everywhere else in the repo.
    """
    from repro.core.transport import available_packers, get_packer
    from repro.stencil.strategies import available_strategies, get_strategy

    if strategies is None:
        strategies = available_strategies()
    if packers is None:
        packers = tuple(
            p for p in available_packers()
            if get_packer(p).wire_tolerance(dtype) == (0.0, 0.0)
        )
    else:
        for p in packers:
            get_packer(p)
    if coalesce_modes is None:
        coalesce_modes = (False, True)
    out = []
    for s in strategies:
        parts = (
            tuple(dict.fromkeys(part_counts))
            if get_strategy(s).uses_partitions else (1,)
        )
        for coalesce in coalesce_modes:
            for packer in packers:
                out.extend(
                    Candidate(s, packer, bool(coalesce), p) for p in parts
                )
    assert out, "empty candidate grid"
    return tuple(out)


# ---------------------------------------------------------------------------
# trace-driven cost model
# ---------------------------------------------------------------------------


def _fit_nonneg(rows: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with every non-intercept coefficient clamped >= 0
    (active-set style: refit with negative columns removed until clean).
    Keeps predictions monotone in every feature; the intercept stays free."""
    n_cols = rows.shape[1]
    keep = set(range(1, n_cols))
    while True:
        cols = [0] + sorted(keep)
        coef_sub, *_ = np.linalg.lstsq(rows[:, cols], y, rcond=None)
        neg = [c for c, v in zip(cols, coef_sub) if c != 0 and v < 0]
        if not neg:
            coef = np.zeros(n_cols)
            coef[cols] = coef_sub
            return coef
        keep -= set(neg)
        if not keep:
            coef = np.zeros(n_cols)
            coef[0] = float(np.mean(y)) if len(y) else 0.0
            return coef


class TraceCostModel:
    """Per-strategy linear model over the static schedule features.

    ``predict`` is monotone (non-strictly) in ``wire_bytes`` and in
    ``inter_node_sends`` with everything else fixed, and the implied
    inter-node per-send cost is always >= the intra-node one — the
    locality-weighted form the ROADMAP's autotuner hook asked for.
    """

    def __init__(self, coefs: Mapping[str, np.ndarray]):
        self._coefs = dict(coefs)

    @classmethod
    def fit(cls, records: Sequence[Mapping]) -> "TraceCostModel":
        by_strategy: dict[str, list[tuple[tuple, float]]] = {}
        for r in records:
            feats = record_features(r)
            if feats is None:
                continue
            by_strategy.setdefault(r["strategy"], []).append(
                (feats.vector(), float(r["us_per_cycle"]))
            )
        coefs = {}
        for strategy, pairs in by_strategy.items():
            rows = np.array([v for v, _ in pairs], dtype=float)
            y = np.array([us for _, us in pairs], dtype=float)
            coefs[strategy] = _fit_nonneg(rows, y)
        return cls(coefs)

    def covers(self, strategy: str) -> bool:
        return strategy in self._coefs

    def predict(self, strategy: str, feats: CellFeatures) -> float:
        coef = self._coefs[strategy]
        us = float(np.dot(coef, np.asarray(feats.vector())))
        return max(us, 0.0)

    def locality_costs(self, strategy: str) -> tuple[float, float]:
        """(intra, inter) fitted per-send costs; inter >= intra always."""
        coef = self._coefs[strategy]
        alpha, delta = float(coef[3]), float(coef[4])
        return alpha, alpha + delta


def record_features(r: Mapping) -> CellFeatures | None:
    """The model features carried by a BENCH sweep record (``None`` when the
    record predates the locality/coalescing schema)."""
    try:
        return CellFeatures(
            wire_bytes=int(r.get("wire_bytes", r["message_bytes"])),
            collective_count=int(r["collective_count"]),
            intra_sends=int(r["intra_node_sends"]),
            inter_sends=int(r["inter_node_sends"]),
        )
    except (KeyError, TypeError):
        return None


# ---------------------------------------------------------------------------
# persistent calibration-verdict cache
# ---------------------------------------------------------------------------


def default_cache_path() -> str:
    return os.environ.get(CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json"
    )


class AutotuneCache:
    """Durable ``cell key -> calibration verdict`` table (json on disk).

    Keys are built like plan keys — topology, dtype/shape, placement,
    transport, and the candidate grid that was raced — so a verdict is only
    reused for the exact selection problem it answered.  Writes are atomic
    (tempfile + rename); a missing or corrupt file is an empty cache, never
    an error (tuning must degrade to probing, not crash the exchange).
    """

    def __init__(self, path: str):
        self.path = path
        self._table: dict[str, dict] | None = None

    def _load(self) -> dict[str, dict]:
        if self._table is None:
            try:
                with open(self.path) as f:
                    payload = json.load(f)
                self._table = dict(payload) if isinstance(payload, dict) else {}
            except (OSError, ValueError):
                self._table = {}
        return self._table

    def get(self, key: str) -> dict | None:
        return self._load().get(key)

    def put(self, key: str, verdict: dict) -> None:
        table = self._load()
        table[key] = verdict
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=os.path.dirname(self.path) or ".", suffix=".autotune"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(table, f, indent=1)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        return len(self._load())


def cell_key(cell: Mapping, candidates: Sequence[Candidate]) -> str:
    """The cache key of one selection problem (string: json must round-trip
    it; candidate order is irrelevant)."""
    cand = ";".join(
        f"{c.strategy}@{c.packer}/c{int(c.coalesce)}/p{c.n_parts}"
        for c in sorted(candidates, key=lambda c: (
            c.strategy, c.packer, c.coalesce, c.n_parts))
    )
    return (
        f"mesh={tuple(cell['mesh_shape'])}|shape={tuple(cell['shape'])}"
        f"|dtype={cell['dtype']}|halo={cell['halo']}"
        f"|mapping={cell['mapping']}|transport={cell['transport']}"
        f"|node_size={cell['node_size']}|{cand}"
    )


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Verdict:
    """One selection outcome: the chosen cell plus its provenance — what
    drivers stamp into plan keys (``selected_by``) and BENCH records
    (``selected_by``/``predicted_us``/``calibration_us``)."""

    candidate: Candidate
    #: "trace" | "trace-nearest" | "model" | "calibration" | "cache"
    selected_by: str
    predicted_us: float
    #: wall time spent probing (0 for trace-driven and cache-hit verdicts)
    calibration_us: float = 0.0

    def plan_stamp(self) -> str:
        """What lands in plan keys: a cache hit replays the original
        calibration verdict, so the stamp (and therefore the plan key)
        stays identical across processes — only the BENCH record says
        "cache"."""
        return "calibration" if self.selected_by == "cache" else (
            self.selected_by
        )


class Tuner:
    """Trace-first, probe-fallback plan selection."""

    def __init__(
        self,
        trace_records: Sequence[Mapping] = (),
        cache: AutotuneCache | None = None,
    ):
        # only static measurements are ground truth: an autotuned record
        # re-fed as trace would amplify earlier selection, not evidence
        self.trace = [r for r in trace_records if not r.get("selected_by")]
        self.model = TraceCostModel.fit(self.trace) if self.trace else None
        self.cache = cache

    # -- trace backend ------------------------------------------------------
    def _trace_rows(self, cand: Candidate, cell: Mapping) -> list[Mapping]:
        rows = []
        for r in self.trace:
            if (r.get("strategy") == cand.strategy
                    and r.get("packer", "slice") == cand.packer
                    and bool(r.get("coalesce", False)) == cand.coalesce
                    and int(r.get("n_parts", 1)) == cand.n_parts
                    and r.get("mapping", "row-major") == cell["mapping"]
                    and r.get("transport", "ppermute") == cell["transport"]
                    and tuple(r.get("mesh_shape", ())) == tuple(
                        cell["mesh_shape"])
                    and int(r.get("node_size", 0)) == int(cell["node_size"])):
                rows.append(r)
        return rows

    def trace_verdict(
        self, cand: Candidate, feats: CellFeatures, cell: Mapping
    ) -> Verdict | None:
        rows = self._trace_rows(cand, cell)
        if not rows:
            if self.model is not None and self.model.covers(cand.strategy):
                return Verdict(cand, "model",
                               self.model.predict(cand.strategy, feats))
            return None
        mb = int(cell["message_bytes"])
        exact = [r for r in rows if int(r["message_bytes"]) == mb]
        if exact:
            us = float(np.mean([r["us_per_cycle"] for r in exact]))
            return Verdict(cand, "trace", us)
        # nearest swept size (log distance: 2x too small == 2x too big),
        # shifted by the model's delta between the two feature points
        nearest = min(
            rows, key=lambda r: abs(math.log(max(int(r["message_bytes"]), 1)
                                             / max(mb, 1)))
        )
        us = float(nearest["us_per_cycle"])
        near_feats = record_features(nearest)
        if (self.model is not None and self.model.covers(cand.strategy)
                and near_feats is not None):
            us += (self.model.predict(cand.strategy, feats)
                   - self.model.predict(cand.strategy, near_feats))
        return Verdict(cand, "trace-nearest", max(us, 0.0))

    def choose(
        self,
        candidates: Sequence[Candidate],
        features: Mapping[Candidate, CellFeatures],
        cell: Mapping,
    ) -> Verdict | None:
        """Trace-driven selection, or ``None`` when no candidate has any
        trace/model support (the caller then calibrates).

        Tiered: measured cells (``trace``) outrank size-interpolated ones
        (``trace-nearest``), which outrank pure model extrapolation — a
        modeled candidate can never beat a measured one on predicted
        microseconds alone.
        """
        verdicts = [
            v for c in candidates
            if (v := self.trace_verdict(c, features[c], cell)) is not None
        ]
        if not verdicts:
            return None
        for tier in ("trace", "trace-nearest", "model"):
            in_tier = [v for v in verdicts if v.selected_by == tier]
            if in_tier:
                return min(in_tier, key=lambda v: v.predicted_us)
        raise AssertionError(verdicts)  # unreachable: tiers are exhaustive

    # -- calibration backend -----------------------------------------------
    def calibrate(
        self,
        candidates: Sequence[Candidate],
        cell: Mapping,
        probe: Callable[[Candidate], float],
    ) -> Verdict:
        """Race the candidates with short timed probes; memoize the verdict.

        A probe that raises is skipped — its plan build aborted before the
        cache insert (``PlanCache.get_or_init`` inserts only after a
        successful init), so a failing candidate can never poison the
        caller's plan cache or win the race.
        """
        key = cell_key(cell, candidates)
        if self.cache is not None:
            hit = self.cache.get(key)
            if hit is not None:
                return Verdict(
                    Candidate(hit["strategy"], hit["packer"],
                              bool(hit["coalesce"]), int(hit["n_parts"])),
                    "cache", float(hit["predicted_us"]), 0.0,
                )
        t0 = time.perf_counter()
        best: tuple[float, Candidate] | None = None
        errors: list[str] = []
        for cand in candidates:
            try:
                us = float(probe(cand))
            except Exception as e:  # noqa: BLE001 — a candidate may be
                # unbuildable on this topology; skip it, never crash tuning
                errors.append(f"{cand.strategy}@{cand.packer}: {e}")
                continue
            if best is None or us < best[0]:
                best = (us, cand)
        calibration_us = (time.perf_counter() - t0) * 1e6
        if best is None:
            raise RuntimeError(
                "autotune calibration: every candidate probe failed:\n  "
                + "\n  ".join(errors)
            )
        us, cand = best
        if self.cache is not None:
            self.cache.put(key, {
                "strategy": cand.strategy, "packer": cand.packer,
                "coalesce": cand.coalesce, "n_parts": cand.n_parts,
                "predicted_us": us, "calibration_us": calibration_us,
            })
        return Verdict(cand, "calibration", us, calibration_us)

    def choose_or_calibrate(
        self,
        candidates: Sequence[Candidate],
        features: Mapping[Candidate, CellFeatures],
        cell: Mapping,
        probe: Callable[[Candidate], float],
    ) -> Verdict:
        verdict = self.choose(candidates, features, cell)
        if verdict is not None:
            return verdict
        return self.calibrate(candidates, cell, probe)


# ---------------------------------------------------------------------------
# process-wide default tuner (env-configured)
# ---------------------------------------------------------------------------

_TUNERS: dict[tuple[str | None, str | None], Tuner] = {}


def default_tuner() -> Tuner:
    """The env-configured tuner: trace from ``REPRO_AUTOTUNE_TRACE`` (fitted
    once per process per path), persistent verdicts at
    ``REPRO_AUTOTUNE_CACHE`` (default ``~/.cache/repro/autotune.json``).
    Sweep worker subprocesses inherit both through ``worker_env``."""
    trace_path = os.environ.get(TRACE_ENV) or None
    cache_path = default_cache_path()
    key = (trace_path, cache_path)
    if key not in _TUNERS:
        records: list[Mapping] = []
        if trace_path:
            from repro.stencil.sweep import read_bench_json

            records, _config = read_bench_json(trace_path)
        _TUNERS[key] = Tuner(records, cache=AutotuneCache(cache_path))
    return _TUNERS[key]


def reset_default_tuners() -> None:
    """Drop memoized tuners (tests re-pointing the env vars)."""
    _TUNERS.clear()


# ---------------------------------------------------------------------------
# mapping selection (mesh-build time — a driver cannot re-place its mesh)
# ---------------------------------------------------------------------------


def choose_mapping(
    mesh_shape: Sequence[int], node_size: int, periodic: bool = True
) -> str:
    """The registered mapping minimizing inter-node nearest-neighbor sends
    on this torus — the ``mapping="auto"`` resolution the launch layer runs
    *before* building a mesh.

    Scored on the generic halo pattern (one +/-1 exchange per mesh axis)
    rather than any one strategy's tables: the placement axis is schedule-
    independent (re-plan purity), so the neighbor structure is all that
    matters.  Ties resolve in registration order (row-major first — the
    identity placement wins unless a permutation strictly helps).
    """
    import itertools

    from repro.launch.mapping import available_mappings, get_mapping

    shape = tuple(mesh_shape)

    def flat(coords: Sequence[int]) -> int:
        idx = 0
        for c, k in zip(coords, shape):
            idx = idx * k + c
        return idx

    best_name, best_inter = None, None
    for name in available_mappings():
        node_of = get_mapping(name).node_of(shape, node_size)
        inter = 0
        for coords in itertools.product(*map(range, shape)):
            for a, k in enumerate(shape):
                if k == 1:
                    continue
                for d in (-1, 1):
                    c = coords[a] + d
                    if not periodic and not 0 <= c < k:
                        continue
                    dst = list(coords)
                    dst[a] = c % k
                    if node_of[flat(coords)] != node_of[flat(dst)]:
                        inter += 1
        if best_inter is None or inter < best_inter:
            best_name, best_inter = name, inter
    assert best_name is not None
    return best_name
