"""Loop-aware HLO-text analysis: FLOPs, HBM bytes, collective wire bytes.

``compiled.cost_analysis()`` counts every ``while`` (scan) body **once**, not
x trip-count (verified empirically on jax 0.8.2), and omits collective traffic
entirely.  Both gaps matter enormously for scanned-layer models (a 32-layer
llama is one scan body), so this module re-derives all three roofline inputs
directly from the post-optimization HLO text:

* per-computation symbol tables (every op's result shape/bytes),
* ``dot`` FLOPs = 2 x |result| x |contracting dims| (from lhs shape +
  ``lhs_contracting_dims``); fusions contribute their inner dots,
* HBM bytes ~= sum over *top-level* ops of (operand + result bytes) — inner
  fusion ops stay in registers/VMEM, mirroring XLA's own cost model,
* collective wire bytes per device with ring-algorithm transfer factors,
* ``while`` trip counts parsed from the ROOT ``compare(counter, constant)``
  of each loop condition (exact for ``lax.scan``), loops nested arbitrarily.

Validated against ``cost_analysis()`` on loop-free programs in
tests/core/test_hlo_analysis.py.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops whose operands/results do NOT represent HBM traffic
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "iota",  # generated on the fly
    # scheduled HLO inserts copies around while-loop carries that buffer
    # assignment later elides/aliases (the carried buffers are marked
    # dynamic_variable_tuple_indices); charging them would count whole
    # loop-stacked activation buffers per iteration.  Real resharding copies
    # are undercounted by this — acceptable (documented in DESIGN.md §6).
    "copy", "copy-start", "copy-done",
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# NOTE: tuple types contain ``/*index=5*/`` comments (an '=' inside the type),
# so the type group must be a lazy any-match, not [^=]*.
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<type>.*?)"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<rest>.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?[\w\.\-]+\s*\(.*\)\s*->\s*.*\{\s*$")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALL_TARGET_RE = re.compile(
    r"(?:to_apply|condition|body|calls)=%?([\w\.\-]+)")


def _shape_of(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d.strip()) if dims.strip() else ()
        out.append((dtype, shape))
    return out


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, shape in _shape_of(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(shape: tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclass
class Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    wire_by_op: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(int))
    # children: ('while', body, cond, known_trips) | ('call', name, None)
    #         | ('cond', branch_names, None)
    children: list = field(default_factory=list)
    fusion_calls: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # op name -> type str
    consts: dict = field(default_factory=dict)  # op name -> int literal
    root_line: str = ""


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(op: str, result_bytes: float, g: int) -> float:
    g = max(g, 1)
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(result_bytes) * (g - 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    if op == "collective-permute":
        return float(result_bytes)
    raise ValueError(op)


def _parse(text: str, default_group: int) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    cur: Comp | None = None
    entry_name = None
    for line in text.splitlines():
        if _COMP_START_RE.match(line):
            name = line.strip().split("(")[0].replace("ENTRY", "").strip()
            name = name.lstrip("%").strip()
            cur = Comp(name=name)
            comps[name] = cur
            if line.startswith("ENTRY"):
                entry_name = name
            continue
        if cur is None or line.startswith("}"):
            continue
        m = _OP_LINE_RE.match(line)
        if not m:
            continue
        name, type_str, op, args, rest = (
            m.group("name"), m.group("type"), m.group("op"),
            m.group("args"), m.group("rest"))
        cur.types[name] = type_str
        if op == "constant":
            lit = re.match(r"^\s*(-?\d+)\s*$", args)
            if lit:
                cur.consts[name] = int(lit.group(1))
            continue
        if line.strip().startswith("ROOT"):
            cur.root_line = line

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLLECTIVES and not op.endswith("-done"):
            rb = _type_bytes(type_str)
            g = _group_size(line, default_group)
            wb = _wire_bytes(base_op, rb, g)
            cur.wire += wb
            cur.wire_by_op[base_op] += wb
            cur.coll_counts[base_op] += 1
            cur.bytes += rb  # collectives also touch HBM
            continue

        if op == "while":
            tm = re.search(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)", rest)
            if tm:
                # exact trip count from the scheduler's backend_config when
                # present (always for lax.scan); else parsed from the cond.
                km = _TRIP_RE.search(rest)
                known = int(km.group(1)) if km else None
                cur.children.append(("while", tm.group(2), tm.group(1), known))
            continue
        if op in ("call", "custom-call") and "to_apply=" in rest:
            tm = re.search(r"to_apply=%?([\w\.\-]+)", rest)
            if tm:
                cur.children.append(("call", tm.group(1), None, None))
            if op == "call":
                continue
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^\}]*)\}|"
                                  r"true_computation=%?([\w\.\-]+)|"
                                  r"false_computation=%?([\w\.\-]+))", rest)
            names = []
            for tup in branches:
                for t in tup:
                    if t:
                        names.extend(_OPERAND_RE.findall(t))
            if names:
                cur.children.append(("cond", tuple(names), None, None))
            continue

        if op == "fusion":
            tm = re.search(r"calls=%?([\w\.\-]+)", rest)
            if tm:
                cur.fusion_calls.append((name, tm.group(1)))

        # --- dot flops (top-level dots; fusion-inner dots added via calls) ---
        if op == "dot":
            cur.flops += _dot_flops(cur, type_str, args, rest)

        # --- HBM byte traffic for top-level ops ---
        if op not in _NO_TRAFFIC_OPS:
            cur.bytes += _op_traffic(cur, name, op, type_str, args)
    # fusion computations contribute their inner dot flops to the caller
    return comps if entry_name is None else {**comps, "__entry__": comps[entry_name]}


def _op_traffic(comp: Comp, name: str, op: str, type_str: str, args: str) -> float:
    """Approximate HBM bytes for one top-level op.

    In-place/sparse-access ops must not be charged their full buffer size:
    * dynamic-update-slice (and fusions rooted there, e.g. scan's per-layer
      activation stacking) aliases the big operand — traffic ~= 3x the update;
    * dynamic-slice / gather read only the slice — traffic ~= 2x the result;
    * scatter writes only the updates — traffic ~= 3x the updates.
    Everything else: result + operands (XLA's own cost-model convention).
    """
    result_b = _type_bytes(type_str)
    operand_b = []
    for operand in _operand_names(args):
        t = comp.types.get(operand)
        if t is not None:
            operand_b.append(_type_bytes(t))
    tag = f"{op} {name}"
    if "dynamic-update-slice" in tag or "scatter" in tag:
        small = sum(operand_b) - (max(operand_b) if operand_b else 0.0)
        return 3.0 * small
    if "dynamic-slice" in tag or "gather" in tag:
        return 2.0 * result_b
    if op == "fusion" and not any(
            k in name for k in ("reduce", "dot", "convolution")):
        # non-reducing fusion: inputs are consumed at the result's
        # granularity (exact for transpose/sort/elementwise roots); a fusion
        # that slices from a loop-stacked buffer must not be charged the
        # whole buffer per iteration.  Only reduce-rooted fusions (operand
        # legitimately larger than result) and dot/conv fusions keep full
        # operand counting.
        return result_b + sum(min(b, result_b) for b in operand_b)
    return result_b + sum(operand_b)


def _split_top_level(args: str) -> list[str]:
    """Split an operand list on commas *outside* ``[]``/``{}``/``()`` — HLO
    operand types (``f32[16,16]{1,0}``) and tuple types contain commas."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _operand_names(args: str) -> list[str]:
    out = []
    for token in _split_top_level(args):
        token = token.strip()
        # typed reference: the %name is the last %-token (tuple types may
        # embed other %refs only in comments, which HLO does not emit here).
        refs = re.findall(r"%([\w\.\-]+)", token)
        if refs:
            out.append(refs[-1])
            continue
        m = re.match(r"^(?:[a-z0-9_]+\[[\d,]*\]\{[^\}]*\}\s+)?([\w\.\-]+)$", token)
        if m:
            out.append(m.group(1))
    return out


def _dot_flops(comp: Comp, result_type: str, args: str, rest: str) -> float:
    shapes = _shape_of(result_type)
    if not shapes:
        return 0.0
    result_elems = _numel(shapes[0][1])
    contracting = 1
    dm = _DIMS_RE.search(rest)
    operands = _operand_names(args)
    if dm and operands:
        lhs_type = comp.types.get(operands[0])
        if lhs_type:
            lhs_shapes = _shape_of(lhs_type)
            if lhs_shapes:
                lhs_shape = lhs_shapes[0][1]
                for idx in dm.group(1).split(","):
                    idx = idx.strip()
                    if idx and int(idx) < len(lhs_shape):
                        contracting *= lhs_shape[int(idx)]
    return 2.0 * result_elems * contracting


def _trip_count(cond: Comp | None, default_trip: int) -> int:
    """Exact trip count from the ROOT compare(counter, constant) of a scan
    condition; falls back to ``default_trip``."""
    if cond is None:
        return default_trip
    line = cond.root_line
    if "compare(" in line:
        for operand in _operand_names(line.split("compare(", 1)[1].split(")")[0]):
            if operand in cond.consts:
                return max(1, cond.consts[operand])
    if cond.consts:
        return max(1, max(cond.consts.values()))
    return default_trip


@dataclass
class HloStats:
    """Loop-aware per-device totals for one compiled executable."""

    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    by_op_bytes: dict = field(default_factory=dict)
    by_op_counts: dict = field(default_factory=dict)
    n_loops: int = 0
    trip_counts: list = field(default_factory=list)

    def summary(self) -> str:
        parts = [f"flops={self.flops:.3e}", f"bytes={self.bytes:.3e}",
                 f"wire={self.wire_bytes/1e9:.3f}GB"]
        for op in sorted(self.by_op_bytes):
            parts.append(f"{op}={self.by_op_bytes[op]/1e9:.3f}GB"
                         f"(x{self.by_op_counts[op]})")
        return " ".join(parts)


def analyze_hlo(
    text: str,
    *,
    default_group: int = 1,
    default_trip: int = 1,
    trip_overrides: dict[str, int] | None = None,
) -> HloStats:
    comps = _parse(text, default_group)
    entry = comps.get("__entry__")
    stats = HloStats()
    by_bytes: dict[str, float] = defaultdict(float)
    by_counts: dict[str, int] = defaultdict(int)

    def fusion_flops(comp: Comp) -> float:
        total = 0.0
        for _, callee in comp.fusion_calls:
            sub = comps.get(callee)
            if sub is not None:
                total += sub.flops + fusion_flops(sub)
        return total

    def walk(comp: Comp, scale: float, depth: int = 0) -> None:
        if depth > 24:
            return
        stats.flops += (comp.flops + fusion_flops(comp)) * scale
        stats.bytes += comp.bytes * scale
        stats.wire_bytes += comp.wire * scale
        for op, b in comp.wire_by_op.items():
            by_bytes[op] += b * scale
            by_counts[op] += comp.coll_counts[op]
        for kind, target, cond_name, known in comp.children:
            if kind == "while":
                body = comps.get(target)
                cond = comps.get(cond_name) if cond_name else None
                if trip_overrides and target in trip_overrides:
                    trips = trip_overrides[target]
                elif known is not None:
                    trips = known
                else:
                    trips = _trip_count(cond, default_trip)
                stats.n_loops += 1
                stats.trip_counts.append(trips)
                if body is not None:
                    walk(body, scale * trips, depth + 1)
                if cond is not None:
                    walk(cond, scale * trips, depth + 1)
            elif kind == "call":
                callee = comps.get(target)
                if callee is not None:
                    walk(callee, scale, depth + 1)
            elif kind == "cond":
                best = None
                for name in target:
                    c = comps.get(name)
                    if c is not None and (best is None or c.flops > best.flops):
                        best = c
                if best is not None:
                    walk(best, scale, depth + 1)

    if entry is not None:
        walk(entry, 1.0)
    stats.by_op_bytes = dict(by_bytes)
    stats.by_op_counts = dict(by_counts)
    return stats


# backwards-compatible wrapper (collectives only)
def parse_collectives(hlo_text: str, *, default_group: int = 1,
                      trip_overrides: dict[str, int] | None = None,
                      default_trip: int | None = None):
    stats = analyze_hlo(hlo_text, default_group=default_group,
                        default_trip=default_trip or 1,
                        trip_overrides=trip_overrides)

    class _Compat:
        wire_bytes = stats.wire_bytes
        by_op_bytes = stats.by_op_bytes
        by_op_counts = stats.by_op_counts
        n_loops_scaled = stats.n_loops

        @staticmethod
        def summary() -> str:
            return stats.summary()

    return _Compat()


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e-class constants, per task spec)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link (1 link assumed; conservative)
    hbm_per_chip: float = 16e9


V5E = Hardware()


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def step_time_s(self) -> float:
        """Roofline step time: the dominant term (perfect-overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device): fraction of compiled compute
        that is 'useful' — catches remat/redundancy waste."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        t = self.step_time_s
        return (self.model_flops / V5E.peak_flops) / t if t else 0.0


def roofline(
    *,
    hlo_flops_per_device: float,
    hlo_bytes_per_device: float,
    wire_bytes_per_device: float,
    model_flops_global: float,
    n_chips: int,
    hw: Hardware = V5E,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops_per_device / hw.peak_flops,
        memory_s=hlo_bytes_per_device / hw.hbm_bw,
        collective_s=wire_bytes_per_device / hw.ici_bw,
        model_flops=model_flops_global / max(1, n_chips),
        hlo_flops=hlo_flops_per_device,
        hlo_bytes=hlo_bytes_per_device,
        wire_bytes=wire_bytes_per_device,
    )
