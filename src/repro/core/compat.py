"""Version-compatibility shims over drifting jax APIs.

The repo targets the pinned container environment but must survive the API
drift between jax 0.4.x and 0.8.x that hits exactly the surfaces this
codebase leans on:

* ``jax.shard_map``           — top-level alias + ``check_vma`` kwarg are new;
  older releases only have ``jax.experimental.shard_map.shard_map`` with the
  ``check_rep`` kwarg.
* ``jax.sharding.AxisType``   — introduced with the explicit-sharding work;
  absent on 0.4.x (where every mesh axis is implicitly "auto").
* ``jax.make_mesh(axis_types=...)`` — the kwarg follows ``AxisType``.
* ``Compiled.cost_analysis()``  — returns a dict on new jax, a one-element
  list of dicts on 0.4.x.

Every call site in src/, tests/ and benchmarks/ goes through these wrappers
instead of feature-testing jax inline.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence

import jax

__all__ = [
    "shard_map",
    "make_mesh",
    "axis_type_auto",
    "axis_size",
    "cost_analysis_dict",
    "enable_cpu_collectives",
]


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, inside ``shard_map``.

    ``lax.axis_size`` is new jax; on 0.4.x ``jax.core.axis_frame(name)``
    returns the size (an int, or a frame carrying ``.size`` on some
    releases).  Must stay a *python int* — the halo code unrolls loops and
    builds permutation tables from it at trace time.
    """
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    size = getattr(frame, "size", frame)
    return int(size)


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check: bool = False,
) -> Callable:
    """``jax.shard_map`` with the replication/VMA check disabled by default.

    ``check`` maps to ``check_vma`` (new jax) or ``check_rep`` (old jax) —
    the manual collectives in :mod:`repro.core.halo` and the models are not
    expressible under either checker.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check,
            )
        except TypeError:  # jax with top-level alias but pre-VMA kwarg
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def axis_type_auto() -> Any | None:
    """``jax.sharding.AxisType.Auto`` where it exists, else ``None``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else axis_type.Auto


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Sequence[Any] | None = None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with auto axis types when the installed jax has them.

    On jax without ``AxisType`` every axis is already auto-typed, so the
    kwarg is simply dropped.
    """
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    auto = axis_type_auto()
    if auto is not None and "axis_types" in inspect.signature(
        jax.make_mesh
    ).parameters:
        kwargs["axis_types"] = (auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def set_mesh(mesh: jax.sharding.Mesh) -> Any:
    """Context manager installing ``mesh`` as the ambient mesh for ``jit``.

    ``jax.set_mesh`` is new jax; on 0.4.x a ``Mesh`` is itself the context
    manager with the same sharding-resolution effect for these programs.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def pallas_tpu_compiler_params(**kwargs: Any) -> Any:
    """``pltpu.CompilerParams`` (new name) / ``pltpu.TPUCompilerParams`` (old).

    Same kwargs (``dimension_semantics`` etc.); only the class name drifted.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def enable_cpu_collectives() -> None:
    """Turn on cross-process collectives for the CPU backend (gloo).

    jax 0.4.x needs ``jax_cpu_collectives_implementation`` flipped to
    ``"gloo"`` *before* backend init or multi-process ``ppermute`` on CPU
    fails with "Multiprocess computations aren't implemented on the CPU
    backend"; newer jax selects a CPU collectives implementation
    automatically (and may drop the option), so unknown-option errors are
    swallowed.  Must run before the first device query of the process.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):
        pass  # newer jax: option gone, collectives already wired


def distributed_initialize(
    *,
    coordinator_address: str,
    num_processes: int,
    process_id: int,
    timeout: float | None = None,
) -> None:
    """``jax.distributed.initialize`` with a bounded coordinator connect.

    Without a bound, a worker whose coordinator died before binding blocks
    in the barrier forever (the zombie-grid failure mode
    :func:`repro.launch.stencil.launch_grid` must reap).
    ``initialization_timeout`` is feature-detected: jax versions that
    predate the kwarg fall back to the unbounded call (the launcher-side
    reap still bounds the grid).
    """
    import inspect

    kwargs: dict[str, Any] = dict(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    if timeout is not None:
        params = inspect.signature(jax.distributed.initialize).parameters
        if "initialization_timeout" in params:
            kwargs["initialization_timeout"] = max(1, int(timeout))
    jax.distributed.initialize(**kwargs)


def cost_analysis_dict(compiled: Any) -> dict:
    """Normalize ``Compiled.cost_analysis()`` to a flat dict.

    jax 0.4.x returns ``[{...}]`` (one entry per program); newer jax returns
    the dict directly.  An empty analysis normalizes to ``{}``.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
