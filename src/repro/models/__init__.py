from repro.models.api import Model, batch_spec, build_model, concrete_batch

__all__ = ["Model", "batch_spec", "build_model", "concrete_batch"]
