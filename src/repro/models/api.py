"""Unified model API: every assigned architecture behind one interface.

    model = build_model(get_config("llama3-8b"))
    params = model.init(jax.random.key(0))
    loss   = model.loss(params, batch, ctx=ctx)
    cache  = model.init_cache(batch=8, max_len=1024)
    logits, cache = model.prefill(params, tokens, cache, ctx=ctx)
    logits, cache = model.decode_step(params, token, cache, ctx=ctx)

``batch_spec``/``cache_spec`` produce ShapeDtypeStruct stand-ins for the
dry-run (no allocation); the shapes follow the assigned (arch x shape) cells.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encoder, hybrid, moe, rwkv, transformer, vision
from repro.parallel.context import LOCAL, ParallelContext

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": moe,
    "rwkv": rwkv,
    "ssm": hybrid,  # pure-ssm arch would use a mamba-only stack; zamba covers hybrid
    "hybrid": hybrid,
    "vlm": vision,
    "audio": encoder,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    module: Any

    # -- params ---------------------------------------------------------------
    def init(self, key) -> dict:
        return self.module.init(self.cfg, key)

    def init_shape(self) -> Any:
        """Abstract params (ShapeDtypeStructs) — no allocation."""
        return jax.eval_shape(lambda k: self.module.init(self.cfg, k),
                              jax.random.key(0))

    # -- steps ------------------------------------------------------------------
    def loss(self, params, batch, *, ctx: ParallelContext = LOCAL):
        return self.module.loss_fn(self.cfg, params, batch, ctx=ctx)

    def logits(self, params, batch, *, ctx: ParallelContext = LOCAL):
        if self.cfg.family == "vlm":
            return self.module.logits_fn(self.cfg, params, batch["tokens"],
                                         batch["vision_emb"], ctx=ctx)
        if self.cfg.family == "audio":
            return self.module.encode(self.cfg, params, batch["frames"], ctx=ctx)
        return self.module.logits_fn(self.cfg, params, batch["tokens"], ctx=ctx)

    @property
    def has_decode(self) -> bool:
        return not self.cfg.is_encoder_only

    def init_cache(self, batch: int, max_len: int, dtype=None) -> dict:
        return self.module.init_cache(self.cfg, batch, max_len, dtype)

    def prefill(self, params, batch, cache, *, ctx: ParallelContext = LOCAL,
                true_len=None):
        # true_len ((B,) int32, traced): bucket-padded prefill — only the
        # dense transformer supports it (capacity-routed MoE and the VLM
        # cross-attention scan are sequence-length-sensitive).
        kw = {} if true_len is None else {"true_len": true_len}
        if self.cfg.family == "vlm":
            assert true_len is None, "vlm prefill has no bucketed form"
            return self.module.prefill(self.cfg, params, batch["tokens"],
                                       batch["vision_emb"], cache, ctx=ctx)
        return self.module.prefill(self.cfg, params, batch["tokens"], cache,
                                   ctx=ctx, **kw)

    def decode_step(self, params, token, cache, *, ctx: ParallelContext = LOCAL):
        return self.module.decode_step(self.cfg, params, token, cache, ctx=ctx)

    # -- abstract inputs (dry-run) ------------------------------------------------
    def batch_spec(self, shape: ShapeConfig) -> dict:
        return batch_spec(self.cfg, shape)

    def cache_spec(self, batch: int, max_len: int) -> dict:
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len)
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, module=_FAMILY_MODULES[cfg.family])


# ---------------------------------------------------------------------------
# input specs per (arch x shape) cell
# ---------------------------------------------------------------------------


def batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one workload cell's inputs."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        spec = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_vision), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
        return spec
    spec = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }
    if cfg.family == "vlm":
        spec["vision_emb"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_vision), jnp.bfloat16)
    return spec


def concrete_batch(cfg: ModelConfig, shape_or_bs, seq: int | None = None,
                   seed: int = 0) -> dict[str, jax.Array]:
    """Random concrete batch matching ``batch_spec`` (smoke tests, examples)."""
    if isinstance(shape_or_bs, ShapeConfig):
        b, s = shape_or_bs.global_batch, shape_or_bs.seq_len
    else:
        b, s = shape_or_bs, seq
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(k1, (b, s, cfg.d_vision), jnp.float32
                                        ).astype(jnp.bfloat16),
            "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size),
            "mask": (jax.random.uniform(k3, (b, s)) < 0.3).astype(jnp.float32),
        }
    out = {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        out["vision_emb"] = jax.random.normal(
            k3, (b, cfg.vision_tokens, cfg.d_vision), jnp.float32
        ).astype(jnp.bfloat16)
    return out
