"""Mamba2 (SSD) block — the state-space backbone of zamba2.

Selective state space with scalar-per-head decay:
    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * x_t (x) B_t
    y_t = C_t . h_t + D_h x_t
Chunked "SSD" algorithm: intra-chunk attention-like matrix (scalar decay per
head keeps the (c, c) pairwise tensor head-wise, no channel blowup), state
carried across chunks by scan and across *devices* by
:func:`repro.core.ring.state_passing`.  The causal depthwise conv1d takes its
left context from the previous sequence shard via
:func:`repro.core.halo.seq_left_halo` — ghost cells, literally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.configs.base import ModelConfig
from repro.core.halo import seq_left_halo
from repro.core.ring import state_passing
from repro.models import layers as L
from repro.parallel.context import LOCAL, ParallelContext

Params = dict
CHUNK = 32


def dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim, n_state)."""
    di = cfg.d_inner
    nh = cfg.ssm_heads
    assert di % nh == 0
    return di, nh, di // nh, cfg.ssm_state


def conv_channels(cfg: ModelConfig) -> int:
    di, _, _, ns = dims(cfg)
    return di + 2 * ns


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def mamba_params(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    di, nh, hd, ns = dims(cfg)
    ch = conv_channels(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "norm": L.norm_params(cfg),
        "in_proj": L.dense_init(ks[0], d, di + ch + nh, pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, ch)) * 0.2).astype(pd),
        "conv_b": jnp.zeros((ch,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_y": L.norm_params(cfg, di),
        "out_proj": L.dense_init(ks[2], di, d, pd),
    }


# ---------------------------------------------------------------------------
# conv1d (causal, depthwise) with optional cross-shard halo
# ---------------------------------------------------------------------------


def causal_conv(cfg: ModelConfig, lp: Params, x: jax.Array,
                left: jax.Array | None = None) -> jax.Array:
    """x: (B, T, ch). ``left``: (B, k-1, ch) context (ghost cells) or None."""
    kk = cfg.conv_kernel
    if left is None:
        left = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([left, x], axis=1)
    w = lp["conv_w"].astype(x.dtype)
    out = sum(
        xp[:, j: j + x.shape[1]] * w[j] for j in range(kk)
    ) + lp["conv_b"].astype(x.dtype)
    return jax.nn.silu(out)


# ---------------------------------------------------------------------------
# chunked SSD
# ---------------------------------------------------------------------------


def _ssd_chunk(xh, Bm, Cm, dt, la, h_in):
    """xh: (B,c,nh,hd); Bm,Cm: (B,c,ns); dt,la: (B,c,nh); h_in: (B,nh,hd,ns)."""
    Bsz, c, nh, hd = xh.shape
    cum = jnp.cumsum(la, axis=1)  # (B,c,nh), <= 0
    # intra-chunk: y_t = sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t.B_s) x_s
    pair = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,nh)
    mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])[None, :, :, None]
    M = jnp.where(mask, jnp.exp(jnp.minimum(pair, 0.0)), 0.0)  # (B,t,s,nh)
    G = jnp.einsum("btn,bsn->bts", Cm, Bm)  # (B,t,s)
    W = M * G[..., None] * dt[:, None, :, :]  # (B,t,s,nh)
    y = jnp.einsum("btsh,bshp->bthp", W, xh)
    # state term: y_t += exp(cum_t) C_t . h_in
    y = y + jnp.exp(cum)[..., None] * jnp.einsum(
        "btn,bhpn->bthp", Cm, h_in
    )
    # chunk state: h_out = exp(cum_T) h_in + sum_s exp(cum_T-cum_s) dt_s x_s (x) B_s
    total = cum[:, -1]  # (B,nh)
    wdec = dt * jnp.exp(total[:, None] - cum)  # (B,c,nh)
    h_out = jnp.exp(total)[..., None, None] * h_in + jnp.einsum(
        "bshp,bsn,bsh->bhpn", xh, Bm, wdec
    )
    return y, h_out


def ssd_scan(xh, Bm, Cm, dt, la, h0=None, chunk: int = CHUNK):
    """Full sequence SSD: returns (y (B,T,nh,hd), h_final)."""
    Bsz, T, nh, hd = xh.shape
    ns = Bm.shape[-1]
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    n = T // c
    if h0 is None:
        h0 = jnp.zeros((Bsz, nh, hd, ns), jnp.float32)

    xc = xh.reshape(Bsz, n, c, nh, hd).swapaxes(0, 1)
    Bc = Bm.reshape(Bsz, n, c, ns).swapaxes(0, 1)
    Cc = Cm.reshape(Bsz, n, c, ns).swapaxes(0, 1)
    dc = dt.reshape(Bsz, n, c, nh).swapaxes(0, 1)
    lc = la.reshape(Bsz, n, c, nh).swapaxes(0, 1)

    def body(h, inp):
        xx, bb, cc2, dd, ll = inp
        y, h2 = _ssd_chunk(xx, bb, cc2, dd, ll, h)
        return h2, y

    h_fin, ys = jax.lax.scan(body, h0, (xc, Bc, Cc, dc, lc))
    return ys.swapaxes(0, 1).reshape(Bsz, T, nh, hd), h_fin


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def mamba_block(
    cfg: ModelConfig,
    lp: Params,
    x: jax.Array,  # (B, T, d)
    *,
    ctx: ParallelContext = LOCAL,
    conv_state: jax.Array | None = None,  # (B, k-1, ch) decode carry
    ssd_state: jax.Array | None = None,  # (B, nh, hd, ns)
    return_state: bool = False,
):
    Bsz, T, d = x.shape
    di, nh, hd, ns = dims(cfg)
    ch = conv_channels(cfg)
    h = L.apply_norm(cfg, lp["norm"], x)
    proj = h @ lp["in_proj"].astype(x.dtype)  # (B,T,di+ch+nh)
    z, xBC, dt_raw = jnp.split(proj, [di, di + ch], axis=-1)

    seq_par = ctx.seq_parallel and ctx.mesh is not None and ctx.model_axis

    if seq_par:
        spec3 = P(ctx.data_axes, ctx.model_axis, None)

        def conv_shard(xl):
            left = seq_left_halo(xl, ctx.model_axis, cfg.conv_kernel - 1,
                                 seq_axis=1, n_parts=ctx.n_parts)
            return causal_conv(cfg, lp, xl, left=left[:, : cfg.conv_kernel - 1])

        xBC = compat.shard_map(conv_shard, mesh=ctx.mesh, in_specs=spec3,
                            out_specs=spec3)(xBC)
    else:
        xBC = causal_conv(cfg, lp, xBC, left=conv_state)
    new_conv_state = None
    if return_state:
        # keep last k-1 *pre-conv* inputs for the next step
        pre_xBC = proj[..., di: di + ch]
        if conv_state is not None:
            hist = jnp.concatenate([conv_state, pre_xBC], axis=1)
        else:
            hist = jnp.concatenate(
                [jnp.zeros((Bsz, cfg.conv_kernel - 1, ch), x.dtype), pre_xBC], 1)
        new_conv_state = hist[:, -(cfg.conv_kernel - 1):]

    xh = xBC[..., :di].reshape(Bsz, T, nh, hd).astype(jnp.float32)
    Bm = xBC[..., di: di + ns].astype(jnp.float32)
    Cm = xBC[..., di + ns:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # (B,T,nh)
    la = -dt * jnp.exp(lp["A_log"])  # log decay, < 0

    if seq_par:
        spec4 = P(ctx.data_axes, ctx.model_axis, None, None)
        spec3f = P(ctx.data_axes, ctx.model_axis, None)

        chunk = cfg.scan_chunk or CHUNK

        def ssd_shard(xl, bl, cl, dl, ll):
            _, C_seg = ssd_scan(xl, bl, cl, dl, ll, None, chunk=chunk)
            D_seg = jnp.exp(jnp.sum(ll, axis=1))[..., None, None]  # (B,nh,1,1)
            h_in = state_passing(C_seg, D_seg * jnp.ones_like(C_seg),
                                 ctx.model_axis, method=ctx.state_method)
            y, _ = ssd_scan(xl, bl, cl, dl, ll, h_in, chunk=chunk)
            return y

        y = compat.shard_map(
            ssd_shard, mesh=ctx.mesh,
            in_specs=(spec4, spec3f, spec3f, spec3f, spec3f),
            out_specs=spec4
        )(xh, Bm, Cm, dt, la)
        h_fin = None
    else:
        y, h_fin = ssd_scan(xh, Bm, Cm, dt, la, ssd_state,
                            chunk=cfg.scan_chunk or CHUNK)

    y = y + lp["D"][None, None, :, None] * xh  # skip connection
    y = y.reshape(Bsz, T, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.apply_norm(cfg, lp["norm_y"], y)
    out = x + y @ lp["out_proj"].astype(x.dtype)
    if return_state:
        return out, new_conv_state, h_fin
    return out
