"""HuBERT X-Large: encoder-only audio transformer with masked cluster
prediction.

The conv waveform frontend is a STUB (assignment): the batch supplies
precomputed frame embeddings (B, T, d_vision=512) which are projected to
d_model.  Bidirectional attention (causal=False); rotary positions stand in
for HuBERT's conv positional embedding (hardware adaptation note in
DESIGN.md).  Loss: cross-entropy on masked frames against k-means cluster
labels (vocab_size=504).  Encoder-only: no decode path (decode cells skipped).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.context import LOCAL, ParallelContext

Params = dict


def init(cfg: ModelConfig, key) -> Params:
    kf, km, kl, ko = jax.random.split(key, 4)
    return {
        "frame_proj": L.dense_init(kf, cfg.d_vision, cfg.d_model,
                                   jnp.dtype(cfg.param_dtype)),
        "mask_emb": (jax.random.normal(km, (cfg.d_model,)) * 0.02).astype(
            jnp.dtype(cfg.param_dtype)),
        "layers": T.stacked_layer_params(cfg, kl, cfg.n_layers),
        "norm_f": L.norm_params(cfg),
        "head": L.dense_init(ko, cfg.d_model, cfg.vocab_size,
                             jnp.dtype(cfg.param_dtype)),
    }


def hidden_states(cfg: ModelConfig, params: Params, frames: jax.Array,
                  mask: jax.Array | None = None,
                  *, ctx: ParallelContext = LOCAL) -> jax.Array:
    """frames: (B, T, d_vision); mask: (B, T) 1.0 where frame is masked."""
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["frame_proj"].astype(
        jnp.dtype(cfg.dtype))
    if mask is not None:
        x = jnp.where(mask[..., None] > 0,
                      params["mask_emb"].astype(x.dtype), x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    block = T._remat(cfg, functools.partial(T.decoder_block, cfg, ctx=ctx))

    def body(xc, lp):
        return block(lp, xc, positions), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(cfg, params["norm_f"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, batch["frames"], batch.get("mask"), ctx=ctx)
    logits = x @ params["head"].astype(x.dtype)
    return L.cross_entropy(logits, batch["labels"], batch.get("mask"))


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           *, ctx: ParallelContext = LOCAL) -> jax.Array:
    """Inference: cluster logits for every frame (the prefill-shape cell)."""
    x = hidden_states(cfg, params, frames, None, ctx=ctx)
    return x @ params["head"].astype(x.dtype)
