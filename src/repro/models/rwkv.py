"""RWKV-6 (Finch): attention-free time-mix with data-dependent decay.

WKV recurrence per head (state S in R^{hd x hd}):
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
with per-channel decays w_t = exp(-exp(w_base + lora(x_t))) in (0, 1).

Training/prefill uses a chunked algorithm: within a chunk the pairwise decay
exponent ``cum[t-1] - cum[s] <= 0`` is materialized per (t, s, channel) —
numerically safe (never exponentiates a positive number) at the cost of a
(c, c, hd) temporary, with chunk length c kept small.  Across chunks the
state is carried by ``lax.scan``; across *devices* (sequence parallelism)
the chunk states compose associatively and ride
:func:`repro.core.ring.state_passing` — the paper's 1-D stencil transport.

Decode is the exact recurrence (one step).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.configs.base import ModelConfig
from repro.core.ring import state_passing
from repro.models import layers as L
from repro.parallel.context import LOCAL, ParallelContext

Params = dict
CHUNK = 16  # intra-chunk length (keeps the (c, c, hd) temporary small)
LORA_R = 32


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv_head_size
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    h, hd = _heads(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    lora_r = min(LORA_R, d)
    return {
        "ln1": L.norm_params(cfg),
        "ln2": L.norm_params(cfg),
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(pd),
        "wr": L.dense_init(ks[1], d, d, pd),
        "wk": L.dense_init(ks[2], d, d, pd),
        "wv": L.dense_init(ks[3], d, d, pd),
        "wg": L.dense_init(ks[4], d, d, pd),
        "wo": L.dense_init(ks[5], d, d, pd),
        "w_base": (jax.random.normal(ks[6], (d,)) * 0.5 - 1.0).astype(pd),
        "w_lora_a": L.dense_init(ks[7], d, lora_r, pd),
        "w_lora_b": (jnp.zeros((lora_r, d))).astype(pd),
        "u": (jax.random.normal(ks[8], (h, hd)) * 0.1).astype(pd),
        # channel-mix
        "mu_c": (jax.random.uniform(ks[9], (2, d)) * 0.5 + 0.25).astype(pd),
        "ck": L.dense_init(jax.random.fold_in(key, 11), d, cfg.d_ff, pd),
        "cv": L.dense_init(jax.random.fold_in(key, 12), cfg.d_ff, d, pd),
        "cr": L.dense_init(jax.random.fold_in(key, 13), d, d, pd),
    }


def init(cfg: ModelConfig, key) -> Params:
    ke, kl, ko = jax.random.split(key, 3)
    keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model,
                              jnp.dtype(cfg.param_dtype)),
        "ln_in": L.norm_params(cfg),
        "layers": jax.vmap(lambda k: layer_params(cfg, k))(keys),
        "norm_f": L.norm_params(cfg),
        "lm_head": L.embed_init(ko, cfg.vocab_size, cfg.d_model,
                                jnp.dtype(cfg.param_dtype)),
    }


# ---------------------------------------------------------------------------
# WKV chunked scan
# ---------------------------------------------------------------------------


def _wkv_chunk(r, k, v, lw, u, S_in):
    """One chunk of the WKV recurrence.

    r,k,v: (B, c, H, hd); lw: (B, c, H, hd) log-decay (<0); u: (H, hd);
    S_in: (B, H, hd, hd).  Returns (y (B,c,H,hd), S_out).
    """
    B, c, H, hd = r.shape
    cum = jnp.cumsum(lw, axis=1)  # (B,c,H,hd)
    cum_prev = cum - lw  # decay through t-1

    # state term: y_t += (r_t * exp(cum_{t-1})) . S_in
    r_dec = r * jnp.exp(cum_prev)
    y = jnp.einsum("bthi,bhij->bthj", r_dec, S_in)

    # intra-chunk: pairwise exponent (<= 0) materialized per channel
    pair = cum_prev[:, :, None] - cum[:, None, :, :]  # (B,t,s,H,hd)
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :, None, None]
    D = jnp.where(mask, jnp.exp(jnp.minimum(pair, 0.0)), 0.0)
    A = jnp.einsum("bthi,bshi,btshi->bhts", r, k, D)
    y = y + jnp.einsum("bhts,bshj->bthj", A, v)

    # bonus (diagonal) term
    y = y + jnp.einsum("bthi,hi,bthi,bthj->bthj", r, u, k, v)

    # chunk state update: S_out = diag(exp(cum_T)) S_in + sum_s exp(cum_T-cum_s) k_s (x) v_s
    total = cum[:, -1]  # (B,H,hd)
    k_dec = k * jnp.exp(total[:, None] - cum)
    S_out = jnp.exp(total)[..., None] * S_in + jnp.einsum(
        "bshi,bshj->bhij", k_dec, v
    )
    return y, S_out


def wkv_scan(r, k, v, lw, u, S0=None, chunk: int = CHUNK):
    """Full-sequence WKV: (B,T,H,hd) inputs -> (y, S_final).

    Also returns (C, D) of the whole segment — the affine state operator —
    so callers can compose states across devices with ``state_passing``.
    """
    B, T, H, hd = r.shape
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    n = T // c
    if S0 is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def to_chunks(x):
        return x.reshape(B, n, c, H, hd).swapaxes(0, 1)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))

    def body(S, inp):
        rr, kk, vv, ll = inp
        y, S_next = _wkv_chunk(rr, kk, vv, ll, u, S)
        return S_next, y

    S_fin, ys = jax.lax.scan(body, S0, (rc, kc, vc, lwc))
    y = ys.swapaxes(0, 1).reshape(B, T, H, hd)
    return y, S_fin


def wkv_segment_operator(k, v, lw, chunk: int = CHUNK):
    """(C, D) of a sequence segment: S_out = D * S_in + C (for state_passing)."""
    B, T, H, hd = k.shape
    r0 = jnp.zeros_like(k)
    _, C = wkv_scan(r0, k, v, lw, jnp.zeros((H, hd), k.dtype), None, chunk)
    D = jnp.exp(jnp.sum(lw, axis=1))[..., None]  # (B,H,hd,1) broadcast over j
    return C, D


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Previous-token features; ``prev`` is the carry for decode/segments."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix(cfg: ModelConfig, lp: Params, x: jax.Array,
             *, ctx: ParallelContext = LOCAL, shift_prev=None, S0=None,
             return_state: bool = False):
    B, T, d = x.shape
    H, hd = _heads(cfg)
    xs = _token_shift(x, shift_prev)
    mu = lp["mu"].astype(x.dtype)  # (5, d)
    xr, xk, xv, xg, xw = (x + mu[i] * (xs - x) for i in range(5))
    r = (xr @ lp["wr"].astype(x.dtype)).reshape(B, T, H, hd)
    k = (xk @ lp["wk"].astype(x.dtype)).reshape(B, T, H, hd)
    v = (xv @ lp["wv"].astype(x.dtype)).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ lp["wg"].astype(x.dtype))
    # data-dependent decay (lora)
    wl = jnp.tanh(xw @ lp["w_lora_a"].astype(x.dtype)) @ lp["w_lora_b"].astype(x.dtype)
    lw = -jnp.exp(
        jnp.clip(lp["w_base"].astype(jnp.float32) + wl.astype(jnp.float32), -8.0, 4.0)
    ).reshape(B, T, H, hd)  # log w < 0

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = lp["u"].astype(jnp.float32)
    chunk = cfg.scan_chunk or CHUNK

    if ctx.seq_parallel and ctx.mesh is not None and ctx.model_axis:
        # sequence parallel: local scan + cross-device state composition
        def seq_par(rl, kl, vl, ll):
            C, D = wkv_segment_operator(kl, vl, ll, chunk=chunk)
            S_in = state_passing(C, D * jnp.ones_like(C), ctx.model_axis,
                                 method=ctx.state_method)
            y, _ = wkv_scan(rl, kl, vl, ll, u, S_in, chunk=chunk)
            return y

        spec = P(ctx.data_axes, ctx.model_axis, None, None)
        y = compat.shard_map(seq_par, mesh=ctx.mesh,
                          in_specs=(spec,) * 4, out_specs=spec)(rf, kf, vf, lw)
        S_fin = None
    else:
        y, S_fin = wkv_scan(rf, kf, vf, lw, u, S0, chunk=chunk)

    y = y.reshape(B, T, d).astype(x.dtype) * g
    out = y @ lp["wo"].astype(x.dtype)
    if return_state:
        return out, x[:, -1:], S_fin
    return out


def channel_mix(cfg: ModelConfig, lp: Params, x: jax.Array, shift_prev=None,
                return_state: bool = False):
    xs = _token_shift(x, shift_prev)
    mu = lp["mu_c"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ lp["ck"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ lp["cr"].astype(x.dtype)) * (
        k @ lp["cv"].astype(x.dtype)
    )
    if return_state:
        return out, x[:, -1:]
    return out


def block(cfg: ModelConfig, lp: Params, x: jax.Array,
          *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = x + time_mix(cfg, lp, L.apply_norm(cfg, lp["ln1"], x), ctx=ctx)
    x = x + channel_mix(cfg, lp, L.apply_norm(cfg, lp["ln2"], x))
    return x


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def hidden_states(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = L.apply_norm(cfg, params["ln_in"], x)

    blk = functools.partial(block, cfg, ctx=ctx)
    if cfg.remat != "none":
        blk = jax.checkpoint(blk)

    def body(xc, lp):
        return blk(lp, xc), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(cfg, params["norm_f"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, batch["tokens"], ctx=ctx)
    return L.chunked_lm_loss(x, params["lm_head"], batch["labels"],
                             cfg.logits_chunk, mask=batch.get("mask"))


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
              *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, tokens, ctx=ctx)
    return x @ params["lm_head"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# decode (exact recurrence; O(1) state per layer)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    H, hd = _heads(cfg)
    d = cfg.d_model
    L_ = cfg.n_layers
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "tm_shift": jnp.zeros((L_, batch, 1, d), dt),
        "cm_shift": jnp.zeros((L_, batch, 1, d), dt),
        "wkv": jnp.zeros((L_, batch, H, hd, hd), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: dict,
                *, ctx: ParallelContext = LOCAL):
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))  # (B,1,d)
    x = L.apply_norm(cfg, params["ln_in"], x)

    def body(xc, per_layer):
        lp, tm_s, cm_s, S = per_layer
        h = L.apply_norm(cfg, lp["ln1"], xc)
        out, tm_new, S_new = time_mix(cfg, lp, h, shift_prev=tm_s, S0=S,
                                      return_state=True)
        xc = xc + out
        h = L.apply_norm(cfg, lp["ln2"], xc)
        out, cm_new = channel_mix(cfg, lp, h, shift_prev=cm_s, return_state=True)
        xc = xc + out
        return xc, (tm_new, cm_new, S_new)

    x, (tm, cm, wkv) = jax.lax.scan(
        body, x, (params["layers"], cache["tm_shift"], cache["cm_shift"],
                  cache["wkv"]),
    )
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x @ params["lm_head"].T.astype(x.dtype)
    return logits, {
        "tm_shift": tm.astype(cache["tm_shift"].dtype),
        "cm_shift": cm.astype(cache["cm_shift"].dtype),
        "wkv": wkv,
        "pos": cache["pos"] + 1,
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, cache: dict,
            *, ctx: ParallelContext = LOCAL):
    """Fill recurrent states from a prompt (chunked scan per layer)."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = L.apply_norm(cfg, params["ln_in"], x)

    def body(xc, lp):
        h = L.apply_norm(cfg, lp["ln1"], xc)
        out, tm_new, S_new = time_mix(cfg, lp, h, return_state=True)
        xc = xc + out
        h = L.apply_norm(cfg, lp["ln2"], xc)
        out, cm_new = channel_mix(cfg, lp, h, return_state=True)
        xc = xc + out
        return xc, (tm_new, cm_new, S_new)

    x, (tm, cm, wkv) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x[:, -1:] @ params["lm_head"].T.astype(x.dtype)
    return logits, {
        "tm_shift": tm.astype(cache["tm_shift"].dtype),
        "cm_shift": cm.astype(cache["cm_shift"].dtype),
        "wkv": wkv,
        "pos": jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32),
    }
