"""Mixture-of-Experts decoder (phi-3.5-moe, grok-1).

Expert weights live in **slot layout**: ``ep_slots`` slots, each holding one
expert's hidden shard of width ``d_ff * n_experts / ep_slots``.  With
``ep_slots == n_experts`` (phi) a slot is a whole expert; grok stores 8
experts as 16 slots (2-way hidden split) so the expert dimension exactly
tiles the 16-way model axis.

Two dispatch modes (ParallelContext.moe_mode):

* ``dense`` — capacity-based scatter/gather on the local device (smoke tests,
  single-device runs, decode).
* ``ep``    — expert parallelism: routing + scatter inside ``shard_map``,
  tokens exchanged with :func:`repro.core.partitioned.partitioned_all_to_all`
  so expert compute on chunk *k* overlaps the transfer of chunk *k+1* — the
  paper's partitioned pipeline with the expert FFN as the consumer.  Hidden-
  split slots (grok) reduce partial outputs with a subgroup ``psum``.

The router aux (load-balance) loss is accumulated through the layer scan.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.configs.base import ModelConfig
from repro.core.partitioned import message_all_to_all, partitioned_all_to_all
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.context import LOCAL, ParallelContext

Params = dict


def _slots(cfg: ModelConfig) -> int:
    return cfg.ep_slots or cfg.n_experts


def _spe(cfg: ModelConfig) -> int:
    s = _slots(cfg)
    assert s % cfg.n_experts == 0, (s, cfg.n_experts)
    return s // cfg.n_experts


def _f_shard(cfg: ModelConfig) -> int:
    assert cfg.d_ff % _spe(cfg) == 0
    return cfg.d_ff // _spe(cfg)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def moe_ffn_params(cfg: ModelConfig, key) -> Params:
    d, fs, s = cfg.d_model, _f_shard(cfg), _slots(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "router": L.dense_init(ks[0], d, cfg.n_experts, pd),
        "w_up": jax.vmap(lambda k: L.dense_init(k, d, fs, pd))(
            jax.random.split(ks[1], s)
        ),
        "w_down": jax.vmap(lambda k: L.dense_init(k, fs, d, pd))(
            jax.random.split(ks[2], s)
        ),
    }
    if cfg.act in ("silu", "geglu"):
        p["w_gate"] = jax.vmap(lambda k: L.dense_init(k, d, fs, pd))(
            jax.random.split(ks[3], s)
        )
    return p


def layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": L.norm_params(cfg),
        "attn": L.attention_params(cfg, k1),
        "norm_mlp": L.norm_params(cfg),
        "moe": moe_ffn_params(cfg, k2),
    }


def init(cfg: ModelConfig, key) -> Params:
    ke, kl, ko = jax.random.split(key, 3)
    keys = jax.random.split(kl, cfg.n_layers)
    p: Params = {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model,
                              jnp.dtype(cfg.param_dtype)),
        "layers": jax.vmap(lambda k: layer_params(cfg, k))(keys),
        "norm_f": L.norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.embed_init(ko, cfg.vocab_size, cfg.d_model,
                                    jnp.dtype(cfg.param_dtype))
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def _route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """x: (T, d) -> (weights (T,k), experts (T,k), aux loss)."""
    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e fraction_e * prob_e
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32).sum(1)  # (T,E)
    frac = onehot.mean(0)
    aux = cfg.n_experts * jnp.sum(frac * probs.mean(0))
    return w.astype(x.dtype), idx, aux


def _dispatch_indices(cfg: ModelConfig, idx: jax.Array, T: int, capacity: int):
    """Capacity-based rank of every (token, choice) within its expert."""
    tk = idx.reshape(-1)  # (T*k,)
    oh = jax.nn.one_hot(tk, cfg.n_experts, dtype=jnp.int32)  # (T*k, E)
    ranks = jnp.cumsum(oh, axis=0) - oh
    rank_e = jnp.take_along_axis(ranks, tk[:, None], axis=1)[:, 0]  # (T*k,)
    keep = rank_e < capacity
    return tk, rank_e, keep


def _expert_ffn(cfg: ModelConfig, p: Params, slot_x: jax.Array) -> jax.Array:
    """slot_x: (S_slots, C, d) -> per-slot FFN outputs (hidden shard)."""
    if cfg.act in ("silu", "geglu"):
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(jnp.einsum("scd,sdf->scf", slot_x, p["w_gate"].astype(slot_x.dtype)))
        h = h * jnp.einsum("scd,sdf->scf", slot_x, p["w_up"].astype(slot_x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("scd,sdf->scf", slot_x, p["w_up"].astype(slot_x.dtype)))
    return jnp.einsum("scf,sfd->scd", h, p["w_down"].astype(slot_x.dtype))


def _moe_dense(cfg: ModelConfig, p: Params, x2d: jax.Array):
    """Local capacity dispatch (T, d) -> (T, d), all slots resident."""
    Tn = x2d.shape[0]
    spe = _spe(cfg)
    capacity = max(1, int(Tn * cfg.capacity_factor * cfg.top_k / cfg.n_experts))
    w, idx, aux = _route(cfg, p["router"], x2d)
    tk, rank_e, keep = _dispatch_indices(cfg, idx, Tn, capacity)
    x_rep = jnp.repeat(x2d, cfg.top_k, axis=0)  # (T*k, d)
    x_rep = jnp.where(keep[:, None], x_rep, 0)
    buf = jnp.zeros((cfg.n_experts, capacity, x2d.shape[1]), x2d.dtype)
    buf = buf.at[tk, jnp.where(keep, rank_e, 0)].add(x_rep, mode="drop")
    # replicate expert buffer across its hidden-shard slots
    slot_buf = jnp.repeat(buf, spe, axis=0)  # (S, C, d)
    y_slots = _expert_ffn(cfg, p, slot_buf)  # (S, C, d) partial outputs
    y_exp = y_slots.reshape(cfg.n_experts, spe, capacity, -1).sum(1)  # (E, C, d)
    gathered = y_exp[tk, rank_e]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(Tn, cfg.top_k, -1)
         * w[..., None]).sum(axis=1)
    return y.astype(x2d.dtype), aux


def _moe_dropless(cfg: ModelConfig, p: Params, x2d: jax.Array):
    """Dropless all-slots MoE (decode path): every slot's FFN runs on every
    token; outputs are combined with top-k router weights.  E/k x the active
    FLOPs, but decode is memory-bound on the expert weights themselves, so
    the roofline is unchanged — and no token is ever dropped."""
    spe = _spe(cfg)
    w, idx, aux = _route(cfg, p["router"], x2d)
    slot_x = jnp.broadcast_to(x2d, (_slots(cfg),) + x2d.shape)  # (S, T, d)
    y_slots = _expert_ffn(cfg, p, slot_x)  # (S, T, d)
    y_exp = y_slots.reshape(cfg.n_experts, spe, *x2d.shape).sum(1)  # (E, T, d)
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=x2d.dtype)  # (T, k, E)
    w_e = jnp.einsum("tk,tke->te", w, onehot)  # (T, E)
    y = jnp.einsum("te,etd->td", w_e, y_exp)
    return y.astype(x2d.dtype), aux


def _moe_ep_local(cfg: ModelConfig, ctx: ParallelContext, p_local: Params,
                  x_local: jax.Array):
    """Inside shard_map: x_local (T_loc, d); expert slots sharded over the
    model axis (one slot per device).  Paper-technique core."""
    axis = ctx.model_axis
    M = _slots(cfg)
    spe = _spe(cfg)
    Tn = x_local.shape[0]
    capacity = max(1, int(Tn * cfg.capacity_factor * cfg.top_k / cfg.n_experts))
    w, idx, aux = _route(cfg, p_local["router"], x_local)
    tk, rank_e, keep = _dispatch_indices(cfg, idx, Tn, capacity)
    x_rep = jnp.repeat(x_local, cfg.top_k, axis=0)
    x_rep = jnp.where(keep[:, None], x_rep, 0)
    safe_rank = jnp.where(keep, rank_e, 0)
    # scatter into slot buffer; hidden-split experts receive duplicates
    buf = jnp.zeros((M, capacity, x_local.shape[1]), x_local.dtype)
    for j in range(spe):
        buf = buf.at[tk * spe + j, safe_rank].add(x_rep, mode="drop")

    def expert_consume(chunk):  # (M, c, d) arrived tokens -> early work
        if cfg.act in ("silu", "geglu"):
            act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
            h = act(chunk @ p_local["w_gate"][0].astype(chunk.dtype))
            h = h * (chunk @ p_local["w_up"][0].astype(chunk.dtype))
        else:
            h = jax.nn.gelu(chunk @ p_local["w_up"][0].astype(chunk.dtype))
        y = h @ p_local["w_down"][0].astype(chunk.dtype)
        return y

    # dispatch: partitioned all-to-all with the expert FFN as per-chunk
    # consumer (MPI_Parrived early work).  Chunking axis = capacity.
    # ctx.moe_comm='messages' routes the exchange through the transport
    # layer's Message tables instead of the native XLA collective, so the
    # wire packer (ctx.comm_packer) applies to the token buffers.
    if ctx.moe_comm == "messages":
        a2a = functools.partial(
            message_all_to_all,
            packer=ctx.comm_packer, coalesce=ctx.comm_coalesce,
        )
    else:
        a2a = partitioned_all_to_all
    y_slot = a2a(
        buf, axis, split_axis=0, concat_axis=0,
        n_parts=max(1, ctx.n_parts), chunk_axis=1, consume_fn=expert_consume,
    )  # (M, capacity, d): my expert's outputs for every source device
    if spe > 1:
        groups = [
            [e * spe + j for j in range(spe)] for e in range(cfg.n_experts)
        ]
        y_slot = jax.lax.psum(y_slot, axis, axis_index_groups=groups)
    # return: all-to-all back (chunked identically)
    y_back = a2a(
        y_slot, axis, split_axis=0, concat_axis=0,
        n_parts=max(1, ctx.n_parts), chunk_axis=1,
    )  # (M, capacity, d): [s] = my tokens' outputs from slot s
    gathered = y_back[tk * spe, safe_rank]  # j=0 copy carries the psum result
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(Tn, cfg.top_k, -1) * w[..., None]).sum(axis=1)
    return y.astype(x_local.dtype), aux


def apply_moe_ffn(
    cfg: ModelConfig, p: Params, x: jax.Array, ctx: ParallelContext
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux). Dispatch mode per context."""
    b, s, d = x.shape

    def run(x_bsd: jax.Array) -> tuple[jax.Array, jax.Array]:
        if ctx.moe_mode == "ep" and ctx.mesh is not None and ctx.model_axis:
            def inner(xl, pl):
                tl = xl.reshape(-1, xl.shape[-1])
                y, aux = _moe_ep_local(cfg, ctx, pl, tl)
                return y.reshape(xl.shape), aux[None, None]

            specs_p = jax.tree.map(lambda _: P(None), p)
            for name in ("w_gate", "w_up", "w_down"):
                if name in p:
                    specs_p[name] = P(ctx.model_axis, None, None)
            # tokens are ALWAYS seq-sharded over the EP axis inside the MoE:
            # routing is per-token, and replicating tokens across model ranks
            # would make every rank dispatch identical buffers — each expert
            # would compute its work |EP| times over (caught by the roofline
            # useful-flops ratio; see EXPERIMENTS.md §Perf iteration 0).
            x_spec = P(ctx.data_axes, ctx.model_axis, None)
            y, aux = compat.shard_map(
                inner,
                mesh=ctx.mesh,
                in_specs=(x_spec, specs_p),
                out_specs=(x_spec, P(ctx.data_axes, ctx.model_axis))
            )(x_bsd, p)
            return y, jnp.mean(aux)
        y, aux = _moe_dense(cfg, p, x_bsd.reshape(-1, d))
        return y.reshape(x_bsd.shape), aux

    chunk = cfg.moe_seq_chunk
    if chunk and s > chunk and s % chunk == 0:
        n = s // chunk
        xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)  # (n, B, c, d)

        def body(aux_sum, xc):
            y, aux = run(xc)
            return aux_sum + aux, y

        aux_sum, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
        return ys.swapaxes(0, 1).reshape(b, s, d), aux_sum / n
    return run(x)


# ---------------------------------------------------------------------------
# model assembly (mirrors transformer.py, MoE FFN + aux-loss carry)
# ---------------------------------------------------------------------------


def hidden_states(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  *, ctx: ParallelContext = LOCAL):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def block(lp, xc):
        h = L.apply_norm(cfg, lp["norm_attn"], xc)
        xc = xc + L.self_attention(cfg, lp["attn"], h, positions, ctx=ctx)
        h = L.apply_norm(cfg, lp["norm_mlp"], xc)
        y, aux = apply_moe_ffn(cfg, lp["moe"], h, ctx)
        return xc + y, aux

    block = T._remat(cfg, block)

    def body(carry, lp):
        xc, aux_sum = carry
        xc, aux = block(lp, xc)
        return (xc, aux_sum + aux), None

    (x, aux_sum), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    return L.apply_norm(cfg, params["norm_f"], x), aux_sum / cfg.n_layers


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x, aux = hidden_states(cfg, params, batch["tokens"], ctx=ctx)
    ce = L.chunked_lm_loss(
        x, T.output_embedding(cfg, params), batch["labels"], cfg.logits_chunk,
        mask=batch.get("mask"),
    )
    return ce + cfg.router_aux_coef * aux


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
              *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x, _ = hidden_states(cfg, params, tokens, ctx=ctx)
    return x @ T.output_embedding(cfg, params).T.astype(x.dtype)


init_cache = T.init_cache


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: dict,
                *, ctx: ParallelContext = LOCAL):
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]

    def body(xc, per_layer):
        lp, ck, cv = per_layer
        h = L.apply_norm(cfg, lp["norm_attn"], xc)
        att, ck, cv = L.decode_attention(cfg, lp["attn"], h, ck, cv, pos)
        xc = xc + att
        h = L.apply_norm(cfg, lp["norm_mlp"], xc)
        y, _ = _moe_dropless(cfg, lp["moe"], h.reshape(-1, h.shape[-1]))
        xc = xc + y.reshape(h.shape)
        return xc, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x @ T.output_embedding(cfg, params).T.astype(x.dtype)
    return logits, {"k": nk, "v": nv, "pos": pos + 1}


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, cache: dict,
            *, ctx: ParallelContext = LOCAL):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xc, lp):
        h = L.apply_norm(cfg, lp["norm_attn"], xc)
        q, k, v = L._project_qkv(cfg, lp["attn"], h)
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
        att = L.prefill_attention(cfg, q, k, v, ctx=ctx)
        att = att.reshape(b, s, -1) @ lp["attn"]["wo"].astype(xc.dtype)
        xc = xc + att
        h = L.apply_norm(cfg, lp["norm_mlp"], xc)
        y, _ = apply_moe_ffn(cfg, lp["moe"], h, ctx)
        xc = xc + y
        return xc, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x[:, -1:] @ T.output_embedding(cfg, params).T.astype(x.dtype)
    nk = jax.lax.dynamic_update_slice(cache["k"], ks.astype(cache["k"].dtype),
                                      (0, 0, 0, 0, 0))
    nv = jax.lax.dynamic_update_slice(cache["v"], vs.astype(cache["v"].dtype),
                                      (0, 0, 0, 0, 0))
    return logits, {"k": nk, "v": nv,
                    "pos": jnp.full((b,), s, jnp.int32)}
