"""Zamba2 hybrid: Mamba2 backbone + one shared attention block every N layers.

Layer layout for n_layers=38, attn_every=6:
    [6 x (6 mamba layers + shared attn block)] + [2 tail mamba layers]
The shared block has ONE set of weights applied at every interval (zamba2's
parameter-sharing trick); its input is ``concat(hidden, embeddings)`` through
a down-projection.  Scan structure: outer scan over the 6 groups (params
stacked per group) so compile time and cost analysis stay per-group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T
from repro.parallel.context import LOCAL, ParallelContext

Params = dict


def group_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, n_tail)."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    return n_groups, g, cfg.n_layers - n_groups * g


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def shared_block_params(cfg: ModelConfig, key) -> Params:
    k0, k1, k2 = jax.random.split(key, 3)
    return {
        "pre_proj": L.dense_init(k0, 2 * cfg.d_model, cfg.d_model,
                                 jnp.dtype(cfg.param_dtype)),
        "norm_attn": L.norm_params(cfg),
        "attn": L.attention_params(cfg, k1),
        "norm_mlp": L.norm_params(cfg),
        "mlp": L.mlp_params(cfg, k2),
    }


def init(cfg: ModelConfig, key) -> Params:
    n_groups, gsize, n_tail = group_layout(cfg)
    ke, kg, kt, ks, ko = jax.random.split(key, 5)
    gkeys = jax.random.split(kg, (n_groups, gsize))
    groups = jax.vmap(
        jax.vmap(lambda k: ssm.mamba_params(cfg, k))
    )(gkeys)
    p: Params = {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model,
                              jnp.dtype(cfg.param_dtype)),
        "groups": groups,
        "shared": shared_block_params(cfg, ks),
        "norm_f": L.norm_params(cfg),
        "lm_head": L.embed_init(ko, cfg.vocab_size, cfg.d_model,
                                jnp.dtype(cfg.param_dtype)),
    }
    if n_tail:
        tkeys = jax.random.split(kt, n_tail)
        p["tail"] = jax.vmap(lambda k: ssm.mamba_params(cfg, k))(tkeys)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def shared_attn_block(cfg: ModelConfig, sp: Params, x: jax.Array,
                      emb: jax.Array, positions: jax.Array,
                      ctx: ParallelContext) -> jax.Array:
    h = jnp.concatenate([x, emb], axis=-1) @ sp["pre_proj"].astype(x.dtype)
    h2 = L.apply_norm(cfg, sp["norm_attn"], h)
    h = h + L.self_attention(cfg, sp["attn"], h2, positions, ctx=ctx)
    h2 = L.apply_norm(cfg, sp["norm_mlp"], h)
    h = h + L.apply_mlp(cfg, sp["mlp"], h2)
    return x + h


def hidden_states(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  *, ctx: ParallelContext = LOCAL) -> jax.Array:
    n_groups, gsize, n_tail = group_layout(cfg)
    emb = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = emb
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mb = functools.partial(ssm.mamba_block, cfg, ctx=ctx)
    if cfg.remat != "none":
        mb = jax.checkpoint(mb)

    def group_body(xc, gp):
        def layer_body(xl, lp):
            return mb(lp, xl), None

        xc, _ = jax.lax.scan(layer_body, xc, gp)
        xc = shared_attn_block(cfg, params["shared"], xc, emb, positions, ctx)
        return xc, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if n_tail:
        def tail_body(xl, lp):
            return mb(lp, xl), None

        x, _ = jax.lax.scan(tail_body, x, params["tail"])
    return L.apply_norm(cfg, params["norm_f"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, batch["tokens"], ctx=ctx)
    return L.chunked_lm_loss(x, params["lm_head"], batch["labels"],
                             cfg.logits_chunk, mask=batch.get("mask"))


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
              *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, tokens, ctx=ctx)
    return x @ params["lm_head"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    n_groups, gsize, n_tail = group_layout(cfg)
    di, nh, hd_s, ns = ssm.dims(cfg)
    ch = ssm.conv_channels(cfg)
    hd = cfg.resolved_head_dim
    dt = dtype or jnp.dtype(cfg.dtype)
    cache = {
        "g_conv": jnp.zeros((n_groups, gsize, batch, cfg.conv_kernel - 1, ch), dt),
        "g_ssd": jnp.zeros((n_groups, gsize, batch, nh, hd_s, ns), jnp.float32),
        "shared_k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, hd), dt),
        "shared_v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if n_tail:
        cache["t_conv"] = jnp.zeros((n_tail, batch, cfg.conv_kernel - 1, ch), dt)
        cache["t_ssd"] = jnp.zeros((n_tail, batch, nh, hd_s, ns), jnp.float32)
    return cache


def _shared_decode(cfg, sp, x, emb, ck, cv, pos):
    h = jnp.concatenate([x, emb], axis=-1) @ sp["pre_proj"].astype(x.dtype)
    h2 = L.apply_norm(cfg, sp["norm_attn"], h)
    att, ck, cv = L.decode_attention(cfg, sp["attn"], h2, ck, cv, pos)
    h = h + att
    h2 = L.apply_norm(cfg, sp["norm_mlp"], h)
    h = h + L.apply_mlp(cfg, sp["mlp"], h2)
    return x + h, ck, cv


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: dict,
                *, ctx: ParallelContext = LOCAL):
    n_groups, gsize, n_tail = group_layout(cfg)
    emb = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    x = emb
    pos = cache["pos"]

    def group_body(xc, per_group):
        gp, conv_s, ssd_s, ck, cv = per_group

        def layer_body(xl, per_layer):
            lp, cs, hs = per_layer
            out, cs2, hs2 = ssm.mamba_block(cfg, lp, xl, conv_state=cs,
                                            ssd_state=hs, return_state=True)
            return out, (cs2, hs2)

        xc, (conv2, ssd2) = jax.lax.scan(layer_body, xc, (gp, conv_s, ssd_s))
        xc, ck, cv = _shared_decode(cfg, params["shared"], xc, emb, ck, cv, pos)
        return xc, (conv2, ssd2, ck, cv)

    x, (gc, gs, sk, sv) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["g_conv"], cache["g_ssd"],
         cache["shared_k"], cache["shared_v"]),
    )
    new = {"g_conv": gc, "g_ssd": gs, "shared_k": sk, "shared_v": sv,
           "pos": pos + 1}
    if n_tail:
        def tail_body(xl, per_layer):
            lp, cs, hs = per_layer
            out, cs2, hs2 = ssm.mamba_block(cfg, lp, xl, conv_state=cs,
                                            ssd_state=hs, return_state=True)
            return out, (cs2, hs2)

        x, (tc, ts) = jax.lax.scan(
            tail_body, x, (params["tail"], cache["t_conv"], cache["t_ssd"]))
        new["t_conv"], new["t_ssd"] = tc, ts
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x @ params["lm_head"].T.astype(x.dtype)
    return logits, new


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, cache: dict,
            *, ctx: ParallelContext = LOCAL):
    n_groups, gsize, n_tail = group_layout(cfg)
    emb = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = emb
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def group_body(xc, gp):
        def layer_body(xl, lp):
            out, cs, hs = ssm.mamba_block(cfg, lp, xl, return_state=True)
            return out, (cs, hs)

        xc, (conv2, ssd2) = jax.lax.scan(layer_body, xc, gp)
        # shared attn with cache capture
        sp = params["shared"]
        h = jnp.concatenate([xc, emb], axis=-1) @ sp["pre_proj"].astype(xc.dtype)
        h2 = L.apply_norm(cfg, sp["norm_attn"], h)
        q, k, v = L._project_qkv(cfg, sp["attn"], h2)
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
        att = L.prefill_attention(cfg, q, k, v, ctx=ctx, causal=True)
        att = att.reshape(b, s, -1) @ sp["attn"]["wo"].astype(xc.dtype)
        h = h + att
        h2 = L.apply_norm(cfg, sp["norm_mlp"], h)
        h = h + L.apply_mlp(cfg, sp["mlp"], h2)
        xc = xc + h
        return xc, (conv2, ssd2, k, v)

    x, (gc, gs, ks, vs) = jax.lax.scan(group_body, x, params["groups"])
    new = dict(cache)
    new["g_conv"], new["g_ssd"] = gc, gs
    new["shared_k"] = jax.lax.dynamic_update_slice(
        cache["shared_k"], ks.astype(cache["shared_k"].dtype), (0, 0, 0, 0, 0))
    new["shared_v"] = jax.lax.dynamic_update_slice(
        cache["shared_v"], vs.astype(cache["shared_v"].dtype), (0, 0, 0, 0, 0))
    if n_tail:
        def tail_body(xl, lp):
            out, cs, hs = ssm.mamba_block(cfg, lp, xl, return_state=True)
            return out, (cs, hs)

        x, (tc, ts) = jax.lax.scan(tail_body, x, params["tail"])
        new["t_conv"], new["t_ssd"] = tc, ts
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x[:, -1:] @ params["lm_head"].T.astype(x.dtype)
    new["pos"] = jnp.full((b,), s, jnp.int32)
    return logits, new
