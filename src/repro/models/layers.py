"""Shared neural layers: norms, rotary embeddings, GQA attention (+cache),
gated MLPs, embeddings.  Pure functions over nested-dict params.

Attention comes in three execution modes, selected by the ParallelContext:
  * local full attention (jnp oracle / Pallas flash kernel),
  * ring attention over the model axis (sequence-parallel prefill — the
    paper's partitioned halo pipeline with attention as the consumer),
  * single-token decode against a KV cache.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.configs.base import ModelConfig
from repro.core.ring import ring_attention
from repro.kernels.flash_attention import attention as flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.parallel.context import LOCAL, ParallelContext

Params = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_params(cfg: ModelConfig, dim: int | None = None) -> Params:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), _pdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _pdtype(cfg))
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (partial-rotary supported: stablelm rope_pct)
# ---------------------------------------------------------------------------


def rope_frequencies(cfg: ModelConfig, head_dim: int) -> jax.Array:
    rot = int(head_dim * cfg.rope_pct) // 2 * 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) absolute token positions."""
    d = x.shape[-1]
    rot = int(d * cfg.rope_pct) // 2 * 2
    if rot == 0:
        return x
    inv = rope_frequencies(cfg, d)  # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_params(cfg: ModelConfig, key) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    pd = _pdtype(cfg)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, pd),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, pd),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, pd),
        "wo": dense_init(ko, cfg.n_heads * hd, d, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), pd)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), pd)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), pd)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


_BLOCKWISE_THRESHOLD = 8192  # above this, never materialize S^2 scores


def _pick_block(n: int, target: int) -> int:
    """Largest block <= target dividing n (n itself for small primes, e.g.
    the 1601 vision tokens of llama-3.2)."""
    if n <= target:
        return n
    for d in range(target, 0, -1):
        if n % d == 0:
            if d >= 128:
                return d
            break
    return n if n <= 8192 else math.gcd(n, target) or n


def blockwise_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool,
    q_block: int = 1024,
    kv_block: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    """Flash-style attention in pure jnp: double scan over (q, kv) blocks with
    online-softmax accumulation.  O(q_block x kv_block) score memory — this is
    what lets the 32k-sequence prefill cells compile within HBM on any
    backend (the Pallas kernel is the TPU-runtime fast path; this is the
    portable lowering)."""
    from repro.core.ring import _attend_block

    b, sq, h, d = q.shape
    skv = k.shape[1]
    qb = _pick_block(sq, q_block)
    kb = _pick_block(skv, kv_block)
    scale = scale if scale is not None else d ** -0.5
    nq, nk = sq // qb, skv // kb

    kc = k.reshape(b, nk, kb, k.shape[2], d).swapaxes(0, 1)
    vc = v.reshape(b, nk, kb, v.shape[2], d).swapaxes(0, 1)
    qc = q.reshape(b, nq, qb, h, d).swapaxes(0, 1)

    def q_body(_, qi_blk):
        qi, qblk = qi_blk
        m = jnp.full((b, h, qb), -1e30, jnp.float32)
        l = jnp.zeros((b, h, qb), jnp.float32)
        acc = jnp.zeros((b, qb, h, d), jnp.float32)

        def kv_body(carry, ki_blk):
            m_, l_, acc_ = carry
            ki, kblk, vblk = ki_blk
            m_, l_, acc_ = _attend_block(
                qblk, kblk, vblk, m_, l_, acc_, qi * qb, ki * kb,
                causal=causal, scale=scale)
            return (m_, l_, acc_), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m, l, acc), (jnp.arange(nk), kc, vc))
        l = jnp.maximum(l, 1e-30)
        return None, (acc / l.transpose(0, 2, 1)[..., None]).astype(q.dtype)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    return out.swapaxes(0, 1).reshape(b, sq, h, d)


def _local_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool, ctx: ParallelContext
) -> jax.Array:
    """(B, S, H, D)-layout attention on local (unsharded-seq) blocks."""
    if ctx.use_flash:
        return flash_attention_op(q, k, v, causal=causal)
    if max(q.shape[1], k.shape[1]) > _BLOCKWISE_THRESHOLD:
        return blockwise_attention(q, k, v, causal=causal)
    return jnp.swapaxes(
        attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=causal,
        ), 1, 2,
    )


def prefill_attention(
    cfg: ModelConfig,
    q: jax.Array,  # (B, S, H, D) post-rope
    k: jax.Array,
    v: jax.Array,
    *,
    ctx: ParallelContext = LOCAL,
    causal: bool | None = None,
) -> jax.Array:
    """Attention for prefill bodies: ring attention over the model axis when
    sequence parallelism is on (explicit seq sharding + partitioned KV
    exchange — the paper's pipeline), else local blockwise attention.

    The explicit ring keeps heads unsharded inside the shard_map, which also
    sidesteps GSPMD's pathological resharding when n_heads does not divide
    the model axis (qwen: 40 heads on 16 shards — see EXPERIMENTS.md §Perf).
    """
    causal = cfg.causal if causal is None else causal
    if ctx.seq_parallel and ctx.mesh is not None and ctx.model_axis:
        def ring(qb, kb, vb):
            return ring_attention(
                qb, kb, vb, ctx.model_axis, causal=causal, n_parts=ctx.n_parts,
                packer=ctx.comm_packer, coalesce=ctx.comm_coalesce)

        spec = P(ctx.data_axes, ctx.model_axis, None, None)
        return compat.shard_map(
            ring, mesh=ctx.mesh, in_specs=(spec, spec, spec), out_specs=spec
        )(q, k, v)
    return _local_attention(q, k, v, causal=causal, ctx=ctx)


def self_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S)
    *,
    ctx: ParallelContext = LOCAL,
    causal: bool | None = None,
) -> jax.Array:
    """Full-sequence self attention (training / prefill)."""
    causal = cfg.causal if causal is None else causal
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    if ctx.seq_parallel and ctx.mesh is not None and ctx.model_axis:
        # sequence-parallel ring attention: KV shards circulate the model axis
        # with partitioned (n_parts) exchange — the paper's pipeline.
        def ring(qb, kb, vb):
            return ring_attention(
                qb, kb, vb, ctx.model_axis, causal=causal, n_parts=ctx.n_parts,
                packer=ctx.comm_packer, coalesce=ctx.comm_coalesce,
            )

        spec = P(ctx.data_axes, ctx.model_axis, None, None)
        out = compat.shard_map(
            ring, mesh=ctx.mesh, in_specs=(spec, spec, spec), out_specs=spec
        )(q, k, v)
    else:
        out = _local_attention(q, k, v, causal=causal, ctx=ctx)
    b, s, _, _ = out.shape
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def decode_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, 1, d)
    cache_k: jax.Array,  # (B, Smax, Hkv, hd)
    cache_v: jax.Array,
    pos: jax.Array,  # (B,) per-sequence positions (continuous batching)
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode against a KV cache; returns (out, new_k, new_v).

    ``pos`` is a per-row vector so slots in a shared batched cache may sit at
    different depths (continuous batching)."""
    b = x.shape[0]
    pos = jnp.broadcast_to(pos, (b,))
    positions = pos[:, None]
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    rows = jnp.arange(b)
    cache_k = cache_k.at[rows, pos].set(k[:, 0].astype(cache_k.dtype),
                                        mode="drop")
    cache_v = cache_v.at[rows, pos].set(v[:, 0].astype(cache_v.dtype),
                                        mode="drop")
    smax = cache_k.shape[1]
    hd = cfg.resolved_head_dim
    group = cfg.n_heads // cfg.n_kv_heads
    kf = jnp.repeat(cache_k, group, axis=2)
    vf = jnp.repeat(cache_v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kf.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(smax)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


def cross_attention_params(cfg: ModelConfig, key) -> Params:
    p = attention_params(cfg, key)
    p["gate_attn"] = jnp.zeros((1,), _pdtype(cfg))
    p["gate_ffn"] = jnp.zeros((1,), _pdtype(cfg))
    p["q_norm"] = jnp.ones((cfg.resolved_head_dim,), _pdtype(cfg))
    p["k_norm"] = jnp.ones((cfg.resolved_head_dim,), _pdtype(cfg))
    return p


def cross_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, S, d) text stream
    kv_feats: jax.Array,  # (B, T_img, d) projected vision tokens
) -> jax.Array:
    """Gated cross attention (llama-3.2-vision image layers)."""
    b, s, _ = x.shape
    t = kv_feats.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    k = (kv_feats @ p["wk"].astype(x.dtype)).reshape(b, t, cfg.n_kv_heads, hd)
    v = (kv_feats @ p["wv"].astype(x.dtype)).reshape(b, t, cfg.n_kv_heads, hd)
    # per-head rmsnorm on q/k (hf layout)
    q = q * jax.lax.rsqrt(jnp.mean(q.astype(jnp.float32) ** 2, -1,
                                   keepdims=True) + 1e-6).astype(q.dtype)
    q = q * p["q_norm"].astype(q.dtype)
    k = k * jax.lax.rsqrt(jnp.mean(k.astype(jnp.float32) ** 2, -1,
                                   keepdims=True) + 1e-6).astype(k.dtype)
    k = k * p["k_norm"].astype(k.dtype)
    out = _local_attention(q, k, v, causal=False, ctx=LOCAL)
    out = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    return jnp.tanh(p["gate_attn"].astype(x.dtype)) * out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_params(cfg: ModelConfig, key, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = _pdtype(cfg)
    if cfg.act in ("silu", "geglu"):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, d, f, pd),
            "w_up": dense_init(k2, d, f, pd),
            "w_down": dense_init(k3, f, d, pd),
        }
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, d, f, pd), "w_down": dense_init(k2, f, d, pd)}


def apply_mlp_ring(cfg: ModelConfig, p: Params, x: jax.Array,
                   ctx: ParallelContext) -> jax.Array:
    """Sequence-sharded Megatron-SP MLP on the partitioned ring primitives:
    ring-AG(x) consumed by gate+up matmuls in flight, ring matmul-RS back to
    the sequence shards.  Wire = AG + RS = half the column/row-TP all-reduce,
    and every hop overlaps a chunk matmul (MPI_Parrived early work)."""
    from repro.core.partitioned import (
        ring_all_gather_matmul, ring_matmul_reduce_scatter,
    )

    b, s_len, d = x.shape
    axis = ctx.model_axis

    def inner(xl, wg, wu, wd):
        bl, sl, _ = xl.shape
        x2 = xl.reshape(bl * sl, d)
        if cfg.act in ("silu", "geglu"):
            act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
            hg, hu = ring_all_gather_matmul(
                x2, [wg.astype(xl.dtype), wu.astype(xl.dtype)], axis)
            h = act(hg) * hu
        else:
            h = jax.nn.gelu(ring_all_gather_matmul(
                x2, wu.astype(xl.dtype), axis))
        y = ring_matmul_reduce_scatter(h, wd.astype(xl.dtype), axis)
        return y.reshape(bl, sl, d)

    k = ctx.model_size
    # rows must be seq-major for the gather/scatter blocks to be seq shards
    specs_x = P(ctx.data_axes, ctx.model_axis, None)
    wg = p.get("w_gate", p["w_up"])
    out = compat.shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(specs_x, P(None, ctx.model_axis), P(None, ctx.model_axis),
                  P(ctx.model_axis, None)),
        out_specs=specs_x
    )(x, wg, p["w_up"], p["w_down"])
    return out


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.act in ("silu", "geglu"):
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(x @ p["w_gate"].astype(x.dtype)) * (
            x @ p["w_up"].astype(x.dtype)
        )
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_lm_loss(
    x: jax.Array,  # (B, S, d) final hidden states
    emb: jax.Array,  # (V, d) output embedding (tied or head)
    labels: jax.Array,  # (B, S)
    chunk: int,
    mask: jax.Array | None = None,
) -> jax.Array:
    """CE loss with the (B, S, V) logits computed chunk-by-chunk over S —
    avoids materializing huge-vocab logit tensors."""
    b, s, d = x.shape
    if chunk <= 0 or s <= chunk or s % chunk != 0:
        logits = x @ emb.T.astype(x.dtype)
        return cross_entropy(logits, labels, mask)
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)  # (n, B, c, d)
    ls = labels.reshape(b, n, chunk).swapaxes(0, 1)
    ms = (mask.reshape(b, n, chunk).swapaxes(0, 1)
          if mask is not None else jnp.ones_like(ls, jnp.float32))

    def body(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        logits = xc @ emb.T.astype(xc.dtype)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, lc[..., None], axis=-1)[..., 0]
        return (tot + jnp.sum(nll * mc), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
