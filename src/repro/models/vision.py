"""Llama-3.2-Vision text decoder with gated cross-attention image layers.

Layout: 8 groups of (4 self-attn layers + 1 cross-attn layer) = 40 layers.
The vision tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, vision_tokens, d_vision), projected once to
d_model.  Cross layers use zero-init tanh gates (hf semantics) so an
untrained model reduces to the pure text decoder.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.context import LOCAL, ParallelContext

Params = dict


def group_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(n_groups, self_per_group)."""
    n_cross = cfg.n_cross_layers
    assert cfg.n_layers % n_cross == 0, (cfg.n_layers, n_cross)
    return n_cross, cfg.n_layers // n_cross - 1


def cross_layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": L.norm_params(cfg),
        "xattn": L.cross_attention_params(cfg, k1),
        "norm_mlp": L.norm_params(cfg),
        "mlp": L.mlp_params(cfg, k2),
    }


def init(cfg: ModelConfig, key) -> Params:
    n_groups, n_self = group_layout(cfg)
    ke, ks, kc, kv, ko = jax.random.split(key, 5)
    skeys = jax.random.split(ks, (n_groups, n_self))
    ckeys = jax.random.split(kc, n_groups)
    return {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model,
                              jnp.dtype(cfg.param_dtype)),
        "vision_proj": L.dense_init(kv, cfg.d_vision, cfg.d_model,
                                    jnp.dtype(cfg.param_dtype)),
        "self_groups": jax.vmap(jax.vmap(
            lambda k: T.layer_params(cfg, k)))(skeys),
        "cross": jax.vmap(lambda k: cross_layer_params(cfg, k))(ckeys),
        "norm_f": L.norm_params(cfg),
        "lm_head": L.embed_init(ko, cfg.vocab_size, cfg.d_model,
                                jnp.dtype(cfg.param_dtype)),
    }


def _cross_block(cfg: ModelConfig, cp: Params, x: jax.Array,
                 vis: jax.Array) -> jax.Array:
    h = L.apply_norm(cfg, cp["norm_attn"], x)
    x = x + L.cross_attention(cfg, cp["xattn"], h, vis)
    h = L.apply_norm(cfg, cp["norm_mlp"], x)
    x = x + jnp.tanh(cp["xattn"]["gate_ffn"].astype(x.dtype)) * L.apply_mlp(
        cfg, cp["mlp"], h)
    return x


def hidden_states(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  vision_emb: jax.Array, *, ctx: ParallelContext = LOCAL):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    vis = (vision_emb.astype(x.dtype)
           @ params["vision_proj"].astype(x.dtype))  # (B, Tv, d)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    self_block = T._remat(cfg, functools.partial(T.decoder_block, cfg, ctx=ctx))

    def group_body(xc, gp):
        sp, cp = gp

        def self_body(xl, lp):
            return self_block(lp, xl, positions), None

        xc, _ = jax.lax.scan(self_body, xc, sp)
        xc = _cross_block(cfg, cp, xc, vis)
        return xc, None

    x, _ = jax.lax.scan(group_body, x, (params["self_groups"], params["cross"]))
    return L.apply_norm(cfg, params["norm_f"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, batch["tokens"], batch["vision_emb"], ctx=ctx)
    return L.chunked_lm_loss(x, params["lm_head"], batch["labels"],
                             cfg.logits_chunk, mask=batch.get("mask"))


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
              vision_emb: jax.Array, *, ctx: ParallelContext = LOCAL):
    x = hidden_states(cfg, params, tokens, vision_emb, ctx=ctx)
    return x @ params["lm_head"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    n_groups, n_self = group_layout(cfg)
    hd = cfg.resolved_head_dim
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((n_groups, n_self, batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((n_groups, n_self, batch, max_len, cfg.n_kv_heads, hd), dt),
        # cross-attn KV over vision tokens, computed once at prefill
        "xk": jnp.zeros((n_groups, batch, cfg.vision_tokens, cfg.n_kv_heads, hd), dt),
        "xv": jnp.zeros((n_groups, batch, cfg.vision_tokens, cfg.n_kv_heads, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _cross_decode(cfg, cp, x, xk, xv):
    """Cross attention against cached vision KV (decode path)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h = L.apply_norm(cfg, cp["norm_attn"], x)
    q = (h @ cp["xattn"]["wq"].astype(x.dtype)).reshape(b, 1, cfg.n_heads, hd)
    q = q * jax.lax.rsqrt(jnp.mean(q.astype(jnp.float32) ** 2, -1,
                                   keepdims=True) + 1e-6).astype(q.dtype)
    q = q * cp["xattn"]["q_norm"].astype(q.dtype)
    from repro.kernels.flash_attention.ref import attention_ref

    out = jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(xk, 1, 2), jnp.swapaxes(xv, 1, 2),
        causal=False), 1, 2)
    out = out.reshape(b, 1, -1) @ cp["xattn"]["wo"].astype(x.dtype)
    x = x + jnp.tanh(cp["xattn"]["gate_attn"].astype(x.dtype)) * out
    h = L.apply_norm(cfg, cp["norm_mlp"], x)
    x = x + jnp.tanh(cp["xattn"]["gate_ffn"].astype(x.dtype)) * L.apply_mlp(
        cfg, cp["mlp"], h)
    return x


def decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: dict,
                *, ctx: ParallelContext = LOCAL):
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]

    def group_body(xc, per_group):
        sp, cp, ck, cv, xk, xv = per_group

        def self_body(xl, per_layer):
            lp, k1, v1 = per_layer
            h = L.apply_norm(cfg, lp["norm_attn"], xl)
            att, k1, v1 = L.decode_attention(cfg, lp["attn"], h, k1, v1, pos)
            xl = xl + att
            h = L.apply_norm(cfg, lp["norm_mlp"], xl)
            xl = xl + L.apply_mlp(cfg, lp["mlp"], h)
            return xl, (k1, v1)

        xc, (k2, v2) = jax.lax.scan(self_body, xc, (sp, ck, cv))
        xc = _cross_decode(cfg, cp, xc, xk, xv)
        return xc, (k2, v2)

    x, (nk, nv) = jax.lax.scan(
        group_body, x,
        (params["self_groups"], params["cross"], cache["k"], cache["v"],
         cache["xk"], cache["xv"]),
    )
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x @ params["lm_head"].T.astype(x.dtype)
    return logits, {**cache, "k": nk, "v": nv, "pos": pos + 1}


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            vision_emb: jax.Array, cache: dict,
            *, ctx: ParallelContext = LOCAL):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    vis = vision_emb.astype(x.dtype) @ params["vision_proj"].astype(x.dtype)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    hd = cfg.resolved_head_dim

    def group_body(xc, gp):
        sp, cp = gp

        def self_body(xl, lp):
            h = L.apply_norm(cfg, lp["norm_attn"], xl)
            q, k, v = L._project_qkv(cfg, lp["attn"], h)
            q = L.apply_rope(cfg, q, positions)
            k = L.apply_rope(cfg, k, positions)
            att = L.prefill_attention(cfg, q, k, v, ctx=ctx, causal=True)
            att = att.reshape(b, s, -1) @ lp["attn"]["wo"].astype(xl.dtype)
            xl = xl + att
            h = L.apply_norm(cfg, lp["norm_mlp"], xl)
            xl = xl + L.apply_mlp(cfg, lp["mlp"], h)
            return xl, (k, v)

        xc, (ks, vs) = jax.lax.scan(self_body, xc, sp)
        # cross block + capture vision KV
        tv = vis.shape[1]
        xk = (vis @ cp["xattn"]["wk"].astype(xc.dtype)).reshape(
            b, tv, cfg.n_kv_heads, hd)
        xk = xk * jax.lax.rsqrt(jnp.mean(xk.astype(jnp.float32) ** 2, -1,
                                         keepdims=True) + 1e-6).astype(xk.dtype)
        xk = xk * cp["xattn"]["k_norm"].astype(xk.dtype)
        xv = (vis @ cp["xattn"]["wv"].astype(xc.dtype)).reshape(
            b, tv, cfg.n_kv_heads, hd)
        xc = _cross_block(cfg, cp, xc, vis)
        return xc, (ks, vs, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(
        group_body, x, (params["self_groups"], params["cross"]))
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x[:, -1:] @ params["lm_head"].T.astype(x.dtype)
    new_k = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0,) * 6)
    new_v = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0,) * 6)
    return logits, {
        "k": new_k, "v": new_v,
        "xk": xks.astype(cache["xk"].dtype), "xv": xvs.astype(cache["xv"].dtype),
        "pos": jnp.full((b,), s, jnp.int32),
    }
