"""Dense decoder-only transformer (llama3 / qwen2.5 / granite / stablelm).

Layers are stacked along a leading axis and executed with ``lax.scan`` (one
compiled body regardless of depth — essential for the 40-cell dry-run on one
CPU core).  Remat policy per config: none | dots | full.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.context import LOCAL, ParallelContext

Params = dict


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": L.norm_params(cfg),
        "attn": L.attention_params(cfg, k1),
        "norm_mlp": L.norm_params(cfg),
        "mlp": L.mlp_params(cfg, k2),
    }


def stacked_layer_params(cfg: ModelConfig, key, n: int) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_params(cfg, k))(keys)


def init(cfg: ModelConfig, key) -> Params:
    ke, kl, ko = jax.random.split(key, 3)
    p: Params = {
        "embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.param_dtype)),
        "layers": stacked_layer_params(cfg, kl, cfg.n_layers),
        "norm_f": L.norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.embed_init(ko, cfg.vocab_size, cfg.d_model,
                                    jnp.dtype(cfg.param_dtype))
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def decoder_block(
    cfg: ModelConfig,
    lp: Params,
    x: jax.Array,
    positions: jax.Array,
    ctx: ParallelContext,
) -> jax.Array:
    h = L.apply_norm(cfg, lp["norm_attn"], x)
    x = x + L.self_attention(cfg, lp["attn"], h, positions, ctx=ctx)
    h = L.apply_norm(cfg, lp["norm_mlp"], x)
    if ctx.tp_mode == "ring" and ctx.mesh is not None and ctx.model_axis:
        x = x + L.apply_mlp_ring(cfg, lp["mlp"], h, ctx)
    else:
        x = x + L.apply_mlp(cfg, lp["mlp"], h)
    return x


def hidden_states(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,  # (B, S)
    *,
    ctx: ParallelContext = LOCAL,
) -> jax.Array:
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    block = _remat(cfg, functools.partial(decoder_block, cfg, ctx=ctx))

    def body(xc, lp):
        return block(lp, xc, positions), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(cfg, params["norm_f"], x)


def output_embedding(cfg: ModelConfig, params: Params) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def logits_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
              *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, tokens, ctx=ctx)
    return x @ output_embedding(cfg, params).T.astype(x.dtype)


def loss_fn(cfg: ModelConfig, params: Params, batch: dict,
            *, ctx: ParallelContext = LOCAL) -> jax.Array:
    x = hidden_states(cfg, params, batch["tokens"], ctx=ctx)
    return L.chunked_lm_loss(
        x, output_embedding(cfg, params), batch["labels"], cfg.logits_chunk,
        mask=batch.get("mask"),
    )


# ---------------------------------------------------------------------------
# decode (KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),  # per-slot (continuous batching)
    }


def decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # (B, 1)
    cache: dict,
    *,
    ctx: ParallelContext = LOCAL,
) -> tuple[jax.Array, dict]:
    """One decode step; returns (logits (B, 1, V), updated cache)."""
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]

    def body(xc, per_layer):
        lp, ck, cv = per_layer
        h = L.apply_norm(cfg, lp["norm_attn"], xc)
        att, ck, cv = L.decode_attention(cfg, lp["attn"], h, ck, cv, pos)
        xc = xc + att
        h = L.apply_norm(cfg, lp["norm_mlp"], xc)
        xc = xc + L.apply_mlp(cfg, lp["mlp"], h)
        return xc, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = L.apply_norm(cfg, params["norm_f"], x)
    logits = x @ output_embedding(cfg, params).T.astype(x.dtype)
    return logits, {"k": new_k, "v": new_v, "pos": pos + 1}


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,  # (B, S)
    cache: dict,
    *,
    ctx: ParallelContext = LOCAL,
    true_len: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Fill the cache from a full prompt; returns (last-position logits, cache).

    ``true_len`` (shape ``(B,)`` int32, traced) supports bucket-padded
    prompts: logits come from position ``true_len - 1`` and the cache ``pos``
    starts there, so right-padding to a shared bucket length reuses ONE
    persistent plan per bucket.  KV rows past ``true_len`` hold junk from the
    padding, which is safe: decode writes each new token's KV at ``pos``
    before the causal mask exposes it.
    """
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xc, lp):
        h = L.apply_norm(cfg, lp["norm_attn"], xc)
        hd = cfg.resolved_head_dim
        q, k, v = L._project_qkv(cfg, lp["attn"], h)
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
        att = L.prefill_attention(cfg, q, k, v, ctx=ctx)
        att = att.reshape(b, s, -1) @ lp["attn"]["wo"].astype(xc.dtype)
        xc = xc + att
        h2 = L.apply_norm(cfg, lp["norm_mlp"], xc)
        xc = xc + L.apply_mlp(cfg, lp["mlp"], h2)
        return xc, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, params["norm_f"], x)
    if true_len is None:
        last = x[:, -1:]
        pos = jnp.full((b,), s, jnp.int32)
    else:
        pos = jnp.asarray(true_len, jnp.int32).reshape(b)
        idx = jnp.broadcast_to((pos - 1)[:, None, None], (b, 1, x.shape[-1]))
        last = jnp.take_along_axis(x, idx, axis=1)
    logits = last @ output_embedding(cfg, params).T.astype(x.dtype)
    smax = cache["k"].shape[2]
    new_k = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return logits, {"k": new_k, "v": new_v, "pos": pos}
