"""End-to-end serving driver: a small LM served with batched requests,
continuous batching, and persistent compiled step plans.

    PYTHONPATH=src python examples/serve_batched.py [--arch stablelm-1.6b]
        [--width 128] [--layers 4] [--requests 12] [--slots 4]

The default builds a ~20M-parameter stablelm-family model (CPU-friendly);
``--full`` serves the unreduced config (needs a real accelerator slice).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--bench-out", default="",
                    help="run the transport-layer serve cells "
                         "(repro.serving.bench) and write BENCH records here")
    args = ap.parse_args()

    if args.bench_out:
        from repro.serving.bench import main as serve_main

        raise SystemExit(serve_main(
            ["--out", args.bench_out, "--requests", str(args.requests),
             "--slots", str(args.slots), "--max-new", str(args.max_new)]))

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced().with_updates(
            d_model=args.width, n_layers=args.layers, vocab_size=args.vocab,
            d_ff=args.width * 3, n_heads=max(4, args.width // 32),
            n_kv_heads=max(4, args.width // 32), head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"serving {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.slots} slots, {args.requests} requests")

    engine = ServingEngine(model, params, max_slots=args.slots, max_len=256)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    uids = [
        engine.submit(rng.integers(0, cfg.vocab_size,
                                   size=int(rng.integers(4, 24))).tolist(),
                      max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    results = engine.run()
    dt = time.perf_counter() - t0

    for uid in uids[:4]:
        print(f"  req {uid}: {results[uid][:10]}...")
    st = engine.stats
    print(f"{st.tokens_generated} tokens in {dt:.2f}s "
          f"({st.tokens_generated/dt:.1f} tok/s) | "
          f"{st.prefills} prefills, {st.decode_steps} decode steps | "
          f"persistent plans: {st.plan_inits} inits, {st.plan_hits} hits "
          f"(amortization={st.plan_hits/max(1, st.plan_inits):.0f}x)")


if __name__ == "__main__":
    main()
