"""The paper's workload end-to-end: 3-D Jacobi (heat) iteration on a device
mesh with standard / persistent / partitioned halo exchanges.

Runs on 8 fake CPU devices (the flag below must precede the jax import).

    PYTHONPATH=src python examples/stencil_heat3d.py [--cycles 20] [--size 32]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import make_mesh
from repro.kernels.stencil27 import jacobi_weights, stencil27_ref
from repro.stencil import Domain, comb_measure, periodic_oracle_step
from repro.stencil.strategies import available_strategies


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cycles", type=int, default=10)
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--strategy",
                    choices=[*available_strategies(), "auto"],
                    help="measure+verify just this strategy (against the "
                         "standard baseline); default: all registered, e.g. "
                         "--strategy fused or --strategy overlap; 'auto' "
                         "lets the repro.core.autotune tuner pick strategy, "
                         "packer, and coalesce mode for this cell")
    from repro.core.transport import available_packers

    ap.add_argument("--packer", choices=available_packers(), default="slice",
                    help="transport-layer pack backend every message stages "
                         "through (pallas = the Comb-style copy kernel; "
                         "falls back to its oracle off-TPU)")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable wire-buffer coalescing (per-message "
                         "pack/permute/unpack instead of one buffer + one "
                         "composed collective per neighbor hop chain)")
    args = ap.parse_args()
    coalesce = not args.no_coalesce

    mesh = make_mesh((4, 2), ("pz", "py"))  # compat shim handles axis_types
    dom = Domain(mesh, global_interior=(args.size, args.size, args.size // 2),
                 mesh_axes=("pz", "py", None))
    w = jacobi_weights()

    def update(xl):
        # periodic wrap on the undecomposed x-axis, then 27-point Jacobi
        xp = jnp.concatenate([xl[..., -1:], xl, xl[..., :1]], axis=-1)
        interior = stencil27_ref(xp, jnp.asarray(w))
        return jax.lax.dynamic_update_slice(xl, interior, (1, 1, 0))

    from repro.stencil import StrategyConfig

    names = (
        tuple(available_strategies()) if args.strategy is None
        else tuple(dict.fromkeys(("standard", args.strategy)))
    )
    strategies = tuple(
        # fully-open autotune cell: the tuner owns packer, coalesce mode,
        # and the partition count, so the CLI pins none of them
        StrategyConfig(name="auto", packer="auto", coalesce="auto")
        if s == "auto" else
        StrategyConfig(
            name=s, packer=args.packer, coalesce=coalesce,
            n_parts=args.parts if s == "partitioned" else 1,
        )
        for s in names
    )
    print(f"domain {dom.global_interior} on mesh {dict(mesh.shape)}; "
          f"{args.cycles} cycles per strategy: {', '.join(names)} "
          f"(packer={args.packer}, "
          f"{'coalesced' if coalesce else 'uncoalesced'})")
    results = comb_measure(dom, strategies=strategies, update_fn=update,
                           n_cycles=args.cycles, repeats=3)
    from repro.stencil.comb import result_label

    base = results[
        result_label("standard", args.packer, coalesce)
    ].us_per_cycle
    for s, r in results.items():
        sp = (base / r.us_per_cycle - 1.0) * 100.0
        print(f"  {s:12s} {r.us_per_cycle:9.1f} us/cycle  "
              f"speedup={sp:+6.1f}%  init={r.init_us:.0f}us")
        if r.selected_by:
            print(f"  {'':12s} resolved to {r.strategy}@{r.packer} "
                  f"{'coalesced' if r.coalesce else 'uncoalesced'} "
                  f"p={r.n_parts} via {r.selected_by} "
                  f"(predicted {r.predicted_us or 0.0:.1f}us, "
                  f"calibration {r.calibration_us / 1e6:.2f}s)")

    # verify against the periodic numpy oracle
    interior = np.random.default_rng(0).normal(
        size=dom.global_interior).astype(np.float32)
    want = interior.copy()
    for _ in range(args.cycles):
        want = periodic_oracle_step(want, np.asarray(w))
    from repro.stencil import make_driver

    verify_with = args.strategy or "persistent"
    verify_config = (
        StrategyConfig(name="auto", packer="auto", coalesce="auto")
        if verify_with == "auto" else
        StrategyConfig(name=verify_with, n_parts=args.parts,
                       packer=args.packer, coalesce=coalesce)
    )
    drv = make_driver(
        verify_config, dom.mesh, dom.halo_spec, ndim=3, update_fn=update,
    )
    x = dom.from_global_interior(interior)
    for _ in range(args.cycles):
        x = drv.step(x)
    got = dom.to_global_interior(drv.wait(x))
    resolved = drv.strategy  # concrete name even when verify_with == "auto"
    drv.free()
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    tag = f"auto→{resolved}" if verify_with == "auto" else verify_with
    print(f"{tag}: verified against periodic numpy oracle ✓")


if __name__ == "__main__":
    main()
