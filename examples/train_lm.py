"""End-to-end training driver with checkpointing and fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 40] [--fail-at 25]

Default: a ~20M-parameter llama-family model on CPU with a mid-run injected
failure — the run restarts from the latest checkpoint and finishes with the
same trajectory an uninterrupted run would produce.  For a real ~100M/full
run on accelerators: ``--width 768 --layers 12 --batch 64 --seq 1024`` and a
production mesh via repro.launch.train.
"""

import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.models import build_model
from repro.train.fault_tolerance import FailureInjector
from repro.train.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--fail-at", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_updates(
        d_model=args.width, n_layers=args.layers, d_ff=args.width * 3,
        vocab_size=4096, n_heads=max(4, args.width // 32),
        n_kv_heads=max(2, args.width // 64), head_dim=32)
    model = build_model(cfg)
    n = sum(x.size for x in jax.tree.leaves(model.init(jax.random.key(0))))
    print(f"training {cfg.name} (reduced, {n/1e6:.1f}M params) "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    run_cfg = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", args.seq, args.batch, "train"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=10,
                                  total_steps=args.steps * 2),
        steps=args.steps,
        log_every=10,
        checkpoint_dir=ckpt_dir,
        checkpoint_every=10,
        async_checkpoint=True,
    )
    injector = FailureInjector(
        fail_at_steps=(args.fail_at,) if args.fail_at >= 0 else ())
    result = Trainer(model, run_cfg, injector=injector).run()
    print(f"done: loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f}, "
          f"restarts={result.restarts} (checkpoints in {ckpt_dir})")
    assert result.losses[-1] < result.losses[0]


if __name__ == "__main__":
    main()
