"""Quickstart: train a tiny LM for a few steps, then generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.models import build_model
from repro.serving.engine import ServingEngine
from repro.train.train_loop import Trainer


def main() -> None:
    # a reduced llama3-style config (64-wide, 2 layers) that trains on CPU
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} (reduced): {sum(x.size for x in jax.tree.leaves(model.init(jax.random.key(0)))):,} params")

    run_cfg = RunConfig(
        model=cfg,
        shape=ShapeConfig("quickstart", seq_len=32, global_batch=8, kind="train"),
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=60),
        steps=30,
        log_every=10,
    )
    result = Trainer(model, run_cfg).run()
    print(f"loss: {result.losses[0]:.3f} -> {result.losses[-1]:.3f} over "
          f"{len(result.losses)} steps")
    assert result.losses[-1] < result.losses[0], "loss must decrease"

    # generate a few tokens with the serving engine (persistent plans)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_slots=2, max_len=64)
    uid = engine.submit([1, 2, 3, 4], max_new_tokens=8)
    out = engine.run()
    print("generated:", out[uid])
    print("plan stats:", engine.stats.plan_inits, "inits,",
          engine.stats.plan_hits, "cache hits")


if __name__ == "__main__":
    main()
