"""Sequence parallelism demo: ring attention with partitioned KV exchange and
SSM/RWKV state passing across sequence shards (8 fake CPU devices).

The ring exchange is the paper's partitioned pipeline with attention as the
consumer: each KV partition is sent as soon as available while the previous
one is being attended to (MPI_Pready/Parrived -> ppermute chunk + early work).

    PYTHONPATH=src python examples/long_context_ring.py [--seq 512]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.ring import ring_attention, state_passing
from repro.models import build_model, concrete_batch
from repro.parallel.context import ParallelContext


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--parts", type=int, default=4)
    args = ap.parse_args()

    mesh = jax.make_mesh((1, 8), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(0)
    B, H, Hkv, D = 2, 8, 4, 32
    q = jnp.asarray(rng.normal(size=(B, args.seq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, args.seq, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, args.seq, Hkv, D)), jnp.float32)
    spec = P(None, "model", None, None)

    print(f"ring attention over seq={args.seq} on 8 sequence shards")
    for n_parts, label in ((1, "fused (persistent-style)"),
                           (args.parts, f"partitioned (n_parts={args.parts})")):
        fn = jax.jit(jax.shard_map(
            lambda a, b, c, n=n_parts: ring_attention(a, b, c, "model",
                                                      causal=True, n_parts=n),
            mesh=mesh, in_specs=(spec,) * 3, out_specs=spec, check_vma=False))
        out = fn(q, k, v)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(q, k, v)
        jax.block_until_ready(out)
        print(f"  {label:32s} {(time.perf_counter()-t0)/5*1e3:7.2f} ms/call")

    # full end-to-end: zamba2 (SSM + shared attention) with sequence-parallel
    # prefill — conv ghost cells + associative state passing around the ring.
    cfg = get_config("zamba2-1.2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = concrete_batch(cfg, 4, args.seq // 4, seed=1)
    local = ParallelContext(mesh=mesh, model_axis="model")
    seqp = ParallelContext(mesh=mesh, model_axis="model", seq_parallel=True,
                           n_parts=args.parts)
    with jax.set_mesh(mesh):
        want = jax.jit(lambda p, b: model.loss(p, b, ctx=local))(params, batch)
        got = jax.jit(lambda p, b: model.loss(p, b, ctx=seqp))(params, batch)
    print(f"zamba2 seq-parallel loss {float(got):.5f} vs local {float(want):.5f}")
    np.testing.assert_allclose(float(got), float(want), rtol=2e-2)
    print("sequence-parallel == local ✓")


if __name__ == "__main__":
    main()
