"""Structural (HLO-level) analysis of partitioned-communication overlap.

Without real TPU timing, the partitioned win is verified structurally: the
compiled HLO of a partitioned exchange must contain ``n_parts`` independent
``collective-permute`` rounds per direction (per hop chain when coalesced —
partition rounds stay pipelined either way), interleaved with the per-chunk
pack/unpack compute, so a latency-hiding scheduler can overlap them.  The
fused (standard/persistent) exchange has one collective per direction and no
interleaving freedom.

Reported per configuration:
  * number of collective-permute ops (partitioned == n_parts x fused),
  * wire bytes (must be ~equal: partitioning must not inflate traffic),
  * overlappable fraction = bytes in collectives that have at least one
    independent sibling collective (can be in flight simultaneously).

Run: PYTHONPATH=src python -m benchmarks.overlap_analysis   (spawns 8-dev child)
"""

from __future__ import annotations

import os
import subprocess
import sys


def _run_inner() -> None:
    import jax

    from repro.core.compat import make_mesh
    from repro.core.hlo_analysis import parse_collectives
    from repro.stencil import Domain, ExchangeDriver

    mesh = make_mesh((4, 2), ("pz", "py"))
    dom = Domain(mesh, global_interior=(64, 32, 16),
                 mesh_axes=("pz", "py", None))

    for strategy, parts in (("persistent", 1), ("partitioned", 2),
                            ("partitioned", 4), ("partitioned", 8)):
        for coalesce in (False, True):
            drv = ExchangeDriver(
                dom.mesh,
                lambda s=strategy, p=parts, c=coalesce:
                    dom.halo_spec(s, p).with_(coalesce=c),
                ndim=3, strategy=strategy,
            )
            x = dom.random(0)
            text = drv.compiled_text(x)
            stats = parse_collectives(text, default_group=1)
            n_cp = stats.by_op_counts.get("collective-permute", 0)
            wire = stats.wire_bytes
            label = f"{strategy}_p{parts}/c{int(coalesce)}"
            print(f"overlap/{label}/collective_permutes,{n_cp},"
                  f"wire_bytes={wire:.0f}")
            drv.free()


def main() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.overlap_analysis", "--inner"],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise SystemExit(out.returncode)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        _run_inner()
    else:
        main()
