"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only SECTION[,SECTION]]

Prints ``name,us_per_call,derived`` CSV rows:
  figures      — the paper's four figures (fig2..fig5), projected with the
                 calibrated Quartz-class model (configs/comb_paper.py)
  claims       — model vs the paper's quoted speedups (C1-C6)
  measured     — REAL timings on this host: per-iteration dispatch/plan
                 overhead of standard vs persistent vs partitioned (8 fake
                 devices, subprocess)
  overlap      — HLO structural verification that partitioned exchanges
                 decompose into n_parts independent collectives
  sweep        — the §VI device x partition x message-size grid over all
                 registered strategies -> BENCH_*.json
  fig_sweep    — §VI curves (Fig. 6-8 analogues) rendered from the recorded
                 sweep file, with paper-claim comparisons
  lm           — LM benchmarks (tiny configs, real step timings)

``--only`` runs exactly the named sections (comma separated); the default is
figures+claims, plus everything else unless ``--fast``.
"""

from __future__ import annotations

import argparse
import sys


def emit(name: str, us: float | None, derived: str = "") -> None:
    us_s = f"{us:.2f}" if isinstance(us, (int, float)) and us is not None else ""
    print(f"{name},{us_s},{derived}")


def _section_figures(args) -> None:
    from benchmarks import figures

    print("# === paper figures (calibrated model projection) ===")
    figures.fig2_weak_scaling(emit)
    figures.fig3_strong_scaling(emit)
    figures.fig4_message_size(emit)
    figures.fig5_ranks_per_node(emit)


def _section_claims(args) -> None:
    from benchmarks import figures

    print("# === paper-claim validation (model vs quoted numbers) ===")
    figures.claims_table(emit)


def _section_measured(args) -> None:
    print("# === measured (real CPU timings, 8 fake devices) ===")
    from benchmarks import measured_dispatch

    measured_dispatch.main()


def _section_overlap(args) -> None:
    print("# === partitioned-overlap structure (HLO analysis) ===")
    from benchmarks import overlap_analysis

    overlap_analysis.main()


def _section_sweep(args) -> None:
    print("# === §VI sweep: devices x partitions x message size x packer ===")
    from repro.stencil.sweep import SweepConfig, config_block, run_sweep, \
        summarize, write_bench_json

    config = SweepConfig(device_counts=(2, 4, 8), part_counts=(1, 2, 4),
                         sizes=((32, 16), (64, 32)))
    records = run_sweep(config, timeout=args.sweep_timeout)
    write_bench_json(
        records, args.sweep_out,
        config=config_block(config, timeout=args.sweep_timeout),
    )
    for row in summarize(records):
        print(row)
    print(f"# sweep: {len(records)} records -> {args.sweep_out}")


def _section_fig_sweep(args) -> None:
    print("# === §VI figures (measured sweep vs paper Fig. 6-8) ===")
    from benchmarks import figures

    figures.fig_sweep(emit, sweep_path=args.sweep_out)


def _section_lm(args) -> None:
    print("# === LM benchmarks (tiny configs, real step timings) ===")
    from benchmarks import lm_bench

    lm_bench.main()


#: registration order is run order
SECTIONS = {
    "figures": _section_figures,
    "claims": _section_claims,
    "measured": _section_measured,
    "overlap": _section_overlap,
    "sweep": _section_sweep,
    "fig_sweep": _section_fig_sweep,
    "lm": _section_lm,
}

#: sections skipped under --fast (subprocess-heavy / real timings)
SLOW_SECTIONS = ("measured", "overlap", "sweep", "fig_sweep", "lm")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="model-only (skip measured subprocess benchmarks)")
    ap.add_argument("--only", metavar="SECTION[,SECTION]",
                    help=f"run exactly these sections; one or more of: "
                         f"{', '.join(SECTIONS)}")
    ap.add_argument("--sweep-out", default="BENCH_stencil_sweep.json",
                    help="where the §VI sweep writes (and fig_sweep reads) "
                         "its BENCH_*.json records")
    ap.add_argument("--sweep-timeout", type=float, default=1200.0,
                    help="per-subprocess timeout (seconds) for the sweep "
                         "section's device-count fan-out")
    args = ap.parse_args()
    from repro.stencil.sweep import is_bench_path

    if not is_bench_path(args.sweep_out):
        # fail before minutes of sweep subprocesses, not at write time
        ap.error(f"--sweep-out must be named BENCH_*.json, got {args.sweep_out!r}")

    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in selected if s not in SECTIONS]
        if unknown:
            ap.error(f"unknown section(s) {unknown}; "
                     f"choose from: {', '.join(SECTIONS)}")
    else:
        selected = [
            s for s in SECTIONS if not (args.fast and s in SLOW_SECTIONS)
        ]

    for name in SECTIONS:  # run in registration order regardless of --only order
        if name in selected:
            SECTIONS[name](args)
    print("# done")


if __name__ == "__main__":
    main()
