"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows:
  fig2..fig5   — the paper's four figures, projected with the calibrated
                 Quartz-class model (configs/comb_paper.py)
  claims/*     — model vs the paper's quoted speedups (C1-C6)
  measured/*   — REAL timings on this host: per-iteration dispatch/plan
                 overhead of standard vs persistent vs partitioned (8 fake
                 devices, subprocess)
  overlap/*    — HLO structural verification that partitioned exchanges
                 decompose into n_parts independent collectives
"""

from __future__ import annotations

import argparse
import sys


def emit(name: str, us: float | None, derived: str = "") -> None:
    us_s = f"{us:.2f}" if isinstance(us, (int, float)) and us is not None else ""
    print(f"{name},{us_s},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="model-only (skip measured subprocess benchmarks)")
    ap.add_argument("--sweep-out", default="BENCH_stencil_sweep.json",
                    help="where the §VI sweep writes its BENCH_*.json records")
    args = ap.parse_args()
    from repro.stencil.sweep import is_bench_path

    if not is_bench_path(args.sweep_out):
        # fail before minutes of sweep subprocesses, not at write time
        ap.error(f"--sweep-out must be named BENCH_*.json, got {args.sweep_out!r}")

    from benchmarks import figures

    print("# === paper figures (calibrated model projection) ===")
    figures.fig2_weak_scaling(emit)
    figures.fig3_strong_scaling(emit)
    figures.fig4_message_size(emit)
    figures.fig5_ranks_per_node(emit)
    print("# === paper-claim validation (model vs quoted numbers) ===")
    figures.claims_table(emit)

    if not args.fast:
        print("# === measured (real CPU timings, 8 fake devices) ===")
        from benchmarks import measured_dispatch

        measured_dispatch.main()
        print("# === partitioned-overlap structure (HLO analysis) ===")
        from benchmarks import overlap_analysis

        overlap_analysis.main()

        print("# === §VI sweep: devices x partitions x message size ===")
        from repro.stencil.sweep import SweepConfig, run_sweep, summarize, \
            write_bench_json

        config = SweepConfig(device_counts=(2, 4, 8), part_counts=(1, 2, 4),
                             sizes=((32, 16), (64, 32)))
        records = run_sweep(config)
        write_bench_json(records, args.sweep_out)
        for row in summarize(records):
            print(row)
        print(f"# sweep: {len(records)} records -> {args.sweep_out}")

        print("# === LM benchmarks (tiny configs, real step timings) ===")
        from benchmarks import lm_bench

        lm_bench.main()
    print("# done")


if __name__ == "__main__":
    main()
