"""Roofline report: three terms per (arch x shape) cell from the dry-run.

    PYTHONPATH=src python -m benchmarks.roofline [--tag hillclimb-x] [--csv]

Reads results/dryrun/<cell>.json (produced by repro.launch.dryrun), computes

    compute term    = HLO_FLOPs_per_device / 197 TFLOP/s
    memory term     = HLO_bytes_per_device / 819 GB/s
    collective term = wire_bytes_per_device / 50 GB/s/link

plus MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE, + attention quadratic term),
the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant bottleneck, and
the roofline MFU bound.  Writes results/roofline.md and prints CSV rows.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.hlo_analysis import V5E, roofline

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS for one step of this cell (global)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
        attn_ctx = shape.seq_len
        causal_factor = 0.5
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
        attn_ctx = shape.seq_len
        causal_factor = 0.5 if cfg.causal else 1.0
    else:  # decode: one token against a seq_len cache
        tokens = shape.global_batch * 1
        mult = 2.0
        attn_ctx = shape.seq_len
        causal_factor = 1.0
    flops = mult * n * tokens
    if cfg.n_heads and cfg.family not in ("rwkv",):
        d_attn = cfg.n_heads * cfg.resolved_head_dim
        n_attn_layers = cfg.n_layers
        if cfg.family == "hybrid":
            n_attn_layers = max(1, cfg.n_layers // max(1, cfg.attn_every))
        flops += (mult * 2 * d_attn * attn_ctx * causal_factor
                  * n_attn_layers * tokens)
    return flops


def load_cells(mesh: str = "single", tag: str = "") -> list[dict]:
    suffix = f".{tag}" if tag else ""
    out = []
    # tagged variants are named <arch>.<shape>.<mesh>.<tag>.json, which the
    # suffix-anchored glob already excludes when tag == "".
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                              f"*.{mesh}{suffix}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def cell_roofline(cell: dict):
    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    mf = model_flops(cfg, shape)
    full = cell["full"]
    return roofline(
        hlo_flops_per_device=full["flops"],
        hlo_bytes_per_device=full["bytes"],
        wire_bytes_per_device=full["wire_bytes"],
        model_flops_global=mf,
        n_chips=cell["n_devices"],
    )


_ACTIONS = {
    "compute": "reduce recompute (remat policy) / raise useful-flop ratio",
    "memory": "fuse attention score traffic (blockwise/flash) and cast "
              "collectives+activations to bf16",
    "collective": "cut TP all-reduces (seq-sharded RS+AG), overlap with "
                  "partitioned collectives, hoist FSDP gathers",
}


def report(mesh: str = "single", tag: str = "", emit=None) -> str:
    cells = load_cells(mesh, tag)
    lines = [
        f"| arch | shape | compute s | memory s | collective s | bottleneck "
        f"| MFU bound | useful ratio | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for cell in cells:
        t = cell_roofline(cell)
        row = (f"| {cell['arch']} | {cell['shape']} | {t.compute_s:.3f} | "
               f"{t.memory_s:.3f} | {t.collective_s:.3f} | {t.bottleneck} | "
               f"{t.mfu_bound*100:.1f}% | {t.useful_flops_ratio:.2f} | "
               f"{'y' if cell.get('fits_16gb') else 'N'} |")
        lines.append(row)
        if emit:
            emit(f"roofline/{mesh}/{cell['arch']}/{cell['shape']}",
                 t.step_time_s * 1e6,
                 f"bottleneck={t.bottleneck};mfu_bound={t.mfu_bound*100:.1f}%;"
                 f"useful={t.useful_flops_ratio:.2f}")
    return "\n".join(lines)


def detail(arch: str, shape: str, mesh: str = "single", tag: str = "") -> None:
    suffix = f".{tag}" if tag else ""
    path = os.path.join(RESULTS_DIR, f"{arch}.{shape}.{mesh}{suffix}.json")
    with open(path) as f:
        cell = json.load(f)
    t = cell_roofline(cell)
    full = cell["full"]
    print(f"=== {arch} x {shape} x {mesh}{suffix} ===")
    print(f"compute    {t.compute_s:9.3f}s   (HLO {full['flops']:.3e} flops/dev)")
    print(f"memory     {t.memory_s:9.3f}s   (HLO {full['bytes']:.3e} B/dev)")
    print(f"collective {t.collective_s:9.3f}s   ({full['wire_bytes']/1e9:.1f} GB/dev wire)")
    print(f"bottleneck: {t.bottleneck} -> {_ACTIONS[t.bottleneck]}")
    print(f"MODEL_FLOPS/dev {t.model_flops:.3e}; useful ratio "
          f"{t.useful_flops_ratio:.3f}; MFU bound {t.mfu_bound*100:.1f}%")
    print("wire by op:", {k: f"{v/1e9:.1f}GB"
                          for k, v in full["wire_by_op"].items()})
    print("memory:", {k: f"{v/1e9:.2f}GB" for k, v in full["memory"].items()})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--detail", nargs=2, metavar=("ARCH", "SHAPE"))
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()
    if args.detail:
        detail(args.detail[0], args.detail[1], args.mesh, args.tag)
        return
    table = report(args.mesh, args.tag)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(f"# Roofline table ({args.mesh}-pod"
                    f"{', tag=' + args.tag if args.tag else ''})\n\n")
            f.write(table + "\n")


if __name__ == "__main__":
    main()
