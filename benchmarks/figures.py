"""Paper-figure benchmarks (Figs. 2-5): model-projected cluster-scale sweeps.

Each function reproduces one figure of the paper using the calibrated
Quartz-class machine model (configs/comb_paper.py).  Output rows are CSV:
``name,us_per_call,derived`` where ``derived`` carries the paper-style
speedup percentages.  Per-claim comparison against the paper's quoted numbers
is appended (EXPERIMENTS.md §Paper mirrors it).
"""

from __future__ import annotations

import json
import math
import os

from repro.configs import comb_paper as cp
from repro.core.model_comm import simulate, speedup


def _trio(wl, nprocs, rpn, threads, n_parts=None):
    b = simulate("standard", cp.QUARTZ, wl, nprocs=nprocs, ranks_per_node=rpn,
                 threads=threads)
    p = simulate("persistent", cp.QUARTZ, wl, nprocs=nprocs, ranks_per_node=rpn,
                 threads=threads)
    q = simulate("partitioned", cp.QUARTZ, wl, nprocs=nprocs, ranks_per_node=rpn,
                 threads=threads, n_parts=n_parts)
    return b, p, q


def fig2_weak_scaling(emit) -> dict:
    cfg = cp.FIG2_WEAK
    wl = cp.fig2_workload()
    out = {}
    for n in cfg["procs"]:
        b, p, q = _trio(wl, n, cfg["ranks_per_node"], cfg["threads"])
        emit(f"fig2/weak/std/p{n}", b.total * 1e6, "")
        emit(f"fig2/weak/pers/p{n}", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig2/weak/part/p{n}", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[n] = (speedup(b, p), speedup(b, q))
    return out


def fig3_strong_scaling(emit) -> dict:
    cfg = cp.FIG3_STRONG
    out = {}
    for n in cfg["procs"]:
        wl = cp.fig3_workload(n)
        b, p, q = _trio(wl, n, cfg["ranks_per_node"], cfg["threads"])
        face = wl.messages()[0]
        emit(f"fig3/strong/std/p{n}", b.total * 1e6, f"face_bytes={face}")
        emit(f"fig3/strong/pers/p{n}", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig3/strong/part/p{n}", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[n] = (speedup(b, p), speedup(b, q))
    return out


def fig4_message_size(emit) -> dict:
    cfg = cp.FIG4_MSG_SIZE
    out = {}
    for doubles in cfg["doubles"]:
        wl = cp.fig4_workload(doubles)
        b, p, q = _trio(wl, cfg["procs"], cfg["ranks_per_node"], cfg["threads"])
        emit(f"fig4/msgsize/std/d{doubles}", b.total * 1e6, "")
        emit(f"fig4/msgsize/pers/d{doubles}", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig4/msgsize/part/d{doubles}", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[doubles] = (speedup(b, p), speedup(b, q))
    return out


def fig5_ranks_per_node(emit) -> dict:
    cfg = cp.FIG5_RANKS_PER_NODE
    out = {}
    for rpn in cfg["ranks_per_node"]:
        n = cfg["nodes"] * rpn
        threads = cfg["threads_per_node"] // rpn
        wl = cp.fig5_workload(n)
        b, p, q = _trio(wl, n, rpn, threads)
        emit(f"fig5/rpn{rpn}/std", b.total * 1e6, f"threads={threads}")
        emit(f"fig5/rpn{rpn}/pers", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig5/rpn{rpn}/part", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[rpn] = (speedup(b, p), speedup(b, q))
    return out


# ---------------------------------------------------------------------------
# §VI sweep figures: measured records (BENCH_stencil_sweep.json) vs Fig. 6-8
# ---------------------------------------------------------------------------

#: the paper's §VI quoted numbers the measured sweep is compared against
SWEEP_CLAIMS = (
    ("S1", "persistent", "persistent peak speedup (paper: up to 37%)", 37.0),
    ("S2", "partitioned", "partitioned peak speedup (paper: up to 68%)", 68.0),
    ("S3", "partitioned", "partitioned small-msg penalty (paper: -42.2%)",
     -42.2),
)


def load_sweep_records(path: str) -> list[dict]:
    """Read one ``BENCH_stencil_sweep.json`` file.

    Accepts both interchange forms: the historical bare list of flat
    records, and the config-block wrapper ``{"config": ..., "records":
    [...]}`` the sweep CLI writes (run parameters travel with the data).
    """
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no sweep records at {path!r}; produce them first with "
            f"`PYTHONPATH=src python -m repro.stencil.sweep --out {path}` "
            f"(or `--smoke` for a 1-cell grid)"
        )
    from repro.stencil.sweep import read_bench_json

    records, _config = read_bench_json(path)
    assert isinstance(records, list) and records, f"{path}: empty sweep"
    return records


def fig_sweep(emit, sweep_path: str = "BENCH_stencil_sweep.json",
              records: list[dict] | None = None,
              baseline: str = "standard") -> dict:
    """The §VI study from MEASURED records: speedup-vs-baseline curves over
    device count (Fig. 6 analogue: process count), partition count (Fig. 7:
    thread count), message size (Fig. 8), the packer axis (the transport
    layer's packing dimension), the wire-buffer coalesce axis, and the
    process-to-node mapping axis (repro.launch.mapping), plus
    raw-latency overlays at the larger message sizes, plan-cache/collective
    amortization rows, and the paper-claim comparison rows.

    Unlike fig2-fig5 (calibrated model projections) this section renders
    what the sweep actually measured on this host.  Returns the structured
    form (``rows`` one per (strategy, cell), ``curves`` per axis, ``raw``
    absolute-time overlay rows, ``claims``, and ``autotune`` — the
    autotuned-vs-best/worst-static comparison per cell) that
    ``tests/benchmarks/test_fig_sweep.py`` validates.

    Autotuned records (``selected_by`` set) render as rows tagged
    ``auto:<resolved strategy>`` but are EXCLUDED from the per-strategy
    curves, claims, and raw overlays: a tuned cell resolving to
    ``overlap`` is a selection result, not an ``overlap`` measurement, and
    folding it in would double-count the static grid.
    """
    if records is None:
        records = load_sweep_records(sweep_path)

    def packer_of(r: dict) -> str:
        return r.get("packer", "slice")  # pre-transport-layer records

    def wire_bytes_of(r: dict) -> int:
        # pre-compression records shipped the face dtype unchanged
        return r.get("wire_bytes", r["message_bytes"])

    def coalesce_of(r: dict) -> bool:
        # pre-coalescing records ran the per-message pipeline
        return bool(r.get("coalesce", False))

    def mapping_of(r: dict) -> str:
        # pre-mapping records ran the identity (row-major) placement
        return r.get("mapping", "row-major")

    def strat_tag(r: dict) -> str:
        # non-default placements suffix the strategy segment (the same
        # `%mapping` convention as ScheduleInfo.tag()), keeping row names
        # unique across the mapping axis without changing their arity;
        # autotuned records prefix `auto:` so a tuned cell never collides
        # with the identical static one
        m = mapping_of(r)
        tag = r["strategy"] if m == "row-major" else f"{r['strategy']}%{m}"
        return f"auto:{tag}" if r.get("selected_by") else tag

    # static records are the measured §VI grid; autotuned ones are the
    # selection layer's outcomes on top of it
    static = [r for r in records if not r.get("selected_by")]
    autos = [r for r in records if r.get("selected_by")]

    # --- per-(strategy, cell) rows; every cell must carry its baseline ----
    cells: dict[tuple, set] = {}
    rows = []
    for r in records:
        cell = (r["n_devices"], tuple(r["global_interior"]))
        if not r.get("selected_by"):
            cells.setdefault(cell, set()).add(r["strategy"])
        sp = r["speedup_vs_baseline"]
        assert math.isfinite(sp) and sp > 0, (r["strategy"], cell, sp)
        name = (f"fig_sweep/d{r['n_devices']}/p{r['n_parts']}"
                f"/m{r['message_bytes']}/{packer_of(r)}"
                f"/c{int(coalesce_of(r))}/{strat_tag(r)}")
        pct = (sp - 1.0) * 100.0
        rows.append((name, r["us_per_cycle"], pct))
        emit(name, r["us_per_cycle"], f"speedup={pct:.1f}%")
    for cell, strategies in cells.items():
        assert baseline in strategies, (
            f"cell {cell} has no {baseline!r} baseline run"
        )

    # --- curves: best speedup per strategy along each §VI axis ------------
    def curve(axis_key, *, keep_baseline: bool = False) -> dict:
        best: dict[tuple, float] = {}
        for r in static:
            if r["strategy"] == baseline and not keep_baseline:
                continue
            k = (r["strategy"], axis_key(r))
            pct = (r["speedup_vs_baseline"] - 1.0) * 100.0
            best[k] = max(pct, best.get(k, -math.inf))
        return best

    curves = {
        "devices": curve(lambda r: r["n_devices"]),
        "parts": curve(lambda r: r["n_parts"]),
        "msgsize": curve(lambda r: r["message_bytes"]),
        # the baseline stays in: standard@pallas vs standard@slice IS the
        # packing effect the transport layer makes sweepable.
        "packer": curve(packer_of, keep_baseline=True),
        # wire-compression axis: bytes a face actually costs on the wire
        # under each record's packer (bf16/scaled-int8 shrink it) — the
        # baseline stays in for the same reason as the packer axis.
        "wirebytes": curve(wire_bytes_of, keep_baseline=True),
        # message-coalescing axis: standard@coalesced vs standard@uncoalesced
        # IS the one-collective-per-neighbor effect, so the baseline stays.
        "coalesce": curve(coalesce_of, keep_baseline=True),
        # process-to-node placement axis: standard@blocked vs
        # standard@row-major IS the topology-mapping effect, so the
        # baseline stays here too.
        "mapping": curve(mapping_of, keep_baseline=True),
    }
    for axis, fig in (("devices", 6), ("parts", 7), ("msgsize", 8),
                      ("packer", None), ("wirebytes", None),
                      ("coalesce", None), ("mapping", None)):
        for (strategy, coord), pct in sorted(curves[axis].items()):
            fig_tag = f";paper_fig={fig}" if fig else ""
            emit(f"fig_sweep/curve_{axis}/{strategy}/{coord}", None,
                 f"speedup={pct:.1f}%{fig_tag}")

    # --- amortization + coalescing evidence rows --------------------------
    # The persistent-amortization claim (plans initialized once, then cache
    # hits) and the coalescing claim (fewer collectives per step) rendered
    # straight from the recorded counters; legacy records without the
    # counters emit nothing.
    amortization = []
    for r in records:
        if "plan_cache_inits" not in r and "collective_count" not in r:
            continue
        name = (f"fig_sweep/amortization/d{r['n_devices']}"
                f"/p{r['n_parts']}/m{r['message_bytes']}/{packer_of(r)}"
                f"/c{int(coalesce_of(r))}/{strat_tag(r)}")
        inits = r.get("plan_cache_inits", 0)
        hits = r.get("plan_cache_hits", 0)
        colls = r.get("collective_count")
        amortization.append((name, inits, hits, colls))
        emit(name, None,
             f"plan_inits={inits};plan_hits={hits};collectives={colls}")

    # --- raw-latency overlays at the larger message sizes -----------------
    # Speedup curves hide *where the time goes*; these rows overlay the
    # ABSOLUTE per-cycle time of the beyond-paper strategies (fused,
    # overlap) on the paper trio, restricted to the upper half of the
    # swept message sizes (the regime the ROADMAP's raw-latency item asks
    # about: large messages are where packing and overlap decisions move
    # real microseconds).
    sizes = sorted({r["message_bytes"] for r in static})
    top_sizes = set(sizes[len(sizes) // 2:]) if sizes else set()
    raw = []
    for r in static:
        if r["message_bytes"] not in top_sizes:
            continue
        name = (f"fig_sweep/raw/m{r['message_bytes']}/d{r['n_devices']}"
                f"/p{r['n_parts']}/{packer_of(r)}"
                f"/c{int(coalesce_of(r))}/{strat_tag(r)}")
        raw.append((name, r["us_per_cycle"], r["strategy"]))
        emit(name, r["us_per_cycle"],
             f"raw_us={r['us_per_cycle']:.1f};strategy={r['strategy']}")
    raw_strategies = {s for _, _, s in raw}
    for s in ("fused", "overlap"):
        if any(r["strategy"] == s for r in static):
            assert s in raw_strategies, (
                f"raw overlay lost {s!r} at sizes {sorted(top_sizes)}"
            )

    # --- autotune vs the static grid --------------------------------------
    # One row per tuned cell: where the selection landed relative to the
    # best and worst static cells it could have picked.  `auto_pct >=
    # best_static_pct` (up to measurement noise) is the tentpole's headline
    # claim; `worst_static_pct` shows the downside a mispick would have
    # cost.  Keyed by mapping too — the tuner runs once per placement.
    autotune = []
    for r in autos:
        key = (r["n_devices"], tuple(r["global_interior"]), mapping_of(r))
        pcts = [
            (s["speedup_vs_baseline"] - 1.0) * 100.0
            for s in static
            if (s["n_devices"], tuple(s["global_interior"]),
                mapping_of(s)) == key
        ]
        auto_pct = (r["speedup_vs_baseline"] - 1.0) * 100.0
        best_pct = max(pcts) if pcts else None
        worst_pct = min(pcts) if pcts else None
        autotune.append({
            "cell": key,
            "strategy": r["strategy"],
            "selected_by": r["selected_by"],
            "auto_pct": auto_pct,
            "best_static_pct": best_pct,
            "worst_static_pct": worst_pct,
        })
        best_tag = "" if best_pct is None else (
            f";best_static={best_pct:.1f}%;worst_static={worst_pct:.1f}%"
        )
        emit(
            f"fig_sweep/autotune/d{r['n_devices']}/m{r['message_bytes']}"
            f"/{mapping_of(r)}",
            r["us_per_cycle"],
            f"auto={auto_pct:.1f}%{best_tag}"
            f";picked={r['strategy']};selected_by={r['selected_by']}",
        )

    # --- measured vs the paper's quoted §VI numbers -----------------------
    claims = []
    for cid, strategy, desc, paper_pct in SWEEP_CLAIMS:
        pcts = [
            (r["speedup_vs_baseline"] - 1.0) * 100.0
            for r in static if r["strategy"] == strategy
        ]
        measured = (
            (min(pcts) if paper_pct < 0 else max(pcts)) if pcts else None
        )
        claims.append((cid, desc, paper_pct, measured))
        emit(f"fig_sweep/claims/{cid}", measured,
             f"paper={paper_pct} :: {desc}")
    return {"rows": rows, "curves": curves, "raw": raw, "claims": claims,
            "amortization": amortization, "autotune": autotune}


# paper-claim validation table (C1-C6 of DESIGN.md §1)
def claims_table(emit) -> list[tuple[str, str, float, float]]:
    f2 = fig2_weak_scaling(lambda *a: None)
    f3 = fig3_strong_scaling(lambda *a: None)
    f4 = fig4_message_size(lambda *a: None)
    f5 = fig5_ranks_per_node(lambda *a: None)
    rows = [
        ("C1", "pers>=base everywhere (weak@4096: paper 12.5%)", 12.5, f2[4096][0]),
        ("C1", "pers peak (strong@2048: paper 37%)", 37.0, f3[2048][0]),
        ("C2", "part total weak@4096 (paper 27%)", 27.0, f2[4096][1]),
        ("C2", "part peak strong@1024 (paper 68%)", 68.0, f3[1024][1]),
        ("C3", "part small-msg penalty (paper -42.2%)", -42.2, f4[768][1]),
        ("C4", "pers large-msg (paper 21%)", 21.0, f4[196608][0]),
        ("C4", "part large-msg (paper 37%)", 37.0, f4[196608][1]),
        ("C5", "part @1 rank/node worse than base (<0)", -1.0, f5[1][1]),
        ("C5", "part overtakes pers by 8 rpn", 0.0, f5[8][1] - f5[8][0]),
        ("C6", "weak curves rise with scale (base@4096/base@64 > 1)", 1.0,
         None),
    ]
    wl = cp.fig2_workload()
    b64, _, _ = _trio(wl, 64, 32, 2)
    b4096, _, _ = _trio(wl, 4096, 32, 2)
    rows[-1] = (rows[-1][0], rows[-1][1], 1.0, b4096.total / b64.total)
    for claim, desc, paper_val, model_val in rows:
        emit(f"claims/{claim}", model_val, f"paper={paper_val} :: {desc}")
    return rows
