"""Paper-figure benchmarks (Figs. 2-5): model-projected cluster-scale sweeps.

Each function reproduces one figure of the paper using the calibrated
Quartz-class machine model (configs/comb_paper.py).  Output rows are CSV:
``name,us_per_call,derived`` where ``derived`` carries the paper-style
speedup percentages.  Per-claim comparison against the paper's quoted numbers
is appended (EXPERIMENTS.md §Paper mirrors it).
"""

from __future__ import annotations

from repro.configs import comb_paper as cp
from repro.core.model_comm import simulate, speedup


def _trio(wl, nprocs, rpn, threads, n_parts=None):
    b = simulate("standard", cp.QUARTZ, wl, nprocs=nprocs, ranks_per_node=rpn,
                 threads=threads)
    p = simulate("persistent", cp.QUARTZ, wl, nprocs=nprocs, ranks_per_node=rpn,
                 threads=threads)
    q = simulate("partitioned", cp.QUARTZ, wl, nprocs=nprocs, ranks_per_node=rpn,
                 threads=threads, n_parts=n_parts)
    return b, p, q


def fig2_weak_scaling(emit) -> dict:
    cfg = cp.FIG2_WEAK
    wl = cp.fig2_workload()
    out = {}
    for n in cfg["procs"]:
        b, p, q = _trio(wl, n, cfg["ranks_per_node"], cfg["threads"])
        emit(f"fig2/weak/std/p{n}", b.total * 1e6, "")
        emit(f"fig2/weak/pers/p{n}", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig2/weak/part/p{n}", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[n] = (speedup(b, p), speedup(b, q))
    return out


def fig3_strong_scaling(emit) -> dict:
    cfg = cp.FIG3_STRONG
    out = {}
    for n in cfg["procs"]:
        wl = cp.fig3_workload(n)
        b, p, q = _trio(wl, n, cfg["ranks_per_node"], cfg["threads"])
        face = wl.messages()[0]
        emit(f"fig3/strong/std/p{n}", b.total * 1e6, f"face_bytes={face}")
        emit(f"fig3/strong/pers/p{n}", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig3/strong/part/p{n}", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[n] = (speedup(b, p), speedup(b, q))
    return out


def fig4_message_size(emit) -> dict:
    cfg = cp.FIG4_MSG_SIZE
    out = {}
    for doubles in cfg["doubles"]:
        wl = cp.fig4_workload(doubles)
        b, p, q = _trio(wl, cfg["procs"], cfg["ranks_per_node"], cfg["threads"])
        emit(f"fig4/msgsize/std/d{doubles}", b.total * 1e6, "")
        emit(f"fig4/msgsize/pers/d{doubles}", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig4/msgsize/part/d{doubles}", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[doubles] = (speedup(b, p), speedup(b, q))
    return out


def fig5_ranks_per_node(emit) -> dict:
    cfg = cp.FIG5_RANKS_PER_NODE
    out = {}
    for rpn in cfg["ranks_per_node"]:
        n = cfg["nodes"] * rpn
        threads = cfg["threads_per_node"] // rpn
        wl = cp.fig5_workload(n)
        b, p, q = _trio(wl, n, rpn, threads)
        emit(f"fig5/rpn{rpn}/std", b.total * 1e6, f"threads={threads}")
        emit(f"fig5/rpn{rpn}/pers", p.total * 1e6,
             f"speedup={speedup(b, p):.1f}%")
        emit(f"fig5/rpn{rpn}/part", q.total * 1e6,
             f"speedup={speedup(b, q):.1f}%")
        out[rpn] = (speedup(b, p), speedup(b, q))
    return out


# paper-claim validation table (C1-C6 of DESIGN.md §1)
def claims_table(emit) -> list[tuple[str, str, float, float]]:
    f2 = fig2_weak_scaling(lambda *a: None)
    f3 = fig3_strong_scaling(lambda *a: None)
    f4 = fig4_message_size(lambda *a: None)
    f5 = fig5_ranks_per_node(lambda *a: None)
    rows = [
        ("C1", "pers>=base everywhere (weak@4096: paper 12.5%)", 12.5, f2[4096][0]),
        ("C1", "pers peak (strong@2048: paper 37%)", 37.0, f3[2048][0]),
        ("C2", "part total weak@4096 (paper 27%)", 27.0, f2[4096][1]),
        ("C2", "part peak strong@1024 (paper 68%)", 68.0, f3[1024][1]),
        ("C3", "part small-msg penalty (paper -42.2%)", -42.2, f4[768][1]),
        ("C4", "pers large-msg (paper 21%)", 21.0, f4[196608][0]),
        ("C4", "part large-msg (paper 37%)", 37.0, f4[196608][1]),
        ("C5", "part @1 rank/node worse than base (<0)", -1.0, f5[1][1]),
        ("C5", "part overtakes pers by 8 rpn", 0.0, f5[8][1] - f5[8][0]),
        ("C6", "weak curves rise with scale (base@4096/base@64 > 1)", 1.0,
         None),
    ]
    wl = cp.fig2_workload()
    b64, _, _ = _trio(wl, 64, 32, 2)
    b4096, _, _ = _trio(wl, 4096, 32, 2)
    rows[-1] = (rows[-1][0], rows[-1][1], 1.0, b4096.total / b64.total)
    for claim, desc, paper_val, model_val in rows:
        emit(f"claims/{claim}", model_val, f"paper={paper_val} :: {desc}")
    return rows
