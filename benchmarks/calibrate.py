"""Calibrate the analytic comm model against the paper's quoted datapoints.

Random-search fit of the MachineModel constants to the paper's measured
speedups (Figs. 2-5).  The resulting constants are frozen into
``repro/configs/comb_paper.py``; re-run this script to re-derive them.

    PYTHONPATH=src python -m benchmarks.calibrate [--iters N] [--seed S]

Targets are (figure, configuration, quoted speedup %).  The objective is a
weighted relative least-squares; soft targets (paper datapoints that are noisy
or internally inconsistent — see EXPERIMENTS.md §Paper) carry lower weight.
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import random

from repro.core.model_comm import MachineModel, StencilWorkload, simulate, speedup


def _trio(m, wl, nprocs, rpn=32, threads=2, n_parts=None):
    b = simulate("standard", m, wl, nprocs=nprocs, ranks_per_node=rpn, threads=threads)
    p = simulate("persistent", m, wl, nprocs=nprocs, ranks_per_node=rpn, threads=threads)
    q = simulate(
        "partitioned", m, wl, nprocs=nprocs, ranks_per_node=rpn, threads=threads,
        n_parts=n_parts,
    )
    return b, p, q


def predictions(m: MachineModel) -> dict[str, float]:
    out = {}
    # Fig 2 (weak scaling, face msgs of 524288 doubles, 32 rpn, 2 thr/core)
    wl = StencilWorkload.from_face_doubles(524288)
    b, p, q = _trio(m, wl, 4096)
    out["fig2_pers_4096"] = speedup(b, p)
    out["fig2_part_4096"] = speedup(b, q)
    # Fig 3 (strong scaling, 2048^3 mesh)
    for n in (128, 1024, 2048, 4096):
        wl = StencilWorkload.from_global_mesh((2048, 2048, 2048), n)
        b, p, q = _trio(m, wl, n)
        out[f"fig3_pers_{n}"] = speedup(b, p)
        out[f"fig3_part_{n}"] = speedup(b, q)
    # Fig 4 (message-size sweep at 4096 procs)
    for doubles in (768, 196608):
        wl = StencilWorkload.from_face_doubles(doubles)
        b, p, q = _trio(m, wl, 4096)
        out[f"fig4_pers_{doubles}"] = speedup(b, p)
        out[f"fig4_part_{doubles}"] = speedup(b, q)
    # Fig 5 (ranks-per-node sweep, 64 nodes, 64 threads/node)
    for rpn in (1, 2, 8, 32):
        n = 64 * rpn
        threads = 64 // rpn
        wl = StencilWorkload.from_global_mesh((2048, 4096, 4096), n)
        b, p, q = _trio(m, wl, n, rpn=rpn, threads=threads)
        out[f"fig5_pers_{rpn}"] = speedup(b, p)
        out[f"fig5_part_{rpn}"] = speedup(b, q)
    return out


# (key, target %, weight) — weights reflect how load-bearing each quoted
# number is for the paper's claims C1-C6 (see DESIGN.md §1).
TARGETS = [
    ("fig2_pers_4096", 12.5, 3.0),  # C1
    ("fig2_part_4096", 27.0, 3.0),  # C2 (weak)
    ("fig3_pers_128", 0.0, 0.25),  # soft: endpoint, tension with fig5 C1
    ("fig3_part_128", 12.0, 1.5),
    ("fig3_part_1024", 68.0, 1.0),  # C2 peak — soft: single-point outlier; a
    #   flat NIC-share model cannot produce 68% here and 27% in fig2 with
    #   comparable byte volumes (see EXPERIMENTS.md §Paper residuals)
    ("fig3_pers_2048", 37.0, 3.0),  # C1 peak
    ("fig3_pers_4096", 0.0, 0.25),  # soft: noisy endpoint
    ("fig3_part_4096", 4.4, 1.5),
    ("fig4_pers_768", 0.0, 1.0),  # "performed similarly to the baseline"
    ("fig4_part_768", -42.2, 3.0),  # C3: baseline 73% faster => 1/1.73-1
    ("fig4_pers_196608", 21.0, 2.5),  # C4
    ("fig4_part_196608", 37.0, 3.0),  # C4
    ("fig5_pers_1", 20.0, 1.5),  # C1: ~20% at every rpn
    ("fig5_part_1", -25.0, 2.0),  # C5: "significantly worse" at 1 rpn
    ("fig5_pers_8", 20.0, 1.5),
    ("fig5_part_8", 25.0, 1.5),  # overtakes persistent at 8 rpn
    ("fig5_pers_32", 20.0, 1.5),
    ("fig5_part_32", 30.0, 1.0),
]

# search space: (field, low, high, log?)
SPACE = [
    ("alpha", 0.5e-6, 6e-6, True),
    ("o_msg", 0.3e-6, 4e-6, True),
    ("o_persist_msg", 0.05e-6, 1e-6, True),
    ("o_part", 0.2e-6, 8e-6, True),
    ("pack_bw", 0.8e9, 6e9, True),
    ("mem_bw", 2e9, 12e9, True),
    ("contention_coef", 0.0, 0.25, False),
    ("on_node_fraction", 0.2, 0.8, False),
    ("proto_frac", 0.0, 0.6, False),
    ("rdv_rtt_factor", 0.0, 8.0, False),
    ("burst_penalty", 0.0, 0.8, False),
    ("burst_scale", 0.0, 1.2, False),
    ("tm_coef", 0.0, 0.3, False),
    ("socket_split_penalty", 1.0, 6.0, False),
    ("ht_eff", 0.05, 0.6, False),
]


def loss(m: MachineModel) -> float:
    pred = predictions(m)
    total = 0.0
    for key, target, w in TARGETS:
        scale = max(abs(target), 10.0)
        total += w * ((pred[key] - target) / scale) ** 2
    # physical-consistency constraints
    if m.o_persist_msg > m.o_msg:  # persistent posting must not cost more
        total += 10.0 * (m.o_persist_msg / m.o_msg - 1.0)
    return total


def sample(rng: random.Random, base: MachineModel) -> MachineModel:
    kw = {}
    for field, lo, hi, log in SPACE:
        if log:
            kw[field] = math.exp(rng.uniform(math.log(lo), math.log(hi)))
        else:
            kw[field] = rng.uniform(lo, hi)
    return dataclasses.replace(base, **kw)


def perturb(rng: random.Random, m: MachineModel, temp: float) -> MachineModel:
    kw = {}
    for field, lo, hi, log in SPACE:
        v = getattr(m, field)
        if log:
            v = math.exp(
                min(math.log(hi), max(math.log(lo),
                    math.log(v) + rng.gauss(0, temp * (math.log(hi) - math.log(lo)))))
            )
        else:
            v = min(hi, max(lo, v + rng.gauss(0, temp * (hi - lo))))
        kw[field] = v
    return dataclasses.replace(m, **kw)


def calibrate(iters: int = 4000, seed: int = 0, verbose: bool = True) -> MachineModel:
    rng = random.Random(seed)
    base = MachineModel()
    best, best_loss = base, loss(base)
    for i in range(iters):
        if i < iters // 2:
            cand = sample(rng, base)
        else:
            cand = perturb(rng, best, temp=0.08)
        l = loss(cand)
        if l < best_loss:
            best, best_loss = cand, l
            if verbose:
                print(f"iter {i}: loss {l:.4f}")
    return best


def report(m: MachineModel) -> None:
    pred = predictions(m)
    print("\n# key                 paper     model    |err|")
    for key, target, w in TARGETS:
        p = pred[key]
        print(f"{key:22s} {target:8.1f} {p:8.1f} {abs(p-target):8.1f}   (w={w})")
    print("\n# calibrated MachineModel fields:")
    for field, *_ in SPACE:
        v = getattr(m, field)
        print(f"    {field}={v:.6g},")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    m = calibrate(args.iters, args.seed)
    report(m)
    print(f"\nfinal loss: {loss(m):.4f}")


if __name__ == "__main__":
    main()
