"""LM-side benchmarks: real step timings on tiny configs (CPU) comparing the
paper-technique variants — persistent plan dispatch vs per-call jit, and
fused vs partitioned collectives in the distributed paths (8 fake devices,
structural check + wall time).

Emits ``name,us_per_call,derived`` CSV like the other benchmark sections.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time


def _run_inner() -> None:
    import jax
    import numpy as np

    from repro.core.compat import make_mesh

    from repro.configs import get_config
    from repro.configs.base import OptimizerConfig
    from repro.core.plan import CommPlan, PlanCache
    from repro.models import build_model, concrete_batch
    from repro.parallel.context import ParallelContext
    from repro.train.optimizer import init_opt_state
    from repro.train.train_loop import make_train_step

    mesh = make_mesh((2, 4), ("data", "model"))

    # --- train-step dispatch: persistent plan vs per-call jit path ----------
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(warmup_steps=0, total_steps=100)
    params = model.init(jax.random.key(0))
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
    batch = concrete_batch(cfg, 8, 64)
    step = make_train_step(model, opt_cfg)

    plan = CommPlan(step, example_args=(
        jax.eval_shape(lambda: state), jax.eval_shape(lambda: batch)))
    jitted = jax.jit(step)

    def time_it(fn, n=20):
        s, out = state, None
        out = fn(s, batch)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(s, batch)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e6

    t_jit = time_it(lambda s, b: jitted(s, b))
    t_plan = time_it(lambda s, b: plan.start(s, b))
    print(f"lm/train_dispatch/jit,{t_jit:.1f},")
    print(f"lm/train_dispatch/persistent_plan,{t_plan:.1f},"
          f"init_us={plan.init_seconds*1e6:.0f}")

    # --- EP MoE: fused vs partitioned all-to-all (8 devices) -----------------
    cfg_m = get_config("phi3.5-moe-42b-a6.6b").reduced()
    model_m = build_model(cfg_m)
    params_m = model_m.init(jax.random.key(1))
    batch_m = concrete_batch(cfg_m, 8, 64, seed=1)
    with jax.set_mesh(mesh):
        for parts, label in ((1, "fused"), (4, "partitioned4")):
            ctx = ParallelContext(mesh=mesh, moe_mode="ep", n_parts=parts)
            fn = jax.jit(lambda p, b, c=ctx: model_m.loss(p, b, ctx=c))
            out = fn(params_m, batch_m)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(10):
                out = fn(params_m, batch_m)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / 10 * 1e6
            print(f"lm/moe_ep_a2a/{label},{us:.1f},loss={float(out):.4f}")

    # --- ring attention: fused vs partitioned KV exchange --------------------
    cfg_d = get_config("llama3-8b").reduced()
    model_d = build_model(cfg_d)
    params_d = model_d.init(jax.random.key(2))
    batch_d = concrete_batch(cfg_d, 8, 128, seed=2)
    with jax.set_mesh(mesh):
        for parts, label in ((1, "fused"), (4, "partitioned4")):
            ctx = ParallelContext(mesh=mesh, seq_parallel=True, n_parts=parts)
            fn = jax.jit(lambda p, b, c=ctx: model_d.loss(p, b, ctx=c))
            out = fn(params_d, batch_d)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(10):
                out = fn(params_d, batch_d)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / 10 * 1e6
            print(f"lm/ring_attention/{label},{us:.1f},loss={float(out):.4f}")


def main() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.lm_bench", "--inner"],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise SystemExit(out.returncode)


if __name__ == "__main__":
    if "--serve" in sys.argv:
        # continuous-batching serve benchmark (repro.serving.bench): the
        # tokens/sec cells over the Message-routed ring-attention path;
        # forwards the remaining flags (--out/--check/--requests/...)
        from repro.serving.bench import main as serve_main

        argv = [a for a in sys.argv[1:] if a != "--serve"]
        raise SystemExit(serve_main(argv))
    if "--inner" in sys.argv:
        _run_inner()
    else:
        main()
