"""MEASURED benchmark: per-iteration overhead of the three strategies on the
real (CPU) backend, 8 fake devices.

This is the component of the paper's finding that *can* be measured in this
container: the per-iteration plan-assembly + dispatch cost that persistent
plans amortize, and the per-partition op overhead that partitioned adds.
Network transfer time does not exist here, so partitioned shows its overhead
without its overlap win — the paper's own small-message regime (claim C3).

Run standalone (spawns itself with the 8-device XLA flag when needed):
    PYTHONPATH=src python -m benchmarks.measured_dispatch
"""

from __future__ import annotations

import os
import subprocess
import sys


def _run_inner() -> None:
    import jax
    import numpy as np

    from repro.core.compat import make_mesh
    from repro.kernels.stencil27 import jacobi_weights, stencil27_ref
    from repro.stencil import Domain, comb_measure

    mesh = make_mesh((4, 2), ("pz", "py"))
    w = jacobi_weights()

    def update(xl):
        import jax.numpy as jnp

        interior_new = stencil27_ref(xl, jnp.asarray(w))
        return jax.lax.dynamic_update_slice(xl, interior_new, (1, 1, 1))

    for size, parts in ((32, 2), (64, 4)):
        dom = Domain(mesh, global_interior=(size, size, size // 2),
                     mesh_axes=("pz", "py", None))
        res = comb_measure(dom, update_fn=None, n_parts=parts, n_cycles=100,
                           repeats=3)
        base = res["standard"].us_per_cycle
        for s, r in res.items():
            sp = (base / r.us_per_cycle - 1.0) * 100.0
            print(f"measured/halo{size}/{s},{r.us_per_cycle:.1f},"
                  f"speedup={sp:.1f}%;init_us={r.init_us:.0f}")
        # exchange+compute cycles (full Comb iteration)
        res = comb_measure(dom, update_fn=update, n_parts=parts, n_cycles=30,
                           repeats=3)
        base = res["standard"].us_per_cycle
        for s, r in res.items():
            sp = (base / r.us_per_cycle - 1.0) * 100.0
            print(f"measured/cycle{size}/{s},{r.us_per_cycle:.1f},"
                  f"speedup={sp:.1f}%")


def main() -> None:
    """Always spawn a fresh interpreter so the 8-device flag precedes jax init."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.measured_dispatch", "--inner"],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise SystemExit(out.returncode)


if __name__ == "__main__":
    if "--inner" in sys.argv:
        _run_inner()
    else:
        main()
