"""Serving engine: batched+continuous decoding == sequential reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reference_generate(model, params, prompt, n_new):
    """Sequential greedy decode, batch 1, dedicated cache."""
    cache = model.init_cache(1, 128)
    batch = {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])}
    logits, cache = model.prefill(params, batch, cache)
    out = [int(np.argmax(np.asarray(logits)[0, -1]))]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(np.argmax(np.asarray(logits)[0, 0])))
    return out


def test_batched_matches_sequential(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 8, 3, 6)]
    n_new = 6

    engine = ServingEngine(model, params, max_slots=4, max_len=128)
    uids = [engine.submit(p, max_new_tokens=n_new) for p in prompts]
    results = engine.run()

    for uid, prompt in zip(uids, prompts):
        want = _reference_generate(model, params, prompt, n_new)
        assert results[uid] == want, (uid, results[uid], want)


def test_continuous_batching_more_requests_than_slots(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=4 + i).tolist()
               for i in range(5)]
    engine = ServingEngine(model, params, max_slots=2, max_len=64)
    uids = [engine.submit(p, max_new_tokens=4) for p in prompts]
    results = engine.run()
    assert set(results) == set(uids)
    for uid, prompt in zip(uids, prompts):
        want = _reference_generate(model, params, prompt, 4)
        assert results[uid] == want, uid


def test_persistent_plans_amortized(setup):
    """Decode steps after the first must hit the plan cache, not re-init."""
    cfg, model, params = setup
    engine = ServingEngine(model, params, max_slots=2, max_len=64)
    for i in range(3):
        engine.submit([1 + i, 2, 3], max_new_tokens=5)
    engine.run()
    st = engine.stats
    assert st.decode_steps >= 5
    # few inits (prefill buckets + decode signature), many cache hits
    assert st.plan_inits <= 4
    assert st.plan_hits >= st.decode_steps - 2
