"""Serving engine: batched+continuous decoding == sequential reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reference_generate(model, params, prompt, n_new):
    """Sequential greedy decode, batch 1, dedicated cache."""
    cache = model.init_cache(1, 128)
    batch = {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])}
    logits, cache = model.prefill(params, batch, cache)
    out = [int(np.argmax(np.asarray(logits)[0, -1]))]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(np.argmax(np.asarray(logits)[0, 0])))
    return out


def test_batched_matches_sequential(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 8, 3, 6)]
    n_new = 6

    engine = ServingEngine(model, params, max_slots=4, max_len=128)
    uids = [engine.submit(p, max_new_tokens=n_new) for p in prompts]
    results = engine.run()

    for uid, prompt in zip(uids, prompts):
        want = _reference_generate(model, params, prompt, n_new)
        assert results[uid] == want, (uid, results[uid], want)


def test_continuous_batching_more_requests_than_slots(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=4 + i).tolist()
               for i in range(5)]
    engine = ServingEngine(model, params, max_slots=2, max_len=64)
    uids = [engine.submit(p, max_new_tokens=4) for p in prompts]
    results = engine.run()
    assert set(results) == set(uids)
    for uid, prompt in zip(uids, prompts):
        want = _reference_generate(model, params, prompt, 4)
        assert results[uid] == want, uid


def test_exact_generation_length_and_step_count(setup):
    """max_new_tokens=N yields exactly N sampled tokens from 1 prefill +
    N-1 decode steps — no extra step whose token is silently truncated."""
    cfg, model, params = setup
    n_new = 5
    engine = ServingEngine(model, params, max_slots=1, max_len=64)
    uid = engine.submit([3, 1, 4, 1, 5], max_new_tokens=n_new)
    results = engine.run()
    assert len(results[uid]) == n_new
    assert engine.stats.prefills == 1
    assert engine.stats.decode_steps == n_new - 1
    assert engine.stats.tokens_generated == n_new - 1  # decode-sampled
    assert results[uid] == _reference_generate(model, params,
                                               [3, 1, 4, 1, 5], n_new)


def test_max_new_tokens_one_finishes_at_prefill(setup):
    """The prefill-sampled token IS the request for max_new_tokens=1: it
    must finish without ever occupying a decode slot."""
    cfg, model, params = setup
    engine = ServingEngine(model, params, max_slots=2, max_len=64)
    uids = [engine.submit([7, 8, 9], max_new_tokens=1) for _ in range(3)]
    results = engine.run()
    assert engine.stats.decode_steps == 0
    for uid in uids:
        assert len(results[uid]) == 1
    assert results[uids[0]] == _reference_generate(model, params,
                                                   [7, 8, 9], 1)


def test_single_slot_engine_really_writes_the_cache(setup):
    """max_slots=1: batch-1 and batched cache shapes coincide, which used to
    defeat _write_slot's size-1 axis search — prefill wrote NOTHING and
    decode ran against a zero cache."""
    cfg, model, params = setup
    prompt = [5, 9, 2, 6]
    engine = ServingEngine(model, params, max_slots=1, max_len=64)
    uid = engine.submit(prompt, max_new_tokens=6)
    results = engine.run()
    assert results[uid] == _reference_generate(model, params, prompt, 6)


def test_short_after_long_slot_reuse_matches_isolated(setup):
    """Continuous-batching regression: a short prompt recycled into the slot
    a longer request just vacated must decode at ITS OWN positions — the
    same tokens as serving the short request alone."""
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    long_prompt = rng.integers(0, cfg.vocab_size, size=24).tolist()
    short_prompt = rng.integers(0, cfg.vocab_size, size=3).tolist()

    engine = ServingEngine(model, params, max_slots=1, max_len=64)
    uid_long = engine.submit(long_prompt, max_new_tokens=4)
    uid_short = engine.submit(short_prompt, max_new_tokens=6)
    results = engine.run()

    alone = ServingEngine(model, params, max_slots=1, max_len=64)
    uid_alone = alone.submit(short_prompt, max_new_tokens=6)
    want = alone.run()[uid_alone]
    assert results[uid_short] == want
    assert want == _reference_generate(model, params, short_prompt, 6)
    assert results[uid_long] == _reference_generate(model, params,
                                                    long_prompt, 4)


def test_bucketed_prefill_plan_inits_flat_across_lengths(setup):
    """Dense prompts pad to power-of-two buckets with the true length as a
    traced argument: every length in a bucket shares ONE prefill plan."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    # lengths 3..8 all land in the 8-bucket
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (3, 5, 6, 8)]
    engine = ServingEngine(model, params, max_slots=1, max_len=64)
    uids = [engine.submit(p, max_new_tokens=3) for p in prompts]
    results = engine.run()
    # one bucketed prefill plan + one decode plan, regardless of lengths
    assert engine.stats.prefills == len(prompts)
    assert engine.stats.plan_inits == 2, engine.plans.stats
    for uid, p in zip(uids, prompts):
        assert results[uid] == _reference_generate(model, params, p, 3)


def test_persistent_plans_amortized(setup):
    """Decode steps after the first must hit the plan cache, not re-init."""
    cfg, model, params = setup
    engine = ServingEngine(model, params, max_slots=2, max_len=64)
    for i in range(3):
        engine.submit([1 + i, 2, 3], max_new_tokens=5)
    engine.run()
    st = engine.stats
    assert st.decode_steps >= 5
    # few inits (prefill buckets + decode signature), many cache hits
    assert st.plan_inits <= 4
    assert st.plan_hits >= st.decode_steps - 2
