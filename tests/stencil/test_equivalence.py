"""Property-based strategy-equivalence harness — the correctness oracle.

Every registered exchange strategy must produce the *exact* ghosted array a
single-device reference roll predicts: for a periodic Cartesian domain, the
post-exchange stored layout is a pure gather of the global interior with
wrap-around indexing (``np.take(..., mode via %)`` per decomposed axis — the
tensor product of per-axis rolls covers faces, edges, and corners).  Ghost
values are only ever *copied*, never combined, so the assertion is full-array
bitwise equality — a far stronger oracle than the historical mean-checksum
agreement check in ``comb_measure``.

The property draws (ndim, domain shape, halo width, n_parts, strategy,
packer, coalesce mode) through :mod:`repro.testing` (real hypothesis when installed, the
deterministic seeded fallback otherwise); a deterministic parametrized pass
guarantees every registered strategy is exercised on 1-D/2-D/3-D under BOTH
exact transport-layer packers (``slice`` inline staging and the ``pallas``
copy kernel, which falls back to its jnp oracle on CPU — so this full
matrix is CI-runnable on the 8 virtual devices) regardless of what the
random draws hit; a second parametrized pass extends the matrix to the
wire-compressed packers (``bf16``, ``scaled-int8``), asserted against the
same oracle but within each packer's documented ``wire_tolerance``.
"""

import zlib

import jax
import numpy as np
import pytest

from repro.core.compat import make_mesh
from repro.stencil.domain import Domain, reference_exchange
from repro.stencil.strategies import (
    StrategyConfig,
    available_strategies,
    make_driver,
)
from repro.testing import given, settings, st

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 virtual devices (conftest)"
)

#: mesh shapes per ndim; the first ``len(shape)`` array axes are decomposed.
MESH_CHOICES = {
    1: ((2,), (4,), (8,)),
    2: ((4,), (2, 2), (4, 2)),
    3: ((8,), (2, 2), (2, 2, 2)),
}
AXIS_NAMES = ("px", "py", "pz")


# the single-device reference roll now lives with the domain layer
# (repro.stencil.domain.reference_exchange) so the multi-process check
# program holds real cross-process exchanges to the SAME oracle.


def _build_domain(ndim, mesh_idx, halo, extents):
    shape = MESH_CHOICES[ndim][mesh_idx % len(MESH_CHOICES[ndim])]
    mesh = make_mesh(
        shape, AXIS_NAMES[: len(shape)],
        devices=jax.devices()[: int(np.prod(shape))],
    )
    interior, axes = [], []
    for a in range(ndim):
        if a < len(shape):  # decomposed: local interior = halo * multiplier
            interior.append(halo * extents[a] * shape[a])
            axes.append(AXIS_NAMES[a])
        else:  # undecomposed: any extent >= 3 keeps the oracle interesting
            interior.append(extents[a] + 2)
            axes.append(None)
    return Domain(
        mesh, global_interior=tuple(interior), mesh_axes=tuple(axes),
        halo=halo,
    )


PACKERS = ("slice", "pallas")


def _assert_strategy_matches_reference(
    domain, strategy, n_parts, seed, packer="slice", coalesce=True
):
    """Exact packers: bitwise.  Wire-compressed packers: the packer's own
    documented ``wire_tolerance`` — tolerance-aware, never looser."""
    from repro.core.transport import get_packer

    rng = np.random.default_rng(seed)
    interior = rng.normal(size=domain.global_interior).astype(domain.dtype)
    want = reference_exchange(domain, interior)
    drv = make_driver(
        StrategyConfig(name=strategy, n_parts=n_parts, packer=packer,
                       coalesce=coalesce),
        domain.mesh, domain.halo_spec, ndim=len(domain.global_interior),
    )
    try:
        got = np.asarray(drv.wait(drv.step(
            domain.from_global_interior(interior)
        )))
    finally:
        drv.free()
    err_msg = (f"{strategy} n_parts={n_parts} packer={packer} "
               f"coalesce={coalesce} halo={domain.halo} "
               f"interior={domain.global_interior} "
               f"mesh={dict(domain.mesh.shape)}")
    rtol, atol = get_packer(packer).wire_tolerance(domain.dtype)
    if rtol == 0.0 and atol == 0.0:
        np.testing.assert_array_equal(got, want, err_msg=err_msg)
    else:
        np.testing.assert_allclose(got, want, rtol=rtol, atol=atol,
                                   err_msg=err_msg)


@settings(max_examples=12, deadline=None)
@given(
    ndim=st.integers(1, 3),
    mesh_idx=st.integers(0, 2),
    halo=st.integers(1, 2),
    e0=st.integers(1, 3),
    e1=st.integers(1, 3),
    e2=st.integers(1, 3),
    n_parts=st.integers(1, 6),
    strategy=st.sampled_from(available_strategies()),
    packer=st.sampled_from(PACKERS),
    coalesce=st.sampled_from((True, False)),
)
def test_any_strategy_matches_reference_roll(
    ndim, mesh_idx, halo, e0, e1, e2, n_parts, strategy, packer, coalesce
):
    domain = _build_domain(ndim, mesh_idx, halo, (e0, e1, e2))
    # stable across processes (hash() of a str varies with PYTHONHASHSEED,
    # which would make a CI failure irreproducible locally)
    seed = zlib.crc32(
        repr((ndim, mesh_idx, halo, e0, e1, e2, n_parts, strategy)).encode()
    )
    _assert_strategy_matches_reference(domain, strategy, n_parts, seed,
                                       packer, coalesce)


# deterministic floor: every registered strategy, every dimensionality,
# all 8 virtual devices — independent of what the property draws sample.
GRID = [
    pytest.param(1, (8,), (24,), 2, id="1d-8dev-halo2"),
    pytest.param(2, (4, 2), (16, 8), 1, id="2d-4x2"),
    pytest.param(3, (2, 2, 2), (8, 6, 4), 1, id="3d-2x2x2"),
]


@pytest.mark.parametrize("packer", PACKERS)
@pytest.mark.parametrize("strategy", available_strategies())
@pytest.mark.parametrize("ndim,shape,interior,halo", GRID)
def test_every_strategy_on_8_devices(strategy, packer, ndim, shape, interior,
                                     halo):
    """Acceptance: the full strategy x packer matrix against the oracle."""
    mesh = make_mesh(
        shape, AXIS_NAMES[: len(shape)],
        devices=jax.devices()[: int(np.prod(shape))],
    )
    domain = Domain(
        mesh, global_interior=interior,
        mesh_axes=AXIS_NAMES[: len(shape)] + (None,) * (ndim - len(shape)),
        halo=halo,
    )
    _assert_strategy_matches_reference(
        domain, strategy, n_parts=3, seed=7, packer=packer
    )


@pytest.mark.parametrize("strategy", available_strategies())
def test_every_strategy_uncoalesced_on_8_devices(strategy):
    """The coalesce-off baseline path stays held to the same oracle: every
    strategy, 3-D corners included, per-message delivery (the default-on
    coalesced path is what the matrix above exercises)."""
    mesh = make_mesh((2, 2, 2), AXIS_NAMES, devices=jax.devices()[:8])
    domain = Domain(mesh, global_interior=(8, 6, 4), mesh_axes=AXIS_NAMES)
    _assert_strategy_matches_reference(
        domain, strategy, n_parts=3, seed=5, coalesce=False
    )


#: the wire-compressed packers, asserted via their documented tolerances
LOSSY_PACKERS = ("bf16", "scaled-int8")


@pytest.mark.parametrize("packer", LOSSY_PACKERS)
@pytest.mark.parametrize("strategy", available_strategies())
def test_every_strategy_under_compressed_packers(strategy, packer):
    """The oracle matrix extended to the wire-compressed packers: every
    strategy's ghosts stay within the packer's wire_tolerance of the
    bitwise reference (2-D, two decomposed axes — edges included)."""
    mesh = make_mesh((4, 2), ("px", "py"), devices=jax.devices()[:8])
    domain = Domain(mesh, global_interior=(16, 8), mesh_axes=("px", "py"))
    _assert_strategy_matches_reference(
        domain, strategy, n_parts=3, seed=11, packer=packer
    )


def test_reference_roll_is_self_consistent():
    """The oracle itself: stored shape, interior roundtrip, ghost contents."""
    mesh = make_mesh((4, 2), ("px", "py"), devices=jax.devices()[:8])
    domain = Domain(mesh, global_interior=(8, 6), mesh_axes=("px", "py"))
    interior = np.arange(48, dtype=np.float32).reshape(8, 6)
    stored = reference_exchange(domain, interior)
    assert stored.shape == domain.stored_global
    # stripping the ghosts recovers the global interior exactly
    np.testing.assert_array_equal(domain.to_global_interior(stored), interior)
    # shard i's one-wide left ghost along axis 0 holds the wrapped previous
    # global row; spot-check every shard row against the wrap rule
    c0, blk = 8 // 4, 8 // 4 + 2  # chunk + ghosted block extent (halo=1)
    for i in range(4):
        ghost_cols = reference_exchange(
            domain, interior
        )[i * blk]  # shard i's left ghost row (still column-ghosted)
        want = interior[(i * c0 - 1) % 8]
        # compare at interior columns of the first column shard
        np.testing.assert_array_equal(ghost_cols[1:4], want[0:3])
