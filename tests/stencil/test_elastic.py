"""Chaos tests: elastic stencil re-planning under injected failures.

Failures are injected at the adversarial points the partitioned-
communication literature warns about — mid-exchange (dispatch in flight),
between pipelined partition rounds, and inside a plan build — and every
resumed run is held to the single-device oracle bitwise (exact packers).
The heavier 2-process form (a real grid killed mid-run and relaunched on
the survivor topology) lives in
tests/distributed_progs/check_elastic_stencil.py (slow lane).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.launch.elastic import (
    ElasticConfig,
    ElasticStencilRunner,
    initial_interior,
)
from repro.train.fault_tolerance import FailureInjector, SimulatedFailure

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >=4 virtual devices (conftest)"
)

CFG = ElasticConfig(global_interior=(16, 8), n_steps=6)


def _oracle(cfg: ElasticConfig) -> np.ndarray:
    """The single-device reference trajectory (no chaos, no checkpoints)."""
    return ElasticStencilRunner(
        dataclasses.replace(cfg, checkpoint_every=0), None,
        devices=jax.devices()[:1],
    ).run().final_interior


def test_mid_exchange_failure_resumes_bitwise(tmp_path):
    """Rank loss mid-exchange: 4 devices -> 2 survivors, plans invalidated,
    tables re-derived, state restored — final interior bitwise == oracle."""
    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(3,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1
    assert [e.cause for e in result.events] == ["initial", "rank-loss"]
    assert result.events[0].n_devices == 4
    assert result.events[1].n_devices == 2
    # the dead topology's one persistent plan was dropped and counted
    assert result.events[1].plan_invalidations == 1
    assert runner.cache.stats.invalidations == 1
    np.testing.assert_array_equal(result.final_interior, _oracle(CFG))


def test_resumed_run_matches_reference_exchange_oracle(tmp_path):
    """The acceptance oracle, stated through ``reference_exchange``: the
    post-failure stored layout (ghosts included) the resumed topology
    would exchange to equals the single-device reference roll of the
    oracle's final interior."""
    from repro.core.compat import make_mesh
    from repro.stencil.domain import Domain, reference_exchange

    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(2,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    oracle_interior = _oracle(CFG)
    # dense prediction of the survivors' exchanged stored layout
    mesh = make_mesh((2,), ("px",), devices=jax.devices()[:2])
    dom = Domain(mesh, global_interior=CFG.global_interior,
                 mesh_axes=("px", None), halo=CFG.halo)
    np.testing.assert_array_equal(
        reference_exchange(dom, result.final_interior),
        reference_exchange(dom, oracle_interior),
    )


@pytest.mark.parametrize("phase", ["plan-build:group", "plan-build:round"])
def test_plan_build_abort_leaves_cache_clean(tmp_path, phase):
    """A failure DURING plan assembly (at a delivery-group entry, or
    between pipelined partition rounds) aborts the build mid-trace; the
    cache must stay unpoisoned — only the survivors' successful build ever
    lands — and the resumed run still matches the oracle bitwise."""
    cfg = dataclasses.replace(CFG, strategy="partitioned", n_parts=3)
    runner = ElasticStencilRunner(
        cfg, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(0,), phases=(phase,)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1
    # the aborted build never reached the cache: nothing to invalidate,
    # exactly one (successful) init total
    assert result.events[-1].plan_invalidations == 0
    assert runner.cache.stats.inits == 1
    assert runner.cache.stats.invalidations == 0
    np.testing.assert_array_equal(result.final_interior, _oracle(cfg))


def test_resume_uses_committed_checkpoint(tmp_path):
    """With sparse checkpointing the runner resumes from the last COMMITTED
    step (structure-free restore) and replays forward — still bitwise."""
    cfg = dataclasses.replace(CFG, checkpoint_every=2)
    runner = ElasticStencilRunner(
        cfg, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(5,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1
    # failure at step 5: last committed checkpoint was step 4
    assert result.events[1].step == 4
    np.testing.assert_array_equal(result.final_interior, _oracle(cfg))


def test_failure_without_checkpoint_restarts_from_initial(tmp_path):
    """No checkpoint committed yet (failure at step 0): the survivors
    restart from the deterministic initial condition."""
    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(0,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1 and result.events[1].step == 0
    np.testing.assert_array_equal(result.final_interior, _oracle(CFG))


def test_replan_is_deterministic_and_cheap(tmp_path):
    """The amortized-setup argument under elasticity: re-deriving the
    static tables (replan_us) must be far below the recompile (init_us)
    every topology change also pays.  Determinism of the derivation is
    asserted inside the runner on every plan; here the recorded metrics
    are checked."""
    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(3,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    for event in result.events:
        assert event.replan_us > 0.0
        assert event.init_us > 0.0
        assert event.replan_us < event.init_us, (
            "static re-planning should be cheap relative to the compile"
        )


def test_compressed_packer_resume_is_deterministic(tmp_path):
    """Wire-compressed resume is *tolerance-aware*, not bitwise: lossy
    packers compress only wire-crossed ghosts, and the set of block
    boundaries depends on the topology, so decompositions legitimately
    drift within the packer's documented wire tolerance (scaled by steps).
    What must still hold exactly is replay-determinism: the same chaos
    run executed twice is bit-for-bit identical."""
    from repro.core.transport import get_packer

    cfg = dataclasses.replace(CFG, packer="bf16", n_steps=4)

    def chaos_run(ckpt):
        return ElasticStencilRunner(
            cfg, str(ckpt),
            injector=FailureInjector(fail_at_steps=(2,),
                                     phases=("mid-exchange",)),
            devices=jax.devices()[:4],
        ).run().final_interior

    final = chaos_run(tmp_path / "a")
    np.testing.assert_array_equal(final, chaos_run(tmp_path / "b"))
    exact = _oracle(dataclasses.replace(cfg, packer="slice"))
    rtol, atol = get_packer("bf16").wire_tolerance(np.float32)
    # cancellation near zero-crossings converts relative wire error into
    # absolute error at field scale, so the atol floor is scale-aware
    scale = float(np.abs(exact).max())
    np.testing.assert_allclose(
        final, exact,
        rtol=cfg.n_steps * rtol,
        atol=cfg.n_steps * max(atol, rtol * scale),
    )


def test_max_replans_exhausted_propagates(tmp_path):
    """Past the chaos budget the failure propagates (the grid-mode
    contract: max_replans=0 lets a real rank death kill the process)."""
    runner = ElasticStencilRunner(
        dataclasses.replace(CFG, max_replans=0), str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(1,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    with pytest.raises(SimulatedFailure):
        runner.run()
    # the checkpoint committed before death is what a relaunch resumes from
    assert runner.checkpoint_step == 1


def test_initial_interior_is_deterministic():
    np.testing.assert_array_equal(initial_interior(CFG),
                                  initial_interior(CFG))
    assert initial_interior(CFG).dtype == np.float32
