"""Chaos tests: elastic stencil re-planning under injected failures.

Failures are injected at the adversarial points the partitioned-
communication literature warns about — mid-exchange (dispatch in flight),
between pipelined partition rounds, and inside a plan build — and every
resumed run is held to the single-device oracle bitwise (exact packers).
The heavier 2-process form (a real grid killed mid-run and relaunched on
the survivor topology) lives in
tests/distributed_progs/check_elastic_stencil.py (slow lane).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.launch.elastic import (
    ElasticConfig,
    ElasticStencilRunner,
    initial_interior,
)
from repro.train.fault_tolerance import FailureInjector, SimulatedFailure

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >=4 virtual devices (conftest)"
)

CFG = ElasticConfig(global_interior=(16, 8), n_steps=6)


def _oracle(cfg: ElasticConfig) -> np.ndarray:
    """The single-device reference trajectory (no chaos, no checkpoints)."""
    return ElasticStencilRunner(
        dataclasses.replace(cfg, checkpoint_every=0), None,
        devices=jax.devices()[:1],
    ).run().final_interior


def test_mid_exchange_failure_resumes_bitwise(tmp_path):
    """Rank loss mid-exchange: 4 devices -> 2 survivors, plans invalidated,
    tables re-derived, state restored — final interior bitwise == oracle."""
    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(3,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1
    assert [e.cause for e in result.events] == ["initial", "rank-loss"]
    assert result.events[0].n_devices == 4
    assert result.events[1].n_devices == 2
    # the dead topology's one persistent plan was dropped and counted
    assert result.events[1].plan_invalidations == 1
    assert runner.cache.stats.invalidations == 1
    np.testing.assert_array_equal(result.final_interior, _oracle(CFG))


def test_resumed_run_matches_reference_exchange_oracle(tmp_path):
    """The acceptance oracle, stated through ``reference_exchange``: the
    post-failure stored layout (ghosts included) the resumed topology
    would exchange to equals the single-device reference roll of the
    oracle's final interior."""
    from repro.core.compat import make_mesh
    from repro.stencil.domain import Domain, reference_exchange

    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(2,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    oracle_interior = _oracle(CFG)
    # dense prediction of the survivors' exchanged stored layout
    mesh = make_mesh((2,), ("px",), devices=jax.devices()[:2])
    dom = Domain(mesh, global_interior=CFG.global_interior,
                 mesh_axes=("px", None), halo=CFG.halo)
    np.testing.assert_array_equal(
        reference_exchange(dom, result.final_interior),
        reference_exchange(dom, oracle_interior),
    )


@pytest.mark.parametrize("phase", ["plan-build:group", "plan-build:round"])
def test_plan_build_abort_leaves_cache_clean(tmp_path, phase):
    """A failure DURING plan assembly (at a delivery-group entry, or
    between pipelined partition rounds) aborts the build mid-trace; the
    cache must stay unpoisoned — only the survivors' successful build ever
    lands — and the resumed run still matches the oracle bitwise."""
    cfg = dataclasses.replace(CFG, strategy="partitioned", n_parts=3)
    runner = ElasticStencilRunner(
        cfg, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(0,), phases=(phase,)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1
    # the aborted build never reached the cache: nothing to invalidate,
    # exactly one (successful) init total
    assert result.events[-1].plan_invalidations == 0
    assert runner.cache.stats.inits == 1
    assert runner.cache.stats.invalidations == 0
    np.testing.assert_array_equal(result.final_interior, _oracle(cfg))


def test_resume_uses_committed_checkpoint(tmp_path):
    """With sparse checkpointing the runner resumes from the last COMMITTED
    step (structure-free restore) and replays forward — still bitwise."""
    cfg = dataclasses.replace(CFG, checkpoint_every=2)
    runner = ElasticStencilRunner(
        cfg, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(5,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1
    # failure at step 5: last committed checkpoint was step 4
    assert result.events[1].step == 4
    np.testing.assert_array_equal(result.final_interior, _oracle(cfg))


def test_failure_without_checkpoint_restarts_from_initial(tmp_path):
    """No checkpoint committed yet (failure at step 0): the survivors
    restart from the deterministic initial condition."""
    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(0,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    assert result.replans == 1 and result.events[1].step == 0
    np.testing.assert_array_equal(result.final_interior, _oracle(CFG))


def test_replan_is_deterministic_and_cheap(tmp_path):
    """The amortized-setup argument under elasticity: re-deriving the
    static tables (replan_us) must be far below the recompile (init_us)
    every topology change also pays.  Determinism of the derivation is
    asserted inside the runner on every plan; here the recorded metrics
    are checked."""
    runner = ElasticStencilRunner(
        CFG, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(3,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    result = runner.run()
    for event in result.events:
        assert event.replan_us > 0.0
        assert event.init_us > 0.0
        assert event.replan_us < event.init_us, (
            "static re-planning should be cheap relative to the compile"
        )


def test_compressed_packer_resume_is_deterministic(tmp_path):
    """Wire-compressed resume is *tolerance-aware*, not bitwise: lossy
    packers compress only wire-crossed ghosts, and the set of block
    boundaries depends on the topology, so decompositions legitimately
    drift within the packer's documented wire tolerance (scaled by steps).
    What must still hold exactly is replay-determinism: the same chaos
    run executed twice is bit-for-bit identical."""
    from repro.core.transport import get_packer

    cfg = dataclasses.replace(CFG, packer="bf16", n_steps=4)

    def chaos_run(ckpt):
        return ElasticStencilRunner(
            cfg, str(ckpt),
            injector=FailureInjector(fail_at_steps=(2,),
                                     phases=("mid-exchange",)),
            devices=jax.devices()[:4],
        ).run().final_interior

    final = chaos_run(tmp_path / "a")
    np.testing.assert_array_equal(final, chaos_run(tmp_path / "b"))
    exact = _oracle(dataclasses.replace(cfg, packer="slice"))
    rtol, atol = get_packer("bf16").wire_tolerance(np.float32)
    # cancellation near zero-crossings converts relative wire error into
    # absolute error at field scale, so the atol floor is scale-aware
    scale = float(np.abs(exact).max())
    np.testing.assert_allclose(
        final, exact,
        rtol=cfg.n_steps * rtol,
        atol=cfg.n_steps * max(atol, rtol * scale),
    )


def test_max_replans_exhausted_propagates(tmp_path):
    """Past the chaos budget the failure propagates (the grid-mode
    contract: max_replans=0 lets a real rank death kill the process)."""
    runner = ElasticStencilRunner(
        dataclasses.replace(CFG, max_replans=0), str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(1,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    with pytest.raises(SimulatedFailure):
        runner.run()
    # the checkpoint committed before death is what a relaunch resumes from
    assert runner.checkpoint_step == 1


def test_initial_interior_is_deterministic():
    np.testing.assert_array_equal(initial_interior(CFG),
                                  initial_interior(CFG))
    assert initial_interior(CFG).dtype == np.float32


# ---------------------------------------------------------------------------
# phase 2: in-grid recovery, JOIN, coordinator fallback, stragglers
# ---------------------------------------------------------------------------


def _prewarm_unrelated_plan(cache):
    """Park an epoch-FREE persistent plan for an unrelated geometry in the
    runner's cache — the warmth probe: in-grid recovery must leave it
    resident (a relaunch would drop it with everything else)."""
    from repro.core.compat import make_mesh
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import StrategyConfig, make_driver

    mesh = make_mesh((2,), ("px",), devices=jax.devices()[:2])
    dom = Domain(mesh, global_interior=(8, 4), mesh_axes=("px", None),
                 halo=1)
    drv = make_driver(
        StrategyConfig(name="persistent", plan_cache=cache),
        mesh, dom.halo_spec, ndim=2,
    )
    drv.init(jax.ShapeDtypeStruct(dom.stored_global, np.dtype(dom.dtype),
                                  sharding=dom.sharding()))
    drv.free()  # drops the reference; the plan stays resident in the cache
    return set(cache.keys())


def test_in_grid_recovery_keeps_survivors_warm(tmp_path):
    """The phase-2 acceptance test: a mid-exchange loss under
    ``recovery_mode="in-grid"`` shrinks 4 -> 2 WITHOUT relaunching —
    survivors keep their processes and their plan cache.  Only the dead
    topology's epoch-stamped plan is invalidated; the unrelated pre-warmed
    plan stays resident, the init counter keeps growing (never resets),
    and the resumed trajectory is still bitwise == oracle."""
    cfg = dataclasses.replace(CFG, recovery_mode="in-grid")
    runner = ElasticStencilRunner(
        cfg, str(tmp_path / "ckpt"),
        injector=FailureInjector(fail_at_steps=(3,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
    )
    warm_keys = _prewarm_unrelated_plan(runner.cache)
    inits_before = runner.cache.stats.inits
    assert inits_before == 1
    result = runner.run()
    assert result.recovery_mode == "in-grid"
    assert [e.cause for e in result.events] == ["initial", "loss-ingrid"]
    assert (result.events[0].n_devices, result.events[1].n_devices) == (4, 2)
    # the loss bumped the membership epoch and the new plan carries it
    assert result.final_epoch == 1 and result.events[1].epoch == 1
    assert result.warm_ranks == 2
    # surgical invalidation: ONLY the dead topology's epoch-0 plan dropped
    assert result.events[1].plan_invalidations == 1
    assert result.plan_cache_invalidations == 1
    assert warm_keys <= set(runner.cache.keys())
    # warmth: inits stayed monotone across the loss — nobody went cold
    assert result.plan_cache_inits == inits_before + 2
    np.testing.assert_array_equal(result.final_interior, _oracle(CFG))


def test_join_grows_mesh_and_moves_live_state():
    """A JOIN at step 3 grows 2 -> 4 devices mid-run with NO checkpoint
    anywhere (``ckpt_dir=None``, ``checkpoint_every=0``): bitwise equality
    to the oracle proves the grown topology computed on the survivors'
    LIVE iterate, moved through ``reshard_state`` — there was nothing on
    disk to restore."""
    cfg = dataclasses.replace(CFG, checkpoint_every=0,
                              recovery_mode="in-grid")
    runner = ElasticStencilRunner(
        cfg, None, devices=jax.devices()[:2],
        joins=[(3, jax.devices()[2:4])],
    )
    result = runner.run()
    assert result.replans == 0  # a JOIN is growth, not failure recovery
    assert [e.cause for e in result.events] == ["initial", "join"]
    assert (result.events[0].n_devices, result.events[1].n_devices) == (2, 4)
    # two joining devices = two registrations = two "join" epoch bumps
    assert result.final_epoch == 2 and result.events[1].epoch == 2
    assert result.warm_ranks == 2  # the founding members never went cold
    assert result.join_us > 0.0
    assert result.checkpoint_step is None  # nothing was ever saved
    rec = result.bench_record()
    assert rec["join_us"] == result.join_us
    assert rec["recovery_mode"] == "in-grid"
    assert rec["warm_ranks"] == 2 and rec["final_epoch"] == 2
    np.testing.assert_array_equal(result.final_interior, _oracle(cfg))


def test_coordinator_death_falls_back_to_relaunch(tmp_path):
    """Heartbeats against a dead coordinator surface ``CoordinatorLost``;
    in-grid recovery is impossible, so the runner takes the PR 6 path —
    full invalidation, everyone cold — and re-forms membership under a
    successor whose epoch starts past every old stamp."""
    cfg = dataclasses.replace(CFG, recovery_mode="in-grid")
    runner = ElasticStencilRunner(
        cfg, str(tmp_path / "ckpt"),
        devices=jax.devices()[:4], fail_coordinator_at=2,
    )
    result = runner.run()
    assert [e.cause for e in result.events] == ["initial",
                                                "coordinator-lost"]
    assert result.warm_ranks == 0  # relaunch semantics: everyone cold
    assert result.final_epoch == 1 and result.events[1].epoch == 1
    assert result.plan_cache_invalidations == 1  # full invalidate
    # the successor coordinator is live and sealed at the bumped epoch
    assert runner.membership.alive
    assert runner.membership.view.epoch == 1
    np.testing.assert_array_equal(result.final_interior, _oracle(CFG))


def test_straggler_monitor_wired_into_runner():
    """Satellite: the dormant StragglerMonitor now rides the step loop.
    factor=0.0 deterministically flags every post-first step; factor=1e9
    flags none — and the flags land in ElasticResult + the BENCH row."""
    from repro.train.fault_tolerance import StragglerMonitor

    cfg = dataclasses.replace(CFG, checkpoint_every=0)
    eager = StragglerMonitor(factor=0.0)
    result = ElasticStencilRunner(
        cfg, None, devices=jax.devices()[:2], straggler=eager,
    ).run()
    assert [s for s, _, _ in result.straggler_flags] == list(
        range(1, cfg.n_steps))
    assert result.bench_record()["straggler_flags"] == [
        list(f) for f in result.straggler_flags]
    lax = StragglerMonitor(factor=1e9)
    result2 = ElasticStencilRunner(
        cfg, None, devices=jax.devices()[:2], straggler=lax,
    ).run()
    assert result2.straggler_flags == []
