"""The §VI sweep subsystem: record schema, speedup bookkeeping, BENCH json.

The in-process tests run the grid on this pytest process's virtual devices;
one ``slow``-marked test exercises the real subprocess fan-out over the
device-count axis (the paper's process-count sweep).
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.stencil.sweep import (
    RECORD_KEYS,
    SweepConfig,
    read_bench_json,
    run_sweep,
    summarize,
    sweep_cells,
    write_bench_json,
)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >=4 virtual devices (conftest)"
)

SMALL = SweepConfig(
    device_counts=(4,), part_counts=(1, 3), sizes=((16, 8),),
    n_cycles=3, repeats=1,
)


def _expected_cells(cfg: SweepConfig) -> int:
    """Partitioning strategies get one record per (partition count, packer,
    coalesce mode, mapping); the partition-count axis does not apply to the
    others (one record per packer x coalesce mode x mapping each).  The
    autotuned cell is ONE per mapping — the tuner owns the strategy /
    packer / coalesce / partition axes, so the static grid does not
    multiply it."""
    from repro.stencil.strategies import get_strategy

    static = [s for s in cfg.strategies if s != "auto"]
    return len(cfg.mappings) * (
        len(cfg.packers) * len(cfg.coalesce_modes) * sum(
            len(cfg.part_counts) if get_strategy(s).uses_partitions else 1
            for s in static
        )
        + ("auto" in cfg.strategies)
    )


@pytest.fixture(scope="module")
def records():
    return sweep_cells(SMALL, n_devices=4)


def test_default_grid_sweeps_all_five_strategies():
    assert SweepConfig().strategies == (
        "standard", "persistent", "partitioned", "fused", "overlap",
    )


def test_record_schema(records):
    assert len(records) == _expected_cells(SMALL)
    for rec in records:
        for key in RECORD_KEYS:
            assert key in rec, f"record missing {key}: {sorted(rec)}"
        assert rec["bench"] == "stencil_sweep"
        assert rec["strategy"] in SMALL.strategies
        assert rec["n_devices"] == 4
        assert rec["us_per_cycle"] > 0
        assert rec["message_bytes"] > 0
        json.dumps(rec)  # every record must be json-serializable as-is


def test_init_only_charged_to_non_standard(records):
    for rec in records:
        if rec["strategy"] == "standard":
            assert rec["init_us"] == 0.0
        else:
            assert rec["init_us"] > 0.0  # trace+lower+compile was timed


def test_speedup_vs_baseline_per_cell(records):
    for rec in records:
        if (rec["strategy"] == "standard" and rec["packer"] == "slice"
                and rec["coalesce"] is SMALL.coalesce_modes[0]):
            # the one denominator: the first-packer first-mode standard run
            assert rec["speedup_vs_baseline"] == pytest.approx(1.0)
        else:
            assert rec["speedup_vs_baseline"] > 0.0


def test_no_duplicate_coordinates(records):
    """Non-partitioned strategies must not be re-measured per partition cell
    — every (strategy, n_parts, packer, coalesce, size, devices) coordinate
    appears once."""
    coords = [
        (r["strategy"], r["n_parts"], r["packer"], r["coalesce"],
         tuple(r["global_interior"]), r["n_devices"])
        for r in records
    ]
    assert len(coords) == len(set(coords)), coords


def test_partition_axis_swept(records):
    parts = {r["n_parts"] for r in records if r["strategy"] == "partitioned"}
    assert parts == set(SMALL.part_counts)
    # non-partitioned strategies never report a partition count
    assert {r["n_parts"] for r in records if r["strategy"] != "partitioned"} == {1}


def test_new_overlap_strategies_in_sweep_output(records):
    """Acceptance: fused and overlap appear with finite speedups, once per
    (packer, coalesce mode)."""
    for strategy in ("fused", "overlap"):
        rows = [r for r in records if r["strategy"] == strategy]
        assert len(rows) == len(SMALL.packers) * len(SMALL.coalesce_modes), (
            strategy
        )
        assert {r["packer"] for r in rows} == set(SMALL.packers)
        assert {r["coalesce"] for r in rows} == set(SMALL.coalesce_modes)
        for row in rows:
            sp = row["speedup_vs_baseline"]
            assert np.isfinite(sp) and sp > 0, (strategy, sp)


def test_packer_axis_swept(records):
    """Acceptance: every cell exists under BOTH packers, with the transport
    backend recorded."""
    assert {r["packer"] for r in records} == {"slice", "pallas"}
    assert {r["transport"] for r in records} == {"ppermute"}
    by_packer = {}
    for r in records:
        by_packer.setdefault(r["packer"], set()).add(
            (r["strategy"], r["n_parts"])
        )
    assert by_packer["slice"] == by_packer["pallas"]


def test_checksums_agree_within_each_cell(records):
    by_cell = {}
    for rec in records:
        key = (rec["n_devices"], tuple(rec["global_interior"]))
        by_cell.setdefault(key, []).append(rec["checksum"])
    for key, sums in by_cell.items():
        assert np.allclose(sums, sums[0], rtol=1e-3, atol=1e-3), (key, sums)


def test_message_size_tracks_domain(records):
    # (16, 8) interior over 4 devices, halo 1, f32: face = 1 * 8 * 4 bytes
    assert all(r["message_bytes"] == 8 * 4 for r in records)


def test_write_bench_json_roundtrip(tmp_path, records):
    path = tmp_path / "BENCH_stencil_sweep.json"
    write_bench_json(records, str(path))
    loaded = json.loads(path.read_text())
    assert loaded == records
    with pytest.raises(AssertionError):
        write_bench_json(records, str(tmp_path / "sweep.json"))  # bad name


def test_summarize_emits_run_py_rows(records):
    rows = summarize(records)
    assert len(rows) == len(records)
    for row in rows:
        name, us, derived = row.split(",")
        assert name.startswith("sweep/d4/p")
        packer = name.split("/")[4]
        assert packer in SMALL.packers
        float(us)
        assert derived.startswith("speedup=")


def test_config_rejects_undecomposable_grid():
    with pytest.raises(AssertionError):
        SweepConfig(device_counts=(3,), sizes=((16, 8),))  # 16 % 3 != 0
    with pytest.raises(AssertionError):
        SweepConfig(strategies=("persistent",))  # baseline not swept
    with pytest.raises(AssertionError):
        SweepConfig(packers=())  # at least one packer
    with pytest.raises(AssertionError, match="process"):
        SweepConfig(device_counts=(2, 4), processes=3)  # 2 % 3 != 0
    with pytest.raises(AssertionError):
        SweepConfig(processes=0)


def test_records_stamp_process_provenance(records):
    """Every record carries the REAL runtime process shape — this in-process
    suite is single-process, so the multihost stamps must be honest."""
    for rec in records:
        assert rec["process_count"] == 1
        assert rec["is_multihost"] is False


def test_wire_bytes_equals_message_bytes_for_exact_packers(records):
    for rec in records:
        assert rec["wire_bytes"] == rec["message_bytes"], rec["packer"]


def test_compressed_packers_shrink_wire_bytes():
    """A grid swept with the wire-compressed packers records the reduced
    wire cost (bf16: /2, scaled-int8: /4 for f32 fields) while
    message_bytes keeps the logical face size."""
    cfg = SweepConfig(
        device_counts=(4,), part_counts=(1,), sizes=((16, 8),),
        strategies=("standard", "persistent"),
        packers=("slice", "bf16", "scaled-int8"),
        n_cycles=2, repeats=1,
    )
    recs = sweep_cells(cfg, n_devices=4)
    assert {r["packer"] for r in recs} == {"slice", "bf16", "scaled-int8"}
    by_packer = {r["packer"]: r for r in recs if r["strategy"] == "persistent"}
    face = by_packer["slice"]["message_bytes"]
    assert by_packer["slice"]["wire_bytes"] == face
    assert by_packer["bf16"]["wire_bytes"] == face // 2
    assert by_packer["scaled-int8"]["wire_bytes"] == face // 4
    for r in recs:
        assert r["message_bytes"] == face
        for key in RECORD_KEYS:
            assert key in r
        json.dumps(r)


def test_coalesce_axis_swept(records):
    """Acceptance: every (strategy, packer) cell exists under BOTH coalesce
    modes, and the mode is stamped on the record."""
    assert {r["coalesce"] for r in records} == {False, True}
    by_mode = {}
    for r in records:
        by_mode.setdefault(r["coalesce"], set()).add(
            (r["strategy"], r["n_parts"], r["packer"])
        )
    assert by_mode[False] == by_mode[True]


def test_collective_counts_recorded_and_shrunk_by_coalescing(records):
    """Every record carries the step's scheduled collective count, and the
    coalesced cell of a given coordinate never launches more collectives
    than its uncoalesced twin (composed chains + shared-neighbor merging)."""
    by_coord = {}
    for r in records:
        assert isinstance(r["collective_count"], int)
        assert r["collective_count"] > 0  # multi-device: something moves
        by_coord[(r["strategy"], r["n_parts"], r["packer"],
                  r["coalesce"])] = r["collective_count"]
    for (strategy, n_parts, packer, coalesce), n in by_coord.items():
        if coalesce:
            assert n <= by_coord[(strategy, n_parts, packer, False)], (
                strategy, n_parts, packer
            )


def test_plan_cache_counters_recorded(records):
    """Private-plan strategies record one init and no hits; the standard
    baseline records neither (nothing is amortized)."""
    for r in records:
        if r["strategy"] == "standard":
            assert r["plan_cache_inits"] == 0
        else:
            assert r["plan_cache_inits"] == 1, r["strategy"]
        assert r["plan_cache_hits"] == 0


def test_replan_metric_recorded(records):
    """Every cell records the elastic re-plan axis: ``replan_us`` (the
    static Message/WireLayout re-derivation latency, always measurable) and
    ``plan_cache_invalidations`` (zero in a steady-state sweep — no
    topology died under it)."""
    for r in records:
        assert r["replan_us"] >= 0.0, r["strategy"]
        assert r["plan_cache_invalidations"] == 0, r["strategy"]
    # table re-derivation is pure python table math: it must be orders of
    # magnitude below any measured compile — the paper's amortized-setup
    # argument only survives elasticity if re-planning stays cheap
    for r in records:
        if r["init_us"] > 0:
            assert r["replan_us"] < r["init_us"], (
                r["strategy"], r["replan_us"], r["init_us"]
            )


def test_regression_failures_guard():
    from repro.stencil.sweep import regression_failures

    def rec(strategy, speedup):
        return {"strategy": strategy, "speedup_vs_baseline": speedup}

    committed = [rec("persistent", 2.0), rec("fused", 3.0)]
    # within threshold: 2.0 -> 1.6 is exactly -20% (< 25%)
    assert regression_failures(
        committed, [rec("persistent", 1.6), rec("fused", 3.1)]
    ) == []
    # beyond threshold: fused collapsed
    fails = regression_failures(
        committed, [rec("persistent", 2.0), rec("fused", 1.0)]
    )
    assert len(fails) == 1 and "fused" in fails[0]
    # a strategy only one side measured is ignored
    assert regression_failures(committed, [rec("persistent", 2.0)]) == []
    # the BEST cell per strategy is what is guarded (single-cell jitter on
    # the 3-cycle smoke grid must not flash red on identical code)
    assert regression_failures(
        committed, [rec("fused", 0.5), rec("fused", 2.9),
                    rec("persistent", 1.9)]
    ) == []


def test_committed_bench_baseline_matches_smoke_grid():
    """The repo-committed BENCH_stencil_sweep.json (the CI regression
    baseline) must carry the smoke grid's schema and the coalesce axis."""
    import os

    from repro.stencil.sweep import read_bench_json

    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "BENCH_stencil_sweep.json")
    records, config = read_bench_json(path)
    assert config is not None and config["smoke"] is True
    assert records, "committed baseline is empty"
    for rec in records:
        for key in RECORD_KEYS:
            assert key in rec, f"committed baseline missing {key}"
    assert {r["coalesce"] for r in records} == {False, True}
    strategies = {r["strategy"] for r in records}
    assert {"standard", "persistent", "partitioned", "fused",
            "overlap"} <= strategies


def test_config_block_stamps_process_shape(tmp_path, records):
    from repro.stencil.sweep import config_block

    block = config_block(SMALL, timeout=90.0, smoke=True)
    assert block["process_count"] == 1 and block["is_multihost"] is False
    assert block["sweep"]["processes"] == 1
    # a launcher writing on behalf of a spawned grid passes the real count
    block2 = config_block(SMALL, timeout=90.0, processes=2)
    assert block2["process_count"] == 2 and block2["is_multihost"] is True
    # and the multihost stamps round-trip through the BENCH interchange
    path = tmp_path / "BENCH_mh.json"
    write_bench_json(records, str(path), config=block2)
    got, cfg = read_bench_json(str(path))
    assert got == records
    assert cfg["process_count"] == 2 and cfg["is_multihost"] is True


def test_multihost_config_carries_processes_axis():
    """--processes fan-out config: processes travels through the worker
    json, and a grid-borne config validates device divisibility."""
    cfg = SweepConfig(device_counts=(4,), sizes=((16, 8),), processes=2,
                      transport="multihost")
    assert SweepConfig.from_json(cfg.to_json()) == cfg
    # a pre-processes-axis config json defaults to the in-process grid
    raw = json.loads(cfg.to_json())
    del raw["processes"]
    raw["transport"] = "ppermute"
    assert SweepConfig.from_json(json.dumps(raw)).processes == 1


def test_bench_json_config_block_roundtrip(tmp_path, records):
    """The CLI's config-block form: records AND run parameters round-trip;
    the legacy bare-list form still reads back."""
    path = tmp_path / "BENCH_block.json"
    write_bench_json(records, str(path),
                     config={"timeout": 90.0, "smoke": True})
    got, cfg = read_bench_json(str(path))
    assert got == records
    assert cfg == {"timeout": 90.0, "smoke": True}
    bare = tmp_path / "BENCH_bare.json"
    write_bench_json(records, str(bare))
    got, cfg = read_bench_json(str(bare))
    assert got == records and cfg is None


def test_config_json_roundtrip():
    cfg = SweepConfig(device_counts=(2, 4), part_counts=(1, 2),
                      sizes=((32, 16),), packers=("pallas",),
                      coalesce_modes=(True,))
    assert SweepConfig.from_json(cfg.to_json()) == cfg
    # a pre-packer-axis config json (no "packers" key) defaults to slice
    import json as _json

    raw = _json.loads(cfg.to_json())
    del raw["packers"]
    assert SweepConfig.from_json(_json.dumps(raw)).packers == ("slice",)
    # a pre-coalescing config json ran the historical uncoalesced path
    del raw["coalesce_modes"]
    assert SweepConfig.from_json(_json.dumps(raw)).coalesce_modes == (False,)
    with pytest.raises(AssertionError):
        SweepConfig(coalesce_modes=())  # at least one mode
    with pytest.raises(AssertionError):
        SweepConfig(coalesce_modes=(True, True))  # duplicate cells


MAPPED = SweepConfig(
    device_counts=(4,), part_counts=(1,), sizes=((16, 8),),
    strategies=("standard", "persistent", "fused"),
    packers=("slice",), coalesce_modes=(True,),
    mappings=("row-major", "blocked"), mesh_ndim=2,
    n_cycles=2, repeats=1,
)


@pytest.fixture(scope="module")
def mapped_records():
    return sweep_cells(MAPPED, n_devices=4)


def test_mapping_axis_swept(mapped_records):
    """Acceptance: every cell exists under BOTH mappings, the mapping is
    stamped on the record, and the baseline denominator is the FIRST
    mapping's first-packer first-mode standard run."""
    assert len(mapped_records) == _expected_cells(MAPPED)
    assert {r["mapping"] for r in mapped_records} == {"row-major", "blocked"}
    by_mapping = {}
    for r in mapped_records:
        by_mapping.setdefault(r["mapping"], set()).add(
            (r["strategy"], r["n_parts"], r["packer"], r["coalesce"])
        )
    assert by_mapping["row-major"] == by_mapping["blocked"]
    for r in mapped_records:
        if (r["mapping"] == "row-major" and r["strategy"] == "standard"
                and r["packer"] == "slice"
                and r["coalesce"] is MAPPED.coalesce_modes[0]):
            assert r["speedup_vs_baseline"] == pytest.approx(1.0)
        else:
            assert r["speedup_vs_baseline"] > 0.0


def test_mapping_records_carry_static_locality(mapped_records):
    """Every record tallies its hop locality under the cell's node_size,
    and the totals are mapping-independent per (strategy, n_parts): a
    mapping moves sends across the node boundary, never adds any."""
    totals = {}
    for r in mapped_records:
        assert r["node_size"] == 2  # 4 in-process devices: modeled 2 nodes
        assert r["intra_node_sends"] >= 0 and r["inter_node_sends"] >= 0
        assert r["intra_node_sends"] + r["inter_node_sends"] > 0
        key = (r["strategy"], r["n_parts"])
        total = r["intra_node_sends"] + r["inter_node_sends"]
        totals.setdefault(key, {})[r["mapping"]] = total
    for key, per_mapping in totals.items():
        assert len(set(per_mapping.values())) == 1, (key, per_mapping)


def test_config_json_roundtrip_mappings():
    cfg = SweepConfig(device_counts=(4,), sizes=((16, 8),),
                      mappings=("row-major", "rb"))
    # aliases canonicalize at construction, and the canonical form
    # round-trips through the worker-config json
    assert cfg.mappings == ("row-major", "recursive-bisection")
    assert SweepConfig.from_json(cfg.to_json()) == cfg
    # a pre-mapping config json ran the identity placement
    raw = json.loads(cfg.to_json())
    del raw["mappings"]
    del raw["node_size"]
    old = SweepConfig.from_json(json.dumps(raw))
    assert old.mappings == ("row-major",) and old.node_size == 0
    with pytest.raises(AssertionError):
        SweepConfig(mappings=())  # at least one mapping
    with pytest.raises(AssertionError):
        # alias and canonical name are the SAME cell
        SweepConfig(mappings=("rb", "recursive-bisection"))
    with pytest.raises(KeyError, match="hilbert"):
        SweepConfig(mappings=("hilbert",))


def test_mesh_shape_for_warns_on_degenerate_2d():
    from repro.stencil.sweep import mesh_shape_for

    with pytest.warns(RuntimeWarning, match="cannot form"):
        assert mesh_shape_for(3, 2, warn=True) == (3,)
    # the default (config-validation loops) stays silent, and a shape that
    # CAN form the torus never warns
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert mesh_shape_for(3, 2) == (3,)
        assert mesh_shape_for(4, 2, warn=True) == (2, 2)
        assert mesh_shape_for(3, 1, warn=True) == (3,)


def test_config_block_records_effective_mesh_shapes():
    from repro.stencil.sweep import config_block

    cfg = SweepConfig(device_counts=(4, 6), sizes=((24, 8),), mesh_ndim=2)
    block = config_block(cfg, timeout=90.0)
    assert block["effective_mesh_shapes"] == {"4": [2, 2], "6": [3, 2]}


def test_smoke_config_covers_two_mappings():
    from repro.stencil.sweep import smoke_config

    assert smoke_config().mappings == ("row-major", "blocked")
    assert smoke_config(mappings=("rb",)).mappings == (
        "recursive-bisection",
    )


def test_read_bench_json_clear_errors(tmp_path):
    """Satellite: malformed BENCH payloads fail with a message naming the
    file and the shape mismatch, not a KeyError deep in a consumer."""
    bad_dict = tmp_path / "BENCH_bad.json"
    bad_dict.write_text(json.dumps({"config": {}, "rows": []}))
    with pytest.raises(ValueError, match="no 'records' key"):
        read_bench_json(str(bad_dict))
    bad_scalar = tmp_path / "BENCH_scalar.json"
    bad_scalar.write_text("42")
    with pytest.raises(ValueError, match="must be a json list or dict"):
        read_bench_json(str(bad_scalar))


def test_regression_guard_clear_errors():
    """Satellite: a stale baseline (pre-schema records, or zero strategy
    overlap) raises a ValueError explaining itself instead of KeyError /
    silently passing a vacuous check."""
    from repro.stencil.sweep import regression_failures

    good = [{"strategy": "standard", "speedup_vs_baseline": 1.0}]
    with pytest.raises(ValueError, match="speedup_vs_baseline"):
        regression_failures([{"strategy": "standard"}], good)
    with pytest.raises(ValueError, match="regenerate"):
        regression_failures(good, [{"speedup_vs_baseline": 2.0}])
    with pytest.raises(ValueError, match="not comparable"):
        regression_failures(
            good, [{"strategy": "fused", "speedup_vs_baseline": 2.0}]
        )
    # both sides empty is vacuously fine (a fresh repo with no baseline)
    assert regression_failures([], []) == []


# ---------------------------------------------------------------------------
# the autotuned cell ("auto" strategy) in the sweep grid
# ---------------------------------------------------------------------------

AUTO_CFG = SweepConfig(
    device_counts=(4,), part_counts=(1, 2), sizes=((16, 8),),
    strategies=("standard", "auto"), packers=("slice",),
    coalesce_modes=(True,), mappings=("row-major",), mesh_ndim=2,
    n_cycles=2, repeats=1,
)

_BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "..", "BENCH_stencil_sweep.json",
)


@pytest.fixture(scope="module")
def auto_records(tmp_path_factory):
    """Sweep the AUTO_CFG grid with the committed baseline as the tuner's
    trace: the (2,2)-torus (16,8) cell matches the committed smoke cell
    verbatim, so selection resolves from the trace — fast and
    deterministic, no calibration probes."""
    import os

    from repro.core.autotune import CACHE_ENV, TRACE_ENV, reset_default_tuners

    cache = tmp_path_factory.mktemp("autotune") / "autotune.json"
    saved = {k: os.environ.get(k) for k in (TRACE_ENV, CACHE_ENV)}
    os.environ[TRACE_ENV] = _BASELINE_PATH
    os.environ[CACHE_ENV] = str(cache)
    reset_default_tuners()
    try:
        yield sweep_cells(AUTO_CFG, n_devices=4)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
        reset_default_tuners()


def test_auto_cell_resolves_from_trace(auto_records):
    """Acceptance: a sweep with the auto strategy yields one tuned record
    per mapping whose selection provenance (selected_by/predicted_us) is
    stamped, with the resolved coordinates all concrete."""
    from repro.stencil.strategies import available_strategies

    assert len(auto_records) == _expected_cells(AUTO_CFG) == 2
    autos = [r for r in auto_records if r.get("selected_by")]
    static = [r for r in auto_records if not r.get("selected_by")]
    assert len(autos) == 1 and len(static) == 1
    assert static[0]["strategy"] == "standard"
    assert static[0]["predicted_us"] is None
    (auto,) = autos
    assert auto["selected_by"] == "trace"  # the committed cell matched
    assert auto["predicted_us"] > 0
    assert auto["calibration_us"] == 0.0  # no probes ran
    # every resolved coordinate is concrete, never the sentinel
    assert auto["strategy"] in available_strategies()
    assert auto["packer"] in ("slice", "pallas")
    assert isinstance(auto["coalesce"], bool)
    assert auto["n_parts"] >= 1
    assert auto["speedup_vs_baseline"] > 0
    assert auto["init_us"] > 0  # the tuned driver amortizes its init
    for key in RECORD_KEYS:
        assert key in auto
    json.dumps(auto)


def test_summarize_tags_autotuned_rows(auto_records):
    """Satellite: summarize carries the mapping + locality columns on
    every row and the auto: tag + selection provenance on tuned rows."""
    rows = summarize(auto_records)
    assert len(rows) == len(auto_records)
    tagged = [r for r in rows if "/auto:" in r]
    assert len(tagged) == 1
    for row in rows:
        name, us, derived = row.split(",")  # derived stays comma-free
        assert name.split("/")[6] == "row-major"  # the mapping column
        float(us)
        assert ";intra=" in derived and ";inter=" in derived
    assert ";selected_by=trace" in tagged[0]
    assert all(";selected_by=" not in r for r in rows if "/auto:" not in r)


def test_regression_guard_floors_auto_against_best_static():
    """Satellite: autotuned records pool under one 'auto' key compared
    against the committed autotuned best when present, else the committed
    best STATIC cell — never keyed by their resolved strategy name."""
    from repro.stencil.sweep import regression_failures

    def rec(strategy, sp, **kw):
        return {"strategy": strategy, "speedup_vs_baseline": sp, **kw}

    static = [rec("standard", 1.0), rec("overlap", 2.0)]
    auto_ok = rec("overlap", 1.9, selected_by="trace")
    auto_bad = rec("standard", 1.0, selected_by="cache")
    # floored against the committed best static (2.0): 1.9 clears the 25%
    # threshold, 1.0 does not
    assert regression_failures(static, static + [auto_ok]) == []
    fails = regression_failures(static, static + [auto_bad])
    assert len(fails) == 1 and fails[0].startswith("auto:")
    # an auto record resolving to "overlap" must NOT satisfy the static
    # overlap guard: only genuine static cells key by strategy name
    assert regression_failures(static, [rec("standard", 1.0), auto_ok]) == []
    # a committed autotuned best takes precedence as the floor
    committed = static + [rec("fused", 1.2, selected_by="cache")]
    assert regression_failures(
        committed, static + [rec("fused", 1.1, selected_by="trace")]
    ) == []
    # an auto-only fresh sweep against a static baseline is comparable
    # (the auto floor IS the comparison; no vacuity error)
    assert regression_failures(static, [auto_ok]) == []
    # but a baseline with nothing to floor against is actionable
    with pytest.raises(ValueError, match="predates the autotune schema"):
        regression_failures([], [auto_ok])


def test_smoke_config_strategy_restriction():
    from repro.stencil.sweep import smoke_config

    cfg = smoke_config(strategies=("standard", "auto"))
    assert cfg.strategies == ("standard", "auto")
    assert _expected_cells(cfg) == len(cfg.mappings) * (
        len(cfg.packers) * len(cfg.coalesce_modes) + 1
    )


def test_config_rejects_auto_baseline():
    with pytest.raises(AssertionError, match="baseline"):
        SweepConfig(strategies=("auto",), baseline="auto")


@pytest.mark.slow
def test_subprocess_sweep_over_device_counts(tmp_path):
    """The real §VI fan-out: a 3-point grid (2 device counts x 2 partition
    counts x 1 size beyond the baseline cell) through fresh subprocesses."""
    cfg = SweepConfig(device_counts=(2, 4), part_counts=(1, 2),
                      sizes=((16, 8),), n_cycles=3, repeats=1)
    records = run_sweep(cfg)
    assert {r["n_devices"] for r in records} == {2, 4}
    path = tmp_path / "BENCH_stencil_sweep.json"
    write_bench_json(records, str(path))
    loaded = json.loads(path.read_text())
    assert len(loaded) == _expected_cells(cfg) * 2  # one grid per device count
    for rec in loaded:
        for key in RECORD_KEYS:
            assert key in rec
