"""Strategy registry + numerical equivalence of the three exchange strategies.

Runs in-process on the 8 virtual devices forced by the repo conftest: every
registered strategy must produce the same halo exchange (standard is the
reference) on 1-D/2-D/3-D domains, including non-dividing partition counts
(the Partitioner's equal-size padding edge cases).
"""

import jax
import numpy as np
import pytest

from repro.core.compat import make_mesh
from repro.core.plan import PlanCache
from repro.stencil import Domain, ExchangeDriver, periodic_oracle_step
from repro.stencil.strategies import (
    ExchangeStrategy,
    StrategyConfig,
    available_strategies,
    get_strategy,
    make_driver,
    register_strategy,
)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >=4 virtual devices (conftest)"
)


def _mesh_1d(n=4):
    return make_mesh((n,), ("px",), devices=jax.devices()[:n])


def _domain(mesh, interior, axes, halo=1):
    return Domain(mesh, global_interior=interior, mesh_axes=axes, halo=halo)


def _exchange_once(domain, strategy, n_parts, seed=0):
    drv = make_driver(
        StrategyConfig(name=strategy, n_parts=n_parts),
        domain.mesh, domain.halo_spec, ndim=len(domain.global_interior),
    )
    y = drv.wait(drv.step(domain.random(seed)))
    out = np.asarray(y)
    drv.free()
    return out


# ---------------------------------------------------------------------------
# registry behavior
# ---------------------------------------------------------------------------


def test_paper_strategies_registered():
    names = available_strategies()
    assert names[:3] == ("standard", "persistent", "partitioned")
    # the two overlap strategies beyond the paper's trio
    assert {"fused", "overlap"} <= set(names)
    for name in names:
        assert issubclass(get_strategy(name), ExchangeStrategy)


def test_unknown_strategy_message_lists_registered():
    with pytest.raises(KeyError, match="standard.*persistent.*partitioned"):
        get_strategy("telepathic")


def test_duplicate_registration_rejected():
    class Dupe(ExchangeStrategy):
        name = "standard"

        def init(self, example):
            pass

        def step(self, x):
            return x

    with pytest.raises(ValueError, match="already registered"):
        register_strategy(Dupe)


def test_registering_new_strategy_makes_it_constructible():
    class Echo(ExchangeStrategy):
        name = "echo-test-only"

        def init(self, example):
            pass

        def step(self, x):
            return x

    register_strategy(Echo)
    try:
        mesh = _mesh_1d()
        dom = _domain(mesh, (16,), ("px",))
        drv = make_driver("echo-test-only", mesh, dom.halo_spec, ndim=1)
        assert isinstance(drv, Echo)
        assert drv.strategy == "echo-test-only"
    finally:
        from repro.stencil import strategies as S

        del S._REGISTRY["echo-test-only"]


def test_custom_strategy_runs_real_exchange():
    """The docstring's extension recipe must actually exchange: a custom
    name flows through build_spec -> HaloSpec -> exchange without tripping
    the paper-trio whitelist, and can opt into partitioned transport."""
    from repro.stencil.strategies import PersistentStrategy

    class Custom(PersistentStrategy):
        name = "custom-partitioned-test"
        uses_partitions = True

    register_strategy(Custom)
    try:
        mesh = _mesh_1d()
        dom = _domain(mesh, (16, 12), ("px", None))
        ref = _exchange_once(dom, "standard", 1)
        got = _exchange_once(dom, "custom-partitioned-test", 5)
        np.testing.assert_array_equal(got, ref)
    finally:
        from repro.stencil import strategies as S

        del S._REGISTRY["custom-partitioned-test"]


def test_comb_measure_same_name_twice_keeps_both():
    from repro.stencil import comb_measure

    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    results = comb_measure(
        dom,
        strategies=("standard",
                    StrategyConfig(name="partitioned", n_parts=2),
                    StrategyConfig(name="partitioned", n_parts=4)),
        n_cycles=2, repeats=1,
    )
    assert set(results) == {"standard", "partitioned", "partitioned#p4"}
    assert results["partitioned"].n_parts == 2
    assert results["partitioned#p4"].n_parts == 4


def test_comb_measure_same_name_same_parts_gets_ordinal_suffix():
    """Same name AND same n_parts (e.g. cache-policy A/B runs) must not
    assert out — later entries get a stable ``#2`` ordinal."""
    from repro.stencil import comb_measure

    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    cfg = StrategyConfig(name="persistent", n_parts=1)
    results = comb_measure(
        dom,
        strategies=("standard", cfg, cfg.with_(plan_cache="shared"), cfg),
        n_cycles=2, repeats=1,
    )
    assert set(results) == {
        "standard", "persistent", "persistent#p1", "persistent#p1#2",
    }


def test_config_validation():
    with pytest.raises(AssertionError):
        StrategyConfig(name="partitioned", n_parts=0)
    with pytest.raises(AssertionError):
        StrategyConfig(name="persistent", plan_cache="global")


# ---------------------------------------------------------------------------
# numerical equivalence across strategies (the acceptance bar)
# ---------------------------------------------------------------------------

CASES = [
    # (interior, mesh shape, mesh axis names, array<-mesh mapping, n_parts)
    pytest.param((16,), (4,), ("px",), ("px",), 3, id="1d-parts3"),
    pytest.param((16, 12), (4,), ("px",), ("px", None), 5, id="2d-parts5-nondiv"),
    pytest.param((16, 8), (4, 2), ("px", "py"), ("px", "py"), 2, id="2d-2axis"),
    pytest.param((16, 8, 6), (4, 2), ("pz", "py"), ("pz", "py", None), 3,
                 id="3d-parts3-nondiv"),
    pytest.param((8, 8, 12), (2, 2), ("pz", "py"), ("pz", "py", None), 4,
                 id="3d-parts4"),
]


@pytest.mark.parametrize("interior,shape,names,axes,n_parts", CASES)
def test_strategies_numerically_equivalent(interior, shape, names, axes, n_parts):
    mesh = make_mesh(shape, names,
                     devices=jax.devices()[: int(np.prod(shape))])
    dom = _domain(mesh, interior, axes)
    ref = _exchange_once(dom, "standard", 1)
    for strategy in available_strategies():
        if strategy == "standard":
            continue
        got = _exchange_once(dom, strategy, n_parts)
        np.testing.assert_array_equal(got, ref, err_msg=strategy)


def test_partition_count_exceeding_face_size():
    """n_parts larger than the tangent axis: tail partitions are pure padding."""
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 4), ("px", None))
    ref = _exchange_once(dom, "standard", 1)
    got = _exchange_once(dom, "partitioned", 7)  # tangent extent is only 4
    np.testing.assert_array_equal(got, ref)


def test_multi_cycle_update_matches_numpy_oracle():
    """Full Comb loop (exchange + 9-point update) vs the periodic oracle."""
    mesh = make_mesh((2, 2), ("pz", "py"), devices=jax.devices()[:4])
    dom = _domain(mesh, (8, 8), ("pz", "py"))
    interior = np.random.default_rng(3).normal(size=(8, 8)).astype(np.float32)
    w = np.full((3, 3), 1.0 / 9.0, np.float32)

    want = interior.copy()
    for _ in range(3):
        want = periodic_oracle_step(want, w)

    import jax.numpy as jnp

    def update(xl):
        new = jnp.zeros_like(xl[1:-1, 1:-1])
        for di in range(3):
            for dj in range(3):
                new = new + w[di, dj] * xl[di:di + xl.shape[0] - 2,
                                           dj:dj + xl.shape[1] - 2]
        return jax.lax.dynamic_update_slice(xl, new, (1, 1))

    for strategy, parts in (("standard", 1), ("persistent", 1),
                            ("partitioned", 3), ("fused", 1),
                            ("overlap", 1)):
        drv = make_driver(
            StrategyConfig(name=strategy, n_parts=parts),
            dom.mesh, dom.halo_spec, ndim=2, update_fn=update,
        )
        x = dom.from_global_interior(interior)
        for _ in range(3):
            x = drv.step(x)
        got = dom.to_global_interior(drv.wait(x))
        drv.free()
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5,
                                   err_msg=strategy)


# ---------------------------------------------------------------------------
# lifecycle / plan-cache policy
# ---------------------------------------------------------------------------


def test_standard_init_is_noop_and_persistent_compiles():
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    x = dom.random(0)

    std = make_driver("standard", mesh, dom.halo_spec, ndim=2)
    assert std.init(x) is None

    per = make_driver("persistent", mesh, dom.halo_spec, ndim=2)
    per.init(x)
    assert "ROOT" in per.compiled_text(x)  # AOT-compiled HLO exists
    per.free()
    std.free()


def test_shared_plan_cache_hits_across_drivers():
    cache = PlanCache()
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    cfg = StrategyConfig(name="persistent", plan_cache=cache)
    for _ in range(2):
        drv = make_driver(cfg, mesh, dom.halo_spec, ndim=2)
        drv.wait(drv.step(dom.random(0)))
        drv.free()
    assert cache.stats.inits == 1  # second driver reused the first's plan
    assert cache.stats.cache_hits >= 1
    assert len(cache) == 1
    cache.free_all()


def test_private_cache_frees_with_driver():
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    drv = make_driver("persistent", mesh, dom.halo_spec, ndim=2)
    drv.init(dom.random(0))
    assert drv._plan is not None
    drv.free()
    assert drv._plan is None


def test_legacy_facade_resolves_registry_drivers():
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    drv = ExchangeDriver(
        mesh, lambda: dom.halo_spec("partitioned", 3), ndim=2
    )
    assert drv.strategy == "partitioned" and drv.n_parts == 3
    assert isinstance(drv, get_strategy("partitioned"))


# ---------------------------------------------------------------------------
# transport-layer knobs (packer / transport)
# ---------------------------------------------------------------------------


def test_config_rejects_unknown_packer_and_transport():
    with pytest.raises(KeyError, match="unknown packer"):
        StrategyConfig(name="persistent", packer="zstd")
    with pytest.raises(KeyError, match="unknown transport"):
        StrategyConfig(name="persistent", transport="nccl")


def test_packer_flows_into_spec_and_plan_identity():
    """The config's packer/transport stamp the built spec, so persistent
    plan keys (derived from the spec) distinguish pipelines."""
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    a = make_driver(StrategyConfig(name="persistent"), mesh,
                    dom.halo_spec, ndim=2)
    b = make_driver(StrategyConfig(name="persistent", packer="pallas"),
                    mesh, dom.halo_spec, ndim=2)
    assert a.build_spec().packer == "slice"
    assert b.build_spec().packer == "pallas"
    assert b.build_spec().transport == "ppermute"
    x = dom.random(0)
    assert a._plan_key(x) != b._plan_key(x)


def test_shared_cache_keeps_packers_apart():
    """Same geometry, different packer: two distinct persistent plans."""
    cache = PlanCache()
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    for packer in ("slice", "pallas"):
        drv = make_driver(
            StrategyConfig(name="persistent", plan_cache=cache,
                           packer=packer),
            mesh, dom.halo_spec, ndim=2,
        )
        drv.wait(drv.step(dom.random(0)))
        drv.free()
    assert cache.stats.inits == 2 and len(cache) == 2
    cache.free_all()


def test_comb_measure_labels_distinguish_packers():
    from repro.stencil import comb_measure

    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 8), ("px", None))
    results = comb_measure(
        dom,
        strategies=(
            "standard",
            StrategyConfig(name="standard", packer="pallas"),
            StrategyConfig(name="partitioned", n_parts=2, packer="pallas"),
        ),
        n_cycles=2, repeats=1,
    )
    assert set(results) == {
        "standard", "standard@pallas", "partitioned@pallas",
    }
    assert results["standard@pallas"].packer == "pallas"
    assert results["standard"].packer == "slice"
    assert results["partitioned@pallas"].transport == "ppermute"


def test_all_strategies_agree_under_pallas_packer():
    """Cross-strategy equality still holds when every message stages
    through the pallas packer (CPU oracle fallback: bit-identical)."""
    mesh = _mesh_1d()
    dom = _domain(mesh, (16, 12), ("px", None))
    ref = _exchange_once(dom, "standard", 1)
    for strategy in available_strategies():
        drv = make_driver(
            StrategyConfig(name=strategy, n_parts=3, packer="pallas"),
            dom.mesh, dom.halo_spec, ndim=2,
        )
        got = np.asarray(drv.wait(drv.step(dom.random(0))))
        drv.free()
        np.testing.assert_array_equal(got, ref, err_msg=strategy)
