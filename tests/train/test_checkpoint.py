"""Checkpoint roundtrip, atomicity, retention, corruption detection, async."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 6)), jnp.bfloat16),
                   "b": jnp.asarray(rng.normal(size=(6,)), jnp.float32)},
        "opt": {"m": {"w": jnp.zeros((4, 6)), "b": jnp.ones((6,))},
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_exact(tmp_path):
    state = _state()
    ckpt.save(state, str(tmp_path), 10)
    restored, step = ckpt.restore(str(tmp_path), like=state)
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_retention(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4):
        ckpt.save(state, str(tmp_path), s, keep=2)
    assert ckpt.committed_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_uncommitted_dir_ignored(tmp_path):
    state = _state()
    ckpt.save(state, str(tmp_path), 1)
    # fake a crashed save: committed marker missing
    d = tmp_path / "step_00000002"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_corruption_detected(tmp_path):
    state = _state()
    path = ckpt.save(state, str(tmp_path), 5)
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr_view = arr.view(np.uint8 if arr.dtype != np.uint8 else np.uint8)
    arr_view.flat[0] ^= 0xFF
    np.save(leaf, arr)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(str(tmp_path), like=state)


def test_async_checkpointer(tmp_path):
    state = _state()
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=3)
    for s in (2, 4, 6):
        ac.save(state, s)
    ac.wait()
    assert ckpt.committed_steps(str(tmp_path)) == [2, 4, 6]
    restored, step = ckpt.restore(str(tmp_path), like=state)
    assert step == 6


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore onto explicit shardings (1-device mesh here)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    state = _state()
    ckpt.save(state, str(tmp_path), 3)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = ckpt.restore(str(tmp_path), like=state, shardings=sh)
    assert restored["params"]["w"].sharding.mesh.shape["d"] == 1
