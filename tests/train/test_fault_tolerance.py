"""Fault tolerance: restart-from-checkpoint continues the exact trajectory;
straggler detection; elastic re-shard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.models import build_model
from repro.train.fault_tolerance import (
    FailureInjector, SimulatedFailure, StragglerMonitor, reshard_state,
)
from repro.train.train_loop import Trainer

TINY_SHAPE = ShapeConfig("tiny", 16, 4, "train")


def _run_cfg(tmp_path, steps=6, **kw):
    return RunConfig(
        model=get_config("stablelm-1.6b").reduced(),
        shape=TINY_SHAPE,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=100),
        steps=steps,
        checkpoint_dir=str(tmp_path / "ckpt"),
        checkpoint_every=2,
        async_checkpoint=False,
        log_every=0,
        **kw,
    )


def test_loss_decreases(tmp_path):
    cfg = _run_cfg(tmp_path, steps=8)
    model = build_model(cfg.model)
    res = Trainer(model, cfg).run()
    assert len(res.losses) == 8
    assert res.losses[-1] < res.losses[0], res.losses


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Injected failure + restore: final state identical to a clean run."""
    model = build_model(_run_cfg(tmp_path).model)

    clean_cfg = _run_cfg(tmp_path / "clean", steps=6)
    clean = Trainer(model, clean_cfg).run()

    faulty_cfg = _run_cfg(tmp_path / "faulty", steps=6)
    injector = FailureInjector(fail_at_steps=(3,))
    faulty = Trainer(model, faulty_cfg, injector=injector).run()

    assert faulty.restarts == 1
    # the replayed trajectory must converge to the same final state
    np.testing.assert_allclose(faulty.checksum, clean.checksum, rtol=1e-6)
    # last loss identical (deterministic data + exact state restore)
    np.testing.assert_allclose(faulty.losses[-1], clean.losses[-1], rtol=1e-5)


def test_injector_raises_once_per_step():
    inj = FailureInjector(fail_at_steps=(2,))
    inj.check(1)
    with pytest.raises(SimulatedFailure):
        inj.check(2)
    inj.check(2)  # second pass after restart: no refire


def test_injector_phase_filter_and_keying():
    """With ``phases`` set only tagged chaos points may fire, and the
    dedup key is (step, phase): the same step's OTHER phases still pass
    after a fire."""
    inj = FailureInjector(fail_at_steps=(2,), phases=("mid-exchange",))
    inj.check(2)  # untagged check at a fail step: filtered, no fire
    inj.check(2, phase="pre-step")  # unlisted phase: filtered
    with pytest.raises(SimulatedFailure):
        inj.check(2, phase="mid-exchange")
    inj.check(2, phase="mid-exchange")  # replay after restart: deduped
    # a later fail step still fires on its own key
    inj2 = FailureInjector(fail_at_steps=(2, 5), phases=("mid-exchange",))
    with pytest.raises(SimulatedFailure):
        inj2.check(2, phase="mid-exchange")
    with pytest.raises(SimulatedFailure):
        inj2.check(5, phase="mid-exchange")


def test_injector_probability_path_is_deterministic_and_dedups():
    """The probability path is seeded by (seed, step, phase) — two
    injectors agree on WHICH steps fail — and records fires in ``_fired``
    so a restart replaying the same step never refires (without the dedup
    the deterministic seeding would re-kill the resumed run forever)."""

    def fired_steps(inj, n=64):
        fired = []
        for step in range(n):
            try:
                inj.check(step, phase="mid-exchange")
            except SimulatedFailure:
                fired.append(step)
        return fired

    a = fired_steps(FailureInjector(probability=0.25, seed=7))
    b = fired_steps(FailureInjector(probability=0.25, seed=7))
    assert a == b and a, "seeded probability path must fire reproducibly"
    # replaying the exact same steps on the SAME injector: all deduped
    inj = FailureInjector(probability=0.25, seed=7)
    first = fired_steps(inj)
    assert first == a
    assert fired_steps(inj) == [], "restart replay must not refire"
    assert {(s, "mid-exchange") for s in a} <= inj._fired
    # phase participates in the draw: a different phase is an independent
    # (but still deterministic) failure pattern
    c = fired_steps(FailureInjector(probability=0.25, seed=7, phases=()))
    d = []
    inj_d = FailureInjector(probability=0.25, seed=7)
    for step in range(64):
        try:
            inj_d.check(step, phase="plan-build:round")
        except SimulatedFailure:
            d.append(step)
    assert c != d  # the crc32 phase salt separates the streams


def test_injector_disabled_never_fires():
    inj = FailureInjector(fail_at_steps=(0, 1), probability=1.0,
                          enabled=False)
    for step in range(4):
        inj.check(step, phase="mid-exchange")
    assert not inj._fired


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(ewma=0.5, factor=2.0)
    hits = []
    mon.on_straggler = lambda s, t, m: hits.append(s)
    for step in range(10):
        mon.observe(step, 0.1)
    assert not mon.flagged
    assert mon.observe(10, 0.5)  # 5x the mean
    assert mon.flagged and hits == [10]
    # outlier must not poison the mean
    assert not mon.observe(11, 0.1)


def test_elastic_reshard_roundtrip():
    """Re-mesh a state onto a different (here: trivial) mesh layout."""
    from jax.sharding import Mesh, PartitionSpec as P

    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    out = reshard_state(state, mesh, {"w": P(None, None)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))


# ---------------------------------------------------------------------------
# satellite: phase scoping — a "join"-armed injector cannot leak into
# steady state (regression alongside the (step, phase) dedup tests above)
# ---------------------------------------------------------------------------


def test_join_phase_scope_cannot_fire_in_steady_state():
    """probability=1.0 armed for the "join" phase: fires inside the JOIN
    window, and NEVER during steady-state steps of the grown grid — the
    scope restores the tag on exit, so it cannot leak forward."""
    inj = FailureInjector(probability=1.0, phases=("join",), seed=3)
    for step in range(3):  # steady state before the join: untagged
        inj.check(step)
    with pytest.raises(SimulatedFailure):
        with inj.phase_scope("join"):
            inj.check(3)  # untagged check inherits the scoped phase
    # the grown grid's steady-state steps: same injector, still armed,
    # but the "join" tag died with its window
    for step in range(4, 50):
        inj.check(step)
    assert inj._fired == {(3, "join")}
    assert inj._active_phase is None  # restored even though check raised


def test_phase_scope_explicit_tags_win_and_scopes_nest():
    inj = FailureInjector(fail_at_steps=(5,), phases=("mid-exchange",))
    with pytest.raises(SimulatedFailure):
        with inj.phase_scope("join"):
            inj.check(5, phase="mid-exchange")  # explicit tag, not "join"
    assert (5, "mid-exchange") in inj._fired
    inj2 = FailureInjector(fail_at_steps=(1,), phases=("inner",))
    with inj2.phase_scope("outer"):
        with pytest.raises(SimulatedFailure):
            with inj2.phase_scope("inner"):
                inj2.check(1)
        assert inj2._active_phase == "outer"  # inner scope restored outer
        inj2.check(1)  # outer tag filtered out; nothing fires
    assert inj2._fired == {(1, "inner")}


# ---------------------------------------------------------------------------
# satellite: reshard_state across unequal old/new meshes
# ---------------------------------------------------------------------------


def _data_mesh(n):
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:n]), ("data",))


def _assert_matches_fresh_shard(resharded, global_np, new_mesh, spec):
    """Bitwise equality to a fresh shard of the same global array — both
    the reassembled value and every per-device shard."""
    from jax.sharding import NamedSharding

    fresh = jax.device_put(global_np, NamedSharding(new_mesh, spec))
    np.testing.assert_array_equal(np.asarray(resharded), global_np)
    shards = {s.device: s for s in resharded.addressable_shards}
    for ref in fresh.addressable_shards:
        got = shards[ref.device]
        assert got.index == ref.index
        np.testing.assert_array_equal(
            np.asarray(got.data), np.asarray(ref.data))


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (conftest)")
@pytest.mark.parametrize("n_old,n_new", [(4, 8), (8, 6), (2, 6)])
def test_reshard_state_across_unequal_meshes(n_old, n_new):
    """Grow 4->8, shrink 8->6, and 2->6: every leaf lands exactly where a
    fresh shard of the same global array would."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rng = np.random.default_rng(7)
    tree_np = {
        "w": rng.normal(size=(24, 4)).astype(np.float32),
        "b": rng.normal(size=(24,)).astype(np.float32),
    }
    specs = {"w": P("data", None), "b": P("data")}
    old = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(_data_mesh(n_old), s)),
        tree_np, specs)
    new_mesh = _data_mesh(n_new)
    out = reshard_state(old, new_mesh, specs)
    for key in tree_np:
        _assert_matches_fresh_shard(out[key], tree_np[key],
                                    new_mesh, specs[key])


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 virtual devices (conftest)")
def test_reshard_state_non_dividing_shard_sizes():
    """Old and new shard sizes that do NOT divide each other (12 rows:
    3-row shards over 4 devices -> 2-row shards over 6): every shard
    boundary moves, so the reshard is a genuine all-to-all, and the
    result still matches the fresh placement bitwise."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = np.arange(12 * 5, dtype=np.float32).reshape(12, 5)
    old = jax.device_put(x, NamedSharding(_data_mesh(4), P("data", None)))
    new_mesh = _data_mesh(6)
    out = reshard_state(old, new_mesh, P("data", None))
    _assert_matches_fresh_shard(out, x, new_mesh, P("data", None))
