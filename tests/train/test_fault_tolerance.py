"""Fault tolerance: restart-from-checkpoint continues the exact trajectory;
straggler detection; elastic re-shard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.models import build_model
from repro.train.fault_tolerance import (
    FailureInjector, SimulatedFailure, StragglerMonitor, reshard_state,
)
from repro.train.train_loop import Trainer

TINY_SHAPE = ShapeConfig("tiny", 16, 4, "train")


def _run_cfg(tmp_path, steps=6, **kw):
    return RunConfig(
        model=get_config("stablelm-1.6b").reduced(),
        shape=TINY_SHAPE,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=100),
        steps=steps,
        checkpoint_dir=str(tmp_path / "ckpt"),
        checkpoint_every=2,
        async_checkpoint=False,
        log_every=0,
        **kw,
    )


def test_loss_decreases(tmp_path):
    cfg = _run_cfg(tmp_path, steps=8)
    model = build_model(cfg.model)
    res = Trainer(model, cfg).run()
    assert len(res.losses) == 8
    assert res.losses[-1] < res.losses[0], res.losses


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Injected failure + restore: final state identical to a clean run."""
    model = build_model(_run_cfg(tmp_path).model)

    clean_cfg = _run_cfg(tmp_path / "clean", steps=6)
    clean = Trainer(model, clean_cfg).run()

    faulty_cfg = _run_cfg(tmp_path / "faulty", steps=6)
    injector = FailureInjector(fail_at_steps=(3,))
    faulty = Trainer(model, faulty_cfg, injector=injector).run()

    assert faulty.restarts == 1
    # the replayed trajectory must converge to the same final state
    np.testing.assert_allclose(faulty.checksum, clean.checksum, rtol=1e-6)
    # last loss identical (deterministic data + exact state restore)
    np.testing.assert_allclose(faulty.losses[-1], clean.losses[-1], rtol=1e-5)


def test_injector_raises_once_per_step():
    inj = FailureInjector(fail_at_steps=(2,))
    inj.check(1)
    with pytest.raises(SimulatedFailure):
        inj.check(2)
    inj.check(2)  # second pass after restart: no refire


def test_injector_phase_filter_and_keying():
    """With ``phases`` set only tagged chaos points may fire, and the
    dedup key is (step, phase): the same step's OTHER phases still pass
    after a fire."""
    inj = FailureInjector(fail_at_steps=(2,), phases=("mid-exchange",))
    inj.check(2)  # untagged check at a fail step: filtered, no fire
    inj.check(2, phase="pre-step")  # unlisted phase: filtered
    with pytest.raises(SimulatedFailure):
        inj.check(2, phase="mid-exchange")
    inj.check(2, phase="mid-exchange")  # replay after restart: deduped
    # a later fail step still fires on its own key
    inj2 = FailureInjector(fail_at_steps=(2, 5), phases=("mid-exchange",))
    with pytest.raises(SimulatedFailure):
        inj2.check(2, phase="mid-exchange")
    with pytest.raises(SimulatedFailure):
        inj2.check(5, phase="mid-exchange")


def test_injector_probability_path_is_deterministic_and_dedups():
    """The probability path is seeded by (seed, step, phase) — two
    injectors agree on WHICH steps fail — and records fires in ``_fired``
    so a restart replaying the same step never refires (without the dedup
    the deterministic seeding would re-kill the resumed run forever)."""

    def fired_steps(inj, n=64):
        fired = []
        for step in range(n):
            try:
                inj.check(step, phase="mid-exchange")
            except SimulatedFailure:
                fired.append(step)
        return fired

    a = fired_steps(FailureInjector(probability=0.25, seed=7))
    b = fired_steps(FailureInjector(probability=0.25, seed=7))
    assert a == b and a, "seeded probability path must fire reproducibly"
    # replaying the exact same steps on the SAME injector: all deduped
    inj = FailureInjector(probability=0.25, seed=7)
    first = fired_steps(inj)
    assert first == a
    assert fired_steps(inj) == [], "restart replay must not refire"
    assert {(s, "mid-exchange") for s in a} <= inj._fired
    # phase participates in the draw: a different phase is an independent
    # (but still deterministic) failure pattern
    c = fired_steps(FailureInjector(probability=0.25, seed=7, phases=()))
    d = []
    inj_d = FailureInjector(probability=0.25, seed=7)
    for step in range(64):
        try:
            inj_d.check(step, phase="plan-build:round")
        except SimulatedFailure:
            d.append(step)
    assert c != d  # the crc32 phase salt separates the streams


def test_injector_disabled_never_fires():
    inj = FailureInjector(fail_at_steps=(0, 1), probability=1.0,
                          enabled=False)
    for step in range(4):
        inj.check(step, phase="mid-exchange")
    assert not inj._fired


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(ewma=0.5, factor=2.0)
    hits = []
    mon.on_straggler = lambda s, t, m: hits.append(s)
    for step in range(10):
        mon.observe(step, 0.1)
    assert not mon.flagged
    assert mon.observe(10, 0.5)  # 5x the mean
    assert mon.flagged and hits == [10]
    # outlier must not poison the mean
    assert not mon.observe(11, 0.1)


def test_elastic_reshard_roundtrip():
    """Re-mesh a state onto a different (here: trivial) mesh layout."""
    from jax.sharding import Mesh, PartitionSpec as P

    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    out = reshard_state(state, mesh, {"w": P(None, None)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
