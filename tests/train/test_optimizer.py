"""AdamW vs numpy reference; schedule, clipping, compression properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.configs.base import OptimizerConfig
from repro.train.optimizer import (
    adamw_update, clip_by_global_norm, compress_grads, global_norm,
    init_opt_state, lr_schedule,
)


def _np_adamw(p, g, m, v, step, cfg):
    m = cfg.beta1 * m + (1 - cfg.beta1) * g
    v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
    mhat = m / (1 - cfg.beta1 ** step)
    vhat = v / (1 - cfg.beta2 ** step)
    lr = float(lr_schedule(cfg, jnp.asarray(step)))
    delta = mhat / (np.sqrt(vhat) + cfg.eps)
    if p.ndim >= 2:
        delta = delta + cfg.weight_decay * p
    return p - lr * delta, m, v


def test_adamw_matches_numpy_reference():
    cfg = OptimizerConfig(lr=1e-2, grad_clip=1e9, warmup_steps=0, total_steps=100)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    opt = init_opt_state(params, cfg)
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    for step in range(1, 4):
        grads = {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                 "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
        params, opt, metrics = adamw_update(params, grads, opt, cfg)
        for k in np_p:
            np_p[k], np_m[k], np_v[k] = _np_adamw(
                np_p[k], np.asarray(grads[k]), np_m[k], np_v[k], step, cfg)
        for k in np_p:
            np.testing.assert_allclose(np.asarray(params[k]), np_p[k],
                                       rtol=1e-5, atol=1e-6, err_msg=f"{k}@{step}")


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((6,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    end = float(lr_schedule(cfg, jnp.asarray(100)))
    assert abs(end - 0.1) < 1e-5
    mid = float(lr_schedule(cfg, jnp.asarray(55)))
    assert 0.1 < mid < 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_int8_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    out = compress_grads(g, "int8_stochastic", jax.random.key(seed))
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"]))
    assert err.max() <= scale + 1e-6  # one quantization bin


def test_bf16_compression_halves_width():
    g = {"w": jnp.ones((8,), jnp.float32)}
    out = compress_grads(g, "bf16")
    assert out["w"].dtype == jnp.bfloat16


def test_bf16_opt_state():
    cfg = OptimizerConfig()
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    opt = init_opt_state(params, cfg, "bfloat16")
    assert opt["m"]["w"].dtype == jnp.bfloat16
    params2, opt2, _ = adamw_update(params, {"w": jnp.ones((4, 4), jnp.bfloat16)},
                                    opt, cfg)
    assert opt2["v"]["w"].dtype == jnp.bfloat16
    assert params2["w"].dtype == jnp.bfloat16
