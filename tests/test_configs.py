"""Config registry: all 10 assigned archs, shape cells, skip rules."""

import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, get_config


def test_all_ten_archs_registered():
    cfgs = all_configs()
    for arch in ARCH_IDS:
        assert arch in cfgs, arch
    assert len(ARCH_IDS) == 10


def test_shape_cells_and_skips():
    """DESIGN.md §4: 31 live cells of the 40 (9 skips per assignment)."""
    live = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = {s.name for s in cfg.shapes()}
        skips = dict(cfg.skipped_shapes())
        assert shapes.isdisjoint(skips)
        assert len(shapes) + len(skips) == 4
        live += len(shapes)
        if cfg.is_encoder_only:
            assert "decode_32k" in skips and "long_500k" in skips
        elif not cfg.supports_long_context:
            assert "long_500k" in skips
        else:
            assert "long_500k" in shapes
    assert live == 31


def test_long_context_archs():
    assert get_config("rwkv6-1.6b").supports_long_context
    assert get_config("zamba2-1.2b").supports_long_context
    assert not get_config("llama3-8b").supports_long_context


def test_assigned_dimensions_exact():
    """Spot-check the assigned architecture dimensions (from the pool)."""
    spec = {
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab_size=65536),
        "llama-3.2-vision-11b": dict(n_layers=40, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=14336, vocab_size=128256),
        "qwen2.5-14b": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
                            d_ff=13824, vocab_size=152064, qkv_bias=True),
        "llama3-8b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
                          d_ff=14336, vocab_size=128256),
        "granite-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                           d_ff=14336, vocab_size=49152),
        "stablelm-1.6b": dict(n_layers=24, d_model=2048, n_heads=32,
                              n_kv_heads=32, d_ff=5632, vocab_size=100352),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=6400, vocab_size=32064,
                                     n_experts=16, top_k=2),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
                            d_ff=32768, vocab_size=131072, n_experts=8, top_k=2),
        "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                              n_kv_heads=16, d_ff=5120, vocab_size=504),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32,
                            n_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_shape_set():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_reduced_configs_small():
    for arch in ARCH_IDS:
        r = get_config(arch).reduced()
        assert r.d_model <= 64 and r.vocab_size <= 128
        assert r.param_count() < 5e6


def test_config_hashable_and_frozen():
    cfg = get_config("llama3-8b")
    hash(cfg)
    with pytest.raises(Exception):
        cfg.n_layers = 1  # type: ignore[misc]
