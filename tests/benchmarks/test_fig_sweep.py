"""The fig_sweep figures section: BENCH_*.json round-trip and row shape.

Synthesizes a small multi-cell sweep record set (no measurement — schema
only), round-trips it through the ``BENCH_*.json`` interchange format, and
validates what ``benchmarks.figures.fig_sweep`` emits: one row per
(strategy, cell), finite speedups, a baseline present in every cell, curve
points along all four sweep axes (devices/parts/msgsize + the transport
layer's packer axis), the raw-latency overlays of fused/overlap against the
paper trio at the larger message sizes, and the Fig. 6-8 paper-claim
comparisons.
"""

import json
import math

import pytest

from benchmarks.figures import SWEEP_CLAIMS, fig_sweep, load_sweep_records
from repro.stencil.sweep import RECORD_KEYS, SCHEMA_VERSION, write_bench_json

STRATEGIES = ("standard", "persistent", "partitioned", "fused", "overlap")


#: wire bytes-per-element of the synthesized packers (f32 faces)
_WIRE_ITEMSIZE = {"slice": 4, "pallas": 4, "bf16": 2, "scaled-int8": 1}


def _record(strategy, n_devices, size, n_parts, us, base_us,
            packer="slice", coalesce=False, selected_by=None):
    return {
        "bench": "stencil_sweep",
        "schema_version": SCHEMA_VERSION,
        "strategy": strategy,
        "n_devices": n_devices,
        "n_parts": n_parts,
        "packer": packer,
        "transport": "ppermute",
        "coalesce": coalesce,
        "process_count": 1,
        "is_multihost": False,
        "mapping": "row-major",
        "node_size": max(1, n_devices // 2),
        "intra_node_sends": n_parts,
        "inter_node_sends": n_parts,
        "global_interior": list(size),
        "mesh_shape": [n_devices],
        "message_bytes": size[1] * 4,
        "wire_bytes": size[1] * _WIRE_ITEMSIZE[packer],
        "us_per_cycle": us,
        "collective_count": (n_parts if coalesce else 2 * n_parts),
        "plan_cache_inits": 0 if strategy == "standard" else 1,
        "plan_cache_hits": 0,
        "init_us": 0.0 if strategy == "standard" else 120.0,
        "replan_us": 0.0 if strategy == "standard" else 15.0,
        "plan_cache_invalidations": 0,
        "selected_by": selected_by,
        "predicted_us": us if selected_by else None,
        "calibration_us": 0.0,
        "recovery_mode": "none",
        "join_us": 0.0,
        "warm_ranks": 0,
        "n_cycles": 3,
        "repeats": 1,
        "checksum": 0.25,
        "speedup_vs_baseline": base_us / us,
    }


def _synth_records():
    """Two device counts x two sizes x three packers (one wire-compressed)
    x both coalesce modes; partitioned at p=1,2."""
    records = []
    for n_devices in (2, 4):
        for size in ((16, 8), (32, 16)):
            base_us = 100.0 * n_devices
            for coalesce, cgain in ((False, 1.0), (True, 1.2)):
                for pk, gain in (("slice", 1.0), ("pallas", 1.25),
                                 ("bf16", 1.5)):
                    gain = gain * cgain
                    records.append(
                        _record("standard", n_devices, size, 1,
                                base_us / gain, base_us, pk, coalesce)
                    )
                    for i, s in enumerate(("persistent", "fused", "overlap")):
                        records.append(
                            _record(s, n_devices, size, 1,
                                    base_us / (2 + i) / gain, base_us, pk,
                                    coalesce)
                        )
                    for p in (1, 2):
                        records.append(
                            _record("partitioned", n_devices, size, p,
                                    base_us / (3 + p) / gain, base_us, pk,
                                    coalesce)
                        )
    return records


@pytest.fixture()
def emitted():
    rows = []
    out = fig_sweep(
        lambda name, us, derived="": rows.append((name, us, derived)),
        records=_synth_records(),
    )
    return rows, out


def test_synth_records_carry_the_sweep_schema():
    for rec in _synth_records():
        assert set(RECORD_KEYS) <= set(rec)


def test_bench_json_roundtrip_feeds_fig_sweep(tmp_path):
    records = _synth_records()
    path = tmp_path / "BENCH_fig_sweep.json"
    write_bench_json(records, str(path))
    assert load_sweep_records(str(path)) == records
    rows = []
    out = fig_sweep(lambda *a: rows.append(a), sweep_path=str(path))
    assert len(out["rows"]) == len(records)


def test_missing_sweep_file_is_a_clear_error(tmp_path):
    with pytest.raises(FileNotFoundError, match="repro.stencil.sweep"):
        load_sweep_records(str(tmp_path / "BENCH_none.json"))


def test_one_row_per_strategy_cell(emitted):
    _, out = emitted
    records = _synth_records()
    assert len(out["rows"]) == len(records)
    names = [name for name, _, _ in out["rows"]]
    assert len(names) == len(set(names))  # (strategy, cell) keys are unique
    # and each row's name encodes the full cell coordinate incl. packer
    # and coalesce mode
    for name in names:
        _, d, p, m, packer, coal, strategy = name.split("/")
        assert strategy in STRATEGIES
        assert packer in ("slice", "pallas", "bf16")
        assert coal in ("c0", "c1")
        assert d.startswith("d") and p.startswith("p") and m.startswith("m")


def test_no_nan_speedups(emitted):
    _, out = emitted
    for _, _, pct in out["rows"]:
        assert math.isfinite(pct)
    for curve in out["curves"].values():
        assert curve, "empty curve axis"
        for pct in curve.values():
            assert math.isfinite(pct)


def test_curves_cover_all_seven_sweep_axes(emitted):
    _, out = emitted
    assert set(out["curves"]) == {
        "devices", "parts", "msgsize", "packer", "wirebytes", "coalesce",
        "mapping",
    }
    # synth records predate the mapping field -> one identity-placement
    # point per strategy (incl. the baseline: placement is a baseline-
    # inclusive axis like packer/coalesce)
    assert {m for _, m in out["curves"]["mapping"]} == {"row-major"}
    assert {d for _, d in out["curves"]["devices"]} == {2, 4}
    # the partition axis reaches 2 only for the partitioning strategy
    assert ("partitioned", 2) in out["curves"]["parts"]
    assert ("fused", 2) not in out["curves"]["parts"]
    # the baseline never gets a point on the paper's three axes (its
    # speedup is 1 by definition)...
    for axis in ("devices", "parts", "msgsize"):
        assert all(s != "standard" for s, _ in out["curves"][axis])
    # ...but DOES on the packer axis: standard@pallas vs standard@slice is
    # the packing effect itself (best across coalesce modes: the coalesced
    # slice cell carries the 20% synthetic coalescing gain)
    packer_curve = out["curves"]["packer"]
    assert {pk for _, pk in packer_curve} == {"slice", "pallas", "bf16"}
    assert packer_curve[("standard", "slice")] == pytest.approx(20.0)
    assert packer_curve[("standard", "pallas")] > 20.0


def test_coalesce_axis_isolates_aggregation_gain(emitted):
    """The coalesce curve separates the aggregation effect: each strategy's
    coalesced point beats its uncoalesced one by the synthetic 1.2x gain
    (the best standard cells are bf16-packed: +50% -> +80%)."""
    _, out = emitted
    coalesce_curve = out["curves"]["coalesce"]
    assert {c for _, c in coalesce_curve} == {False, True}
    assert coalesce_curve[("standard", False)] == pytest.approx(50.0)
    assert coalesce_curve[("standard", True)] == pytest.approx(80.0)
    for strategy in STRATEGIES:
        assert coalesce_curve[(strategy, True)] > coalesce_curve[
            (strategy, False)
        ], strategy


def test_amortization_rows_render_counters(emitted):
    """Plan-cache hit/miss counters and per-cell collective counts reach
    the rendered output (the persistent-amortization evidence rows)."""
    rows, out = emitted
    amort = out["amortization"]
    assert len(amort) == len(_synth_records())
    for name, inits, hits, colls in amort:
        assert name.startswith("fig_sweep/amortization/")
        assert inits in (0, 1) and hits == 0
        assert isinstance(colls, int) and colls > 0
    emitted_amort = [r for r in rows if "/amortization/" in r[0]]
    assert len(emitted_amort) == len(amort)
    for _, _, derived in emitted_amort:
        assert derived.startswith("plan_inits=")
        assert "collectives=" in derived
    # legacy records (no counters) render no amortization rows
    legacy = [dict(r) for r in _synth_records()]
    for r in legacy:
        del r["plan_cache_inits"], r["plan_cache_hits"]
        del r["collective_count"]
    out2 = fig_sweep(lambda *a: None, records=legacy)
    assert out2["amortization"] == []


def test_wire_bytes_axis_tracks_compression(emitted):
    """The wirebytes curve separates the compressed wire format (bf16 at
    half the face bytes) from the exact packers at the full face size."""
    _, out = emitted
    wire_curve = out["curves"]["wirebytes"]
    coords = {w for _, w in wire_curve}
    # faces are 8*4 and 16*4 logical bytes; bf16 adds the halved 16-byte
    # point (its large-face wire of 32 coincides with the small slice face)
    assert coords == {16, 32, 64}
    # the 16-byte point exists ONLY via the compressed wire, and carries
    # standard@bf16's gain over the uncompressed baseline (best across
    # coalesce modes: 1.5 packing x 1.2 coalescing -> +80%)
    assert wire_curve[("standard", 16)] == pytest.approx(80.0)
    # pre-compression records (no wire_bytes key) fall back to message_bytes
    legacy = [dict(r) for r in _synth_records()]
    for r in legacy:
        del r["wire_bytes"]
    out2 = fig_sweep(lambda *a: None, records=legacy)
    assert {w for _, w in out2["curves"]["wirebytes"]} == {32, 64}


def test_raw_latency_overlays_at_larger_sizes(emitted):
    """ROADMAP item: absolute fused/overlap times overlaid on the trio at
    the larger message sizes — not just speedup curves."""
    _, out = emitted
    assert out["raw"], "no raw-latency overlay rows"
    sizes = {int(name.split("/")[2][1:]) for name, _, _ in out["raw"]}
    all_sizes = {r["message_bytes"] for r in _synth_records()}
    assert sizes == {max(all_sizes)}  # only the upper half of 2 sizes
    strategies = {s for _, _, s in out["raw"]}
    assert {"fused", "overlap"} <= strategies  # overlaid on...
    assert {"standard", "persistent", "partitioned"} <= strategies  # ...the trio
    for name, us, _ in out["raw"]:
        assert name.startswith("fig_sweep/raw/m")
        assert math.isfinite(us) and us > 0


def test_claims_compare_measured_to_paper(emitted):
    _, out = emitted
    assert len(out["claims"]) == len(SWEEP_CLAIMS)
    for cid, desc, paper_pct, measured in out["claims"]:
        assert measured is not None and math.isfinite(measured)
        assert math.isfinite(paper_pct)


def test_baseline_required_in_every_cell():
    records = [r for r in _synth_records() if r["strategy"] != "standard"]
    with pytest.raises(AssertionError, match="baseline"):
        fig_sweep(lambda *a: None, records=records)


def test_emitted_rows_are_csv_safe(emitted):
    rows, _ = emitted
    assert rows
    for name, us, derived in rows:
        assert "," not in name and "," not in derived
        json.dumps(derived)


# ---------------------------------------------------------------------------
# the autotune-vs-static comparison section
# ---------------------------------------------------------------------------


def _with_autos():
    """The static grid plus one autotuned record per (devices, size) cell,
    matching the best static cell (the tuner's contract)."""
    records = _synth_records()
    best: dict[tuple, dict] = {}
    for r in records:
        key = (r["n_devices"], tuple(r["global_interior"]))
        if (key not in best
                or r["us_per_cycle"] < best[key]["us_per_cycle"]):
            best[key] = r
    for (n_devices, size), b in sorted(best.items()):
        records.append(
            _record(b["strategy"], n_devices, list(size), b["n_parts"],
                    b["us_per_cycle"], b["us_per_cycle"]
                    * b["speedup_vs_baseline"], b["packer"], b["coalesce"],
                    selected_by="trace")
        )
    return records


@pytest.fixture()
def emitted_auto():
    rows = []
    out = fig_sweep(
        lambda name, us, derived="": rows.append((name, us, derived)),
        records=_with_autos(),
    )
    return rows, out


def test_autotune_section_compares_against_static_envelope(emitted_auto):
    """One autotune entry per tuned cell, carrying the auto speedup next to
    the best/worst static cells it chose between."""
    rows, out = emitted_auto
    autos = [r for r in _with_autos() if r.get("selected_by")]
    assert len(out["autotune"]) == len(autos) == 4
    for entry in out["autotune"]:
        assert entry["selected_by"] == "trace"
        assert entry["strategy"] in STRATEGIES
        assert entry["worst_static_pct"] <= entry["best_static_pct"]
        # the synthetic tuner picked the oracle cell exactly
        assert entry["auto_pct"] == pytest.approx(entry["best_static_pct"])
    emitted_rows = [r for r in rows if r[0].startswith("fig_sweep/autotune/")]
    assert len(emitted_rows) == len(out["autotune"])
    for name, us, derived in emitted_rows:
        assert math.isfinite(us) and us > 0
        assert "auto=" in derived and "best_static=" in derived
        assert "selected_by=trace" in derived
        assert "," not in name and "," not in derived


def test_autotuned_records_stay_out_of_static_curves(emitted_auto):
    """Auto records are selection outcomes, not measurements: every curve,
    claim, and raw overlay must be identical with and without them."""
    _, out = emitted_auto
    out_static = fig_sweep(lambda *a: None, records=_synth_records())
    assert out["curves"] == out_static["curves"]
    assert out["claims"] == out_static["claims"]
    assert out["raw"] == out_static["raw"]
    assert out_static["autotune"] == []


def test_autotuned_rows_carry_the_auto_tag(emitted_auto):
    """Tuned cells render as `auto:<resolved strategy>` rows — same arity,
    never colliding with the identical static cell's row."""
    _, out = emitted_auto
    assert len(out["rows"]) == len(_with_autos())
    names = [name for name, _, _ in out["rows"]]
    assert len(names) == len(set(names))
    tagged = [n for n in names if n.split("/")[-1].startswith("auto:")]
    assert len(tagged) == 4
    for name in tagged:
        _, d, p, m, packer, coal, strategy = name.split("/")
        assert strategy.removeprefix("auto:") in STRATEGIES
