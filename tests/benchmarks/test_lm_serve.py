"""The lm_serve bench section: record schema, static wire accounting, and
the --check guard semantics.

No serving runs here — records are synthesized (or derived from the static
``ring_comm_stats`` accounting, which needs no mesh) and pushed through the
same ``check_records`` path CI uses, so a schema drift or a guard that stops
failing on tampered baselines is caught in the fast lane.
"""

import json

import jax.numpy as jnp
import pytest

from repro.core.transport import get_packer
from repro.serving.bench import (
    BENCH_NAME,
    CELLS,
    RECORD_KEYS,
    SCHEMA_VERSION,
    STATIC_KEYS,
    check_records,
    ring_comm_stats,
)


def _record(packer="slice", coalesce=True, selected_by="", **over):
    stats = ring_comm_stats(
        seq_bucket=16, ring=8, n_layers=2, n_kv_heads=2, head_dim=32,
        dtype_bytes=4, packer=packer, coalesce=coalesce, n_parts=1)
    rec = {
        "bench": BENCH_NAME,
        "schema_version": SCHEMA_VERSION,
        "strategy": "ring-messages",
        "arch": "stablelm-1.6b-reduced",
        "n_devices": 8,
        "n_parts": 1,
        "packer": packer,
        "transport": "ppermute",
        "coalesce": coalesce,
        "mapping": "row-major",
        "seq_bucket": 16,
        "tokens_generated": 48,
        "decode_steps": 25,
        "prefills": 6,
        "plan_cache_inits": 2,
        "plan_cache_hits": 25,
        "selected_by": selected_by,
        "tokens_per_sec": 12.5,
        "us_per_cycle": 8000.0,
        **stats,
    }
    rec.update(over)
    return rec


def _baseline(tmp_path, records):
    path = tmp_path / "BENCH_lm_serve.json"
    path.write_text(json.dumps({"config": {}, "records": records}))
    return str(path)


def test_record_keys_cover_the_schema():
    rec = _record()
    assert set(rec) == set(RECORD_KEYS)
    # the wall-clock fields are exactly the non-static remainder
    assert set(RECORD_KEYS) - set(STATIC_KEYS) == {
        "tokens_per_sec", "us_per_cycle"}


def test_swept_cells_never_auto_lossy():
    # the lossy packer is swept explicitly but can't win the auto cell
    assert ("bf16", True) in CELLS
    for packer, _ in CELLS:
        tol = get_packer(packer).wire_tolerance(jnp.float32)
        assert packer == "bf16" or tol == (0.0, 0.0)


def test_ring_comm_stats_matches_message_algebra():
    # 2 (K,V) x seq 16/8 x 2 kv-heads x 32 head_dim x f32 = 2048 B per hop
    # per layer; 7 hops x 2 layers; coalesced = one collective per hop
    stats = ring_comm_stats(
        seq_bucket=16, ring=8, n_layers=2, n_kv_heads=2, head_dim=32,
        dtype_bytes=4, packer="slice", coalesce=True, n_parts=1)
    assert stats["message_bytes"] == 2 * 2 * 2 * 32 * 4 * 7 * 2
    assert stats["wire_bytes"] == stats["message_bytes"]
    assert stats["collective_count"] == 7 * 2
    un = ring_comm_stats(
        seq_bucket=16, ring=8, n_layers=2, n_kv_heads=2, head_dim=32,
        dtype_bytes=4, packer="slice", coalesce=False, n_parts=1)
    assert un["collective_count"] == 2 * 7 * 2  # K and V permute separately
    bf = ring_comm_stats(
        seq_bucket=16, ring=8, n_layers=2, n_kv_heads=2, head_dim=32,
        dtype_bytes=4, packer="bf16", coalesce=True, n_parts=1)
    assert bf["wire_bytes"] == stats["wire_bytes"] // 2
    assert bf["message_bytes"] == stats["message_bytes"]


def test_check_passes_on_matching_records(tmp_path):
    records = [_record("slice", False), _record("slice", True),
               _record("bf16", True), _record("slice", True,
                                              selected_by="trace")]
    path = _baseline(tmp_path, records)
    # a fresh run only has to match the static fields; wall clock may drift
    fresh = [dict(r, tokens_per_sec=99.0, us_per_cycle=1.0) for r in records]
    assert check_records(fresh, path) == []


def test_check_fails_on_tampered_static_field(tmp_path):
    path = _baseline(tmp_path, [_record("slice", True)])
    drifted = _record("slice", True, plan_cache_inits=5)
    failures = check_records([drifted], path)
    assert len(failures) == 1 and "plan_cache_inits" in failures[0]

    wire = _record("slice", True)
    wire["wire_bytes"] += 1
    assert any("wire_bytes" in f for f in check_records([wire], path))


def test_check_fails_on_unknown_cell_and_bad_wallclock(tmp_path):
    path = _baseline(tmp_path, [_record("slice", True)])
    missing = _record("bf16", True)
    assert any("not in baseline" in f for f in check_records([missing], path))
    stalled = _record("slice", True, tokens_per_sec=0.0)
    assert any("tokens_per_sec" in f for f in check_records([stalled], path))


def test_committed_baseline_is_well_formed():
    # the repo-root baseline CI guards against: right bench, full schema,
    # the swept cells plus the trace-replay cell, flat plan inits
    from repro.stencil.sweep import read_bench_json

    records, config = read_bench_json("BENCH_lm_serve.json")
    assert config.get("bench") == BENCH_NAME
    cells = {(r["packer"], r["coalesce"], r["selected_by"]) for r in records}
    assert {(p, c, "") for p, c in CELLS} <= cells
    assert any(sel == "trace" for _, _, sel in cells)
    for r in records:
        assert set(RECORD_KEYS) <= set(r)
        assert r["plan_cache_inits"] == 2  # one bucketed prefill + one decode
        assert r["tokens_per_sec"] > 0
