"""In-grid recovery chaos check over the REAL membership wire.

The in-process unit tests (tests/stencil/test_elastic.py) drive the
MembershipService directly; this program is the CI leg that routes every
membership operation through a live localhost TCP coordinator
(MembershipServer + MembershipClient) — the same wire a multi-process
grid would use — and holds the phase-2 acceptance criteria:

- a mid-exchange rank loss under ``recovery_mode="in-grid"`` shrinks the
  mesh WITHOUT a relaunch: the run resumes in the same process;
- survivors stay WARM — an unrelated pre-warmed plan stays resident in
  the cache, the invalidation is surgical (exactly the dead topology's
  epoch-stamped plan), and ``plan_cache_inits`` keeps growing instead of
  resetting to zero;
- the resumed trajectory is bitwise equal to the 1-device oracle
  (exact-wire packer);
- the BENCH row lands on disk for the artifact upload.
"""

import os

# 8 virtual host devices, pinned BEFORE jax initializes (standalone
# program: the repo conftest does this for pytest, not for us)
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import json
import sys
import tempfile

import jax
import numpy as np

from repro.launch.elastic import ElasticConfig, ElasticStencilRunner
from repro.launch.membership import (
    MembershipClient,
    MembershipServer,
    MembershipService,
)
from repro.train.fault_tolerance import FailureInjector

BENCH_VAR = "REPRO_ELASTIC_BENCH"
FAIL_STEP = 3

PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


def prewarm_unrelated_plan(cache):
    """An epoch-FREE persistent plan for an unrelated geometry — the
    warmth probe in-grid recovery must leave resident."""
    from repro.core.compat import make_mesh
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import StrategyConfig, make_driver

    mesh = make_mesh((2,), ("px",), devices=jax.devices()[:2])
    dom = Domain(mesh, global_interior=(8, 4), mesh_axes=("px", None),
                 halo=1)
    drv = make_driver(
        StrategyConfig(name="persistent", plan_cache=cache),
        mesh, dom.halo_spec, ndim=2,
    )
    drv.init(jax.ShapeDtypeStruct(dom.stored_global, np.dtype(dom.dtype),
                                  sharding=dom.sharding()))
    drv.free()
    return set(cache.keys())


cfg = ElasticConfig(
    global_interior=(16, 8), n_steps=6, checkpoint_every=1,
    recovery_mode="in-grid", heartbeat_timeout=30.0,
)

svc = MembershipService(heartbeat_timeout=cfg.heartbeat_timeout)
with MembershipServer(svc) as srv:
    cli = MembershipClient(srv.address, timeout=10.0)
    runner = ElasticStencilRunner(
        cfg, tempfile.mkdtemp(prefix="elastic_ingrid_ckpt_"),
        injector=FailureInjector(fail_at_steps=(FAIL_STEP,),
                                 phases=("mid-exchange",)),
        devices=jax.devices()[:4],
        membership=cli,  # every membership op crosses the TCP wire
    )
    warm_keys = prewarm_unrelated_plan(runner.cache)
    inits_before = runner.cache.stats.inits
    result = runner.run()
    # the coordinator's view (read fresh over the wire) agrees with the
    # runner's adopted epoch: one "loss" bump, two members evicted
    view = cli.view()
    assert view.epoch == 1 and view.cause == "loss", view
    assert len(view.members) == 2, view

assert result.recovery_mode == "in-grid"
assert [e.cause for e in result.events] == ["initial", "loss-ingrid"], (
    result.events)
assert (result.events[0].n_devices, result.events[1].n_devices) == (4, 2)
assert result.final_epoch == 1, result.final_epoch
ok("mid-exchange loss recovered IN-GRID over the TCP wire "
   "(4 -> 2 devices, epoch 0 -> 1, no relaunch)")

assert result.warm_ranks == 2, result.warm_ranks
assert result.events[1].plan_invalidations == 1, result.events
assert result.plan_cache_invalidations == 1, result.plan_cache_invalidations
assert warm_keys <= set(runner.cache.keys()), "pre-warmed plan was dropped"
assert result.plan_cache_inits == inits_before + 2, (
    result.plan_cache_inits, inits_before)
ok("survivors stayed warm: unrelated plan retained, exactly one "
   "epoch-stale invalidation, init counter monotone")

oracle = ElasticStencilRunner(
    dataclasses.replace(cfg, checkpoint_every=0, recovery_mode="relaunch"),
    None, devices=jax.devices()[:1],
).run()
assert np.array_equal(result.final_interior, oracle.final_interior), (
    "in-grid resumed run diverged from the single-device oracle"
)
ok("resumed trajectory bitwise == 1-device oracle")

bench_path = os.environ.get(BENCH_VAR, "BENCH_elastic_loss_ingrid.json")
rec = dict(result.bench_record(), mode="loss-ingrid")
with open(bench_path, "w") as f:
    json.dump(rec, f, indent=1)
    f.write("\n")
ok(f"BENCH row written to {bench_path}")

print(f"ALL {len(PASS)} ELASTIC-INGRID CHECKS PASSED")
sys.exit(0)
