"""Multi-device checks for the stencil substrate (8 fake CPU devices).

Verifies the full Comb-style loop: domain scatter -> N cycles of
(halo exchange + 27/9-point update) -> gather == periodic numpy oracle,
for all three strategies, 2-D and 3-D decompositions.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import make_mesh
from repro.core.halo import exchange  # noqa: F401 (import check)
from repro.kernels.stencil27 import jacobi_weights, stencil27_ref
from repro.stencil import Domain, comb_measure, periodic_oracle_step

PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


# --- 3-D domain on a (4, 2) mesh over (z, y); x undecomposed ------------------
mesh = make_mesh((4, 2), ("pz", "py"))
dom = Domain(mesh, global_interior=(16, 8, 6), mesh_axes=("pz", "py", None))

interior = np.random.default_rng(0).normal(size=(16, 8, 6)).astype(np.float32)
x = dom.from_global_interior(interior)
np.testing.assert_array_equal(dom.to_global_interior(x), interior)
ok("domain scatter/gather roundtrip")

w = np.asarray(jacobi_weights())
N_CYCLES = 5

# numpy oracle: N periodic update cycles
want = interior.copy()
for _ in range(N_CYCLES):
    want = periodic_oracle_step(want, w)


def update_fn(xl):
    """Local update: stencil the ghosted block interior, keep ghosts (stale)."""
    interior_new = stencil27_ref(xl, jnp.asarray(w))
    return jax.lax.dynamic_update_slice(xl, interior_new, (1, 1, 0))


# note: x-axis is undecomposed but periodic; the oracle wraps in x too, so we
# emulate the x-wrap locally inside the update by rolling ghosts... simpler:
# decompose only z,y and make x periodic via local pad in update.
def update_fn_xwrap(xl):
    # xl: (lz+2, ly+2, 6) — pad x periodically to (.., 8), stencil, write back
    xp = jnp.concatenate([xl[..., -1:], xl, xl[..., :1]], axis=-1)
    interior_new = stencil27_ref(xp, jnp.asarray(w))
    return jax.lax.dynamic_update_slice(xl, interior_new, (1, 1, 0))


results = comb_measure(
    dom, update_fn=update_fn_xwrap, n_parts=3, n_cycles=N_CYCLES, repeats=1,
    seed=0,
)
# comb_measure used random(seed=0) which re-derives the same interior
x2 = dom.random(0)
for strategy in ("standard", "persistent", "partitioned"):
    from repro.stencil import ExchangeDriver

    drv = ExchangeDriver(
        dom.mesh,
        lambda s=strategy: dom.halo_spec(s, 3 if s == "partitioned" else 1),
        ndim=3, strategy=strategy, update_fn=update_fn_xwrap,
    )
    y = dom.from_global_interior(interior)
    for _ in range(N_CYCLES):
        y = drv.step(y)
    got = dom.to_global_interior(drv.wait(y))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4, err_msg=strategy)
    drv.free()
ok(f"{N_CYCLES}-cycle Jacobi == periodic numpy oracle (3 strategies)")

# --- comb_measure returns consistent checksums and sane timings ---------------
assert all(r.us_per_cycle > 0 for r in results.values())
assert results["persistent"].init_us > 0
print("    measured us/cycle:",
      {s: round(r.us_per_cycle, 1) for s, r in results.items()})
ok("comb_measure checksums agree across strategies")

# --- 2-D domain, bigger partition counts --------------------------------------
mesh2 = make_mesh((8,), ("px",))
dom2 = Domain(mesh2, global_interior=(64, 32), mesh_axes=("px", None))
int2 = np.random.default_rng(1).normal(size=(64, 32)).astype(np.float32)
x2 = dom2.from_global_interior(int2)

from repro.stencil import ExchangeDriver

for strategy, parts in (("standard", 1), ("partitioned", 5)):
    drv = ExchangeDriver(
        dom2.mesh, lambda s=strategy, p=parts: dom2.halo_spec(s, p),
        ndim=2, strategy=strategy,
    )
    y = drv.wait(drv.step(dom2.from_global_interior(int2)))
    # ghosts of each shard must equal periodic neighbors
    got = np.asarray(y)
    blocks = got.reshape(8, 10, 32)
    for i in range(8):
        np.testing.assert_array_equal(blocks[i][0], blocks[(i - 1) % 8][-2],
                                      err_msg=f"{strategy} shard {i} low ghost")
        np.testing.assert_array_equal(blocks[i][-1], blocks[(i + 1) % 8][1],
                                      err_msg=f"{strategy} shard {i} high ghost")
    drv.free()
ok("1-axis decomposition ghost correctness (standard & partitioned)")

print(f"ALL {len(PASS)} STENCIL CHECKS PASSED")
