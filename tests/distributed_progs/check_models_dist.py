"""Multi-device model checks (8 fake CPU devices): the distributed execution
paths must match their single-device references.

* EP MoE (partitioned all-to-all dispatch)  == dense-dispatch MoE
* sequence-parallel prefill (ring attention) == local attention
* sequence-parallel SSM / RWKV (state passing + conv halo) == local scan
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import make_mesh, set_mesh

from repro.configs import get_config
from repro.models import build_model, concrete_batch
from repro.parallel.context import ParallelContext

PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


mesh = make_mesh((2, 4), ("data", "model"))

# --- EP MoE == dense MoE ------------------------------------------------------
cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
# 4 experts on a 4-way model axis; capacity factor high => no drops => paths equal
model = build_model(cfg)
params = model.init(jax.random.key(0))
batch = concrete_batch(cfg, 4, 32)

ctx_dense = ParallelContext(mesh=mesh, moe_mode="dense")
ctx_ep = ParallelContext(mesh=mesh, moe_mode="ep", n_parts=1)
ctx_ep_part = ParallelContext(mesh=mesh, moe_mode="ep", n_parts=3)

with set_mesh(mesh):
    want = jax.jit(lambda p, b: model.loss(p, b, ctx=ctx_dense))(params, batch)
    got = jax.jit(lambda p, b: model.loss(p, b, ctx=ctx_ep))(params, batch)
    got_part = jax.jit(lambda p, b: model.loss(p, b, ctx=ctx_ep_part))(params, batch)
np.testing.assert_allclose(float(got), float(want), rtol=2e-2, atol=2e-2)
np.testing.assert_allclose(float(got_part), float(got), rtol=2e-3, atol=2e-3)
ok("EP MoE (a2a, partitioned a2a) == dense dispatch")

# grok-style hidden-split slots (spe=2): 2 experts as 4 slots on 4 devices
cfg_g = get_config("grok-1-314b").reduced().with_updates(
    n_experts=2, top_k=1, ep_slots=4, capacity_factor=8.0, d_ff=64)
model_g = build_model(cfg_g)
params_g = model_g.init(jax.random.key(1))
batch_g = concrete_batch(cfg_g, 4, 16, seed=1)
with set_mesh(mesh):
    want = jax.jit(lambda p, b: model_g.loss(p, b, ctx=ctx_dense))(params_g, batch_g)
    got = jax.jit(lambda p, b: model_g.loss(p, b, ctx=ctx_ep))(params_g, batch_g)
np.testing.assert_allclose(float(got), float(want), rtol=2e-2, atol=2e-2)
ok("EP MoE hidden-split slots (spe=2, subgroup psum) == dense")

# --- sequence-parallel dense prefill (ring attention) -------------------------
cfg_d = get_config("llama3-8b").reduced()
model_d = build_model(cfg_d)
params_d = model_d.init(jax.random.key(2))
batch_d = concrete_batch(cfg_d, 4, 64, seed=2)
ctx_local = ParallelContext(mesh=mesh)
ctx_ring = ParallelContext(mesh=mesh, seq_parallel=True, n_parts=1)
ctx_ring_part = ParallelContext(mesh=mesh, seq_parallel=True, n_parts=2)
with set_mesh(mesh):
    want = jax.jit(lambda p, b: model_d.loss(p, b, ctx=ctx_local))(params_d, batch_d)
    got = jax.jit(lambda p, b: model_d.loss(p, b, ctx=ctx_ring))(params_d, batch_d)
    got2 = jax.jit(lambda p, b: model_d.loss(p, b, ctx=ctx_ring_part))(params_d, batch_d)
np.testing.assert_allclose(float(got), float(want), rtol=2e-2, atol=2e-2)
np.testing.assert_allclose(float(got2), float(want), rtol=2e-2, atol=2e-2)
ok("ring-attention prefill (fused + partitioned) == local attention")

# --- sequence-parallel zamba2 (conv halo + SSD state passing) -----------------
cfg_z = get_config("zamba2-1.2b").reduced()
model_z = build_model(cfg_z)
params_z = model_z.init(jax.random.key(3))
batch_z = concrete_batch(cfg_z, 4, 64, seed=3)
for method in ("ring", "tree"):
    ctx_sp = ParallelContext(mesh=mesh, seq_parallel=True, n_parts=2,
                             state_method=method)
    with set_mesh(mesh):
        want = jax.jit(lambda p, b: model_z.loss(p, b, ctx=ctx_local))(params_z, batch_z)
        got = jax.jit(lambda p, b: model_z.loss(p, b, ctx=ctx_sp))(params_z, batch_z)
    np.testing.assert_allclose(float(got), float(want), rtol=2e-2, atol=2e-2,
                               err_msg=method)
ok("seq-parallel zamba2 (conv halo + state passing ring/tree) == local")

# --- sequence-parallel rwkv6 ---------------------------------------------------
cfg_r = get_config("rwkv6-1.6b").reduced()
model_r = build_model(cfg_r)
params_r = model_r.init(jax.random.key(4))
batch_r = concrete_batch(cfg_r, 4, 64, seed=4)
ctx_sp = ParallelContext(mesh=mesh, seq_parallel=True)
with set_mesh(mesh):
    want = jax.jit(lambda p, b: model_r.loss(p, b, ctx=ctx_local))(params_r, batch_r)
    got = jax.jit(lambda p, b: model_r.loss(p, b, ctx=ctx_sp))(params_r, batch_r)
np.testing.assert_allclose(float(got), float(want), rtol=2e-2, atol=2e-2)
ok("seq-parallel rwkv6 (WKV state passing) == local scan")

# --- ring-TP (Megatron-SP on partitioned ring matmuls) == gspmd TP -----------
ctx_ringtp = ParallelContext(mesh=mesh, tp_mode="ring")
with set_mesh(mesh):
    want = jax.jit(lambda p, b: model_d.loss(p, b, ctx=ctx_local))(params_d, batch_d)
    got = jax.jit(lambda p, b: model_d.loss(p, b, ctx=ctx_ringtp))(params_d, batch_d)
    g = jax.jit(jax.grad(lambda p, b: model_d.loss(p, b, ctx=ctx_ringtp)))(
        params_d, batch_d)
np.testing.assert_allclose(float(got), float(want), rtol=2e-2, atol=2e-2)
for leaf in jax.tree.leaves(g):
    assert np.isfinite(np.asarray(leaf, np.float32)).all()
ok("ring-TP MLP (ring AG-matmul + matmul-RS) == gspmd TP, grads finite")

# --- grad flow under distributed contexts --------------------------------------
with set_mesh(mesh):
    g = jax.jit(jax.grad(lambda p, b: model_d.loss(p, b, ctx=ctx_ring)))(
        params_d, batch_d)
for leaf in jax.tree.leaves(g):
    assert np.isfinite(np.asarray(leaf, np.float32)).all()
ok("gradients finite through ring attention")

print(f"ALL {len(PASS)} MODEL-DIST CHECKS PASSED")
