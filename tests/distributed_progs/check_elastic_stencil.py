"""2-process elastic chaos check: a REAL grid killed mid-run and resumed.

The in-process chaos tests (tests/stencil/test_elastic.py) re-mesh inside
one process; this program exercises the grid form of the same contract.
A live ``jax.distributed`` rank cannot be dropped from its process grid,
so grid-mode recovery is a *relaunch*: the whole grid dies with the lost
rank, and the re-plan is booting the run again on the survivor topology.

Phase A — this file spawns a 2-rank grid of itself (``launch_grid`` with
``check=False``), every rank checkpointing each step, with a mid-exchange
failure injected at a fixed step (``max_replans=0``: the failure kills
the process, as a real node loss would).  The launcher asserts the grid
died AND that the last checkpoint committed before death survived.

Phase B — the launcher reboots the run as a single-process 2-device
"survivor" worker pointed at the same checkpoint directory.  The worker
resumes from the committed step, re-derives its transport tables for the
new topology, finishes the run, and holds the final interior to the
single-device oracle **bitwise** (exact-wire packer).

Dual-mode like the sibling check programs: grid workers are selected by
the ``REPRO_COORDINATOR`` env var, the resume worker by
``REPRO_ELASTIC_RESUME``; with neither set this file is the launcher.
"""

import os
import subprocess
import sys

CKPT_VAR = "REPRO_ELASTIC_CKPT"
FAIL_VAR = "REPRO_ELASTIC_FAIL_STEP"
RESUME_VAR = "REPRO_ELASTIC_RESUME"
BENCH_VAR = "REPRO_ELASTIC_BENCH"  # where phase B writes its BENCH row

FAIL_STEP = 3
N_STEPS = 6


def _config():
    from repro.launch.elastic import ElasticConfig

    # multihost transport in phase A (the exchange really crosses the
    # process boundary); the same cell resumes single-process in phase B
    return ElasticConfig(
        global_interior=(16, 8), n_steps=N_STEPS, checkpoint_every=1,
        strategy="persistent", packer="slice", transport="multihost",
        max_replans=0,
    )


if os.environ.get("REPRO_COORDINATOR") is not None:
    # ---- phase A worker: one rank of the doomed grid ----------------------
    from repro.launch.stencil import maybe_initialize_from_env

    RANK = maybe_initialize_from_env()

    import jax

    from repro.launch.elastic import ElasticStencilRunner
    from repro.train.fault_tolerance import FailureInjector

    assert jax.process_count() == 2, jax.process_count()
    runner = ElasticStencilRunner(
        _config(), os.environ[CKPT_VAR],
        injector=FailureInjector(
            fail_at_steps=(int(os.environ[FAIL_VAR]),),
            phases=("mid-exchange",),
        ),
        devices=jax.devices(),
    )
    # max_replans=0: the SimulatedFailure propagates and kills this rank —
    # the expected outcome; a clean exit here is the FAILURE mode
    runner.run()
    print(f"rank {RANK}: survived a run that should have died", flush=True)
    sys.exit(17)

if os.environ.get(RESUME_VAR) is not None:
    # ---- phase B worker: single-process survivor resumes the run ----------
    import dataclasses

    import jax
    import numpy as np

    from repro.launch.elastic import ElasticConfig, ElasticStencilRunner

    fail_step = int(os.environ[FAIL_VAR])
    cfg = _config()
    runner = ElasticStencilRunner(
        cfg, os.environ[CKPT_VAR], devices=jax.devices()[:2],
    )
    result = runner.run()
    assert result.steps == N_STEPS, result.steps
    assert result.replans == 0, result.replans
    # the one plan event is the survivor boot, picking up at the
    # checkpointed step with freshly derived tables for the new topology
    assert result.events[0].step == fail_step, result.events
    assert result.events[0].n_devices == 2, result.events
    assert result.events[0].replan_us > 0.0, result.events

    oracle = ElasticStencilRunner(
        dataclasses.replace(cfg, checkpoint_every=0), None,
        devices=jax.devices()[:1],
    ).run()
    assert np.array_equal(result.final_interior, oracle.final_interior), (
        "resumed run diverged from the single-device oracle"
    )
    if os.environ.get(BENCH_VAR):
        import json

        rec = dict(result.bench_record(), mode="loss-relaunch",
                   resumed_at=fail_step)
        with open(os.environ[BENCH_VAR], "w") as f:
            json.dump(rec, f, indent=1)
            f.write("\n")
    print(f"RESUME-BITWISE-OK resumed_at={fail_step} "
          f"replan_us={result.events[0].replan_us:.0f}", flush=True)
    sys.exit(0)

# ---- launcher -------------------------------------------------------------
import tempfile

from repro.launch.stencil import launch_grid, worker_env
from repro.train import checkpoint

PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


ckpt_dir = tempfile.mkdtemp(prefix="elastic_grid_ckpt_")
bench_path = os.environ.get(BENCH_VAR, "BENCH_elastic_loss_relaunch.json")
chaos_env = dict(os.environ, **{CKPT_VAR: ckpt_dir, FAIL_VAR: str(FAIL_STEP),
                                BENCH_VAR: bench_path})

# phase A: the grid is EXPECTED to die mid-exchange at FAIL_STEP
grid = launch_grid(
    [sys.executable, os.path.abspath(__file__)],
    processes=2, local_devices=2, timeout=1200.0,
    env=chaos_env, check=False,
)
assert not grid.ok, "chaos grid exited clean — injected failure never fired"
assert 17 not in grid.returncodes, "a rank ran past the injected failure"
assert any("SimulatedFailure" in e for e in grid.errs), grid.errs
ok(f"2-rank grid died from the injected mid-exchange failure "
   f"(ranks {grid.failed_ranks})")

committed = checkpoint.committed_steps(ckpt_dir)
assert committed and committed[-1] == FAIL_STEP, (committed, FAIL_STEP)
ok(f"checkpoint committed at step {FAIL_STEP} survived the crash "
   f"(committed: {committed})")

# phase B: relaunch on the survivor topology (1 process, 2 devices)
resume_env = worker_env(local_devices=2, base=chaos_env)
resume_env[RESUME_VAR] = "1"
out = subprocess.run(
    [sys.executable, os.path.abspath(__file__)],
    env=resume_env, capture_output=True, text=True, timeout=1200,
)
if out.returncode != 0:
    sys.stderr.write(out.stdout[-4000:])
    sys.stderr.write(out.stderr[-4000:])
    sys.exit(1)
assert "RESUME-BITWISE-OK" in out.stdout, out.stdout[-2000:]
print(out.stdout, end="")
ok("survivor relaunch resumed from the checkpoint and matched the "
   "1-device oracle bitwise")

assert os.path.exists(bench_path), bench_path
ok(f"BENCH row written to {bench_path}")

print(f"ALL {len(PASS)} ELASTIC-STENCIL CHECKS PASSED")
