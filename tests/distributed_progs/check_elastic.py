"""Elastic re-mesh integration check (8 fake CPU devices).

Simulates losing half the data-parallel width mid-run: train on a (4, 2)
(data, model) mesh, checkpoint, then restore the same state onto a (2, 2)
mesh (4 surviving devices) and keep training.  The loss trajectory must
continue sanely (same data stream, same params — only the device layout and
per-device batch slices change; with deterministic data the post-restart
losses must match a run that used the small mesh from that step onward).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.compat import make_mesh, set_mesh
from repro.configs.base import OptimizerConfig
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.parallel import sharding as shd
from repro.parallel.context import ParallelContext
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import reshard_state
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import make_train_step

PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


cfg = get_config("llama3-8b").reduced()
model = build_model(cfg)
opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=100)
dataset = SyntheticLM(cfg, global_batch=8, seq_len=32, seed=0)

mesh_big = make_mesh((4, 2), ("data", "model"))
mesh_small = make_mesh((2, 2), ("data", "model"),
                       devices=jax.devices()[:4])


def specs_for(mesh):
    state_sh = jax.eval_shape(
        lambda: {"params": model.init(jax.random.key(0)),
                 "opt": init_opt_state(model.init(jax.random.key(0)), opt_cfg)})
    pspec = shd.param_pspecs(state_sh["params"], model_axis="model",
                             model_size=mesh.shape["model"])
    mspec = shd.zero1_pspecs(
        state_sh["opt"]["m"],
        shd.param_pspecs(state_sh["opt"]["m"], model_axis="model",
                         model_size=mesh.shape["model"]),
        data_axes=("data",), mesh=mesh)
    return {"params": pspec, "opt": {"m": mspec, "v": mspec, "step": P()}}


def place(state, mesh, specs):
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), NamedSharding(mesh, s)),
        state, specs)


def run_steps(state, mesh, start, n):
    ctx = ParallelContext(mesh=mesh)
    step_fn = jax.jit(make_train_step(model, opt_cfg, ctx))
    losses = []
    with set_mesh(mesh):
        for i in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in dataset.batch_at(i).items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
    return state, losses


# --- phase 1: train 4 steps on the big mesh, checkpoint ---------------------
state = {"params": model.init(jax.random.key(0)),
         "opt": init_opt_state(model.init(jax.random.key(0)), opt_cfg)}
state = place(state, mesh_big, specs_for(mesh_big))
state, losses_big = run_steps(state, mesh_big, 0, 4)
assert losses_big[-1] < losses_big[0]
ok(f"trained 4 steps on (4,2) mesh: loss {losses_big[0]:.3f} -> {losses_big[-1]:.3f}")

tmp = tempfile.mkdtemp(prefix="elastic_")
ckpt.save(state, tmp, 4)
ok("checkpointed on the big mesh")

# --- phase 2: 'lose' half the data axis; restore onto the small mesh ---------
like = jax.eval_shape(lambda: state)
restored, step = ckpt.restore(tmp, like=like)
small_specs = specs_for(mesh_small)
restored = reshard_state(restored, mesh_small, small_specs)
leaf = jax.tree.leaves(restored["params"])[0]
assert leaf.sharding.mesh.shape["data"] == 2, leaf.sharding
ok("restored + re-sharded onto the (2,2) survivor mesh")

# --- phase 3: training continues identically (deterministic data) ------------
state_small, losses_small = run_steps(restored, mesh_small, 4, 3)
ok(f"continued training on small mesh: losses {['%.4f' % l for l in losses_small]}")

# reference: never-interrupted run switched to the small mesh at step 4
state_ref = {"params": model.init(jax.random.key(0)),
             "opt": init_opt_state(model.init(jax.random.key(0)), opt_cfg)}
state_ref = place(state_ref, mesh_big, specs_for(mesh_big))
state_ref, _ = run_steps(state_ref, mesh_big, 0, 4)
state_ref = reshard_state(
    jax.tree.map(np.asarray, state_ref), mesh_small, small_specs)
_, losses_ref = run_steps(state_ref, mesh_small, 4, 3)
np.testing.assert_allclose(losses_small, losses_ref, rtol=1e-5, atol=1e-6)
ok("post-re-mesh trajectory == uninterrupted reference")

print(f"ALL {len(PASS)} ELASTIC CHECKS PASSED")
