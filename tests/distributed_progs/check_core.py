"""Multi-device (8 fake CPU devices) correctness checks for repro.core.

Run standalone (spawned by tests/test_distributed.py):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 python check_core.py
Prints one `OK <name>` line per passing check; exits nonzero on failure.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.compat import make_mesh
from repro.core import (
    HaloSpec,
    Partitioner,
    build_exchange_step,
    exchange,
    partitioned_all_to_all,
    partitioned_ppermute,
    partitioned_psum,
    partitioned_psum_scatter,
    ring_all_gather,
    ring_all_gather_matmul,
    ring_attention,
    ring_matmul_reduce_scatter,
    ring_perm,
    seq_left_halo,
    state_passing,
)

assert len(jax.devices()) == 8, jax.devices()
mesh1d = make_mesh((8,), ("x",))
mesh2d = make_mesh((4, 2), ("r", "c"))
rng = np.random.default_rng(0)
PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


def smap(f, mesh, in_specs, out_specs):
    return compat.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


# --- partitioned_ppermute == fused ppermute ---------------------------------
x = jnp.asarray(rng.normal(size=(16, 12)).astype(np.float32))
perm = [(i, (i + 1) % 8) for i in range(8)]
for n_parts in (1, 2, 3, 4):  # 3 exercises the padding path (12 % 3 == 0; use 5)
    def f(a, n=n_parts):
        return partitioned_ppermute(a, "x", perm, n_parts=n, split_axis=1)
    got = smap(f, mesh1d, P("x", None), P("x", None))(x)
    want = smap(lambda a: lax.ppermute(a, "x", perm), mesh1d, P("x", None), P("x", None))(x)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
ok("partitioned_ppermute (incl. padding)")

# --- ring_all_gather == lax.all_gather --------------------------------------
x = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
for n_parts in (1, 2):
    got = smap(lambda a, n=n_parts: ring_all_gather(a, "x", gather_axis=0, n_parts=n),
               mesh1d, P("x", None), P(None, None))(x)
    np.testing.assert_allclose(got, np.asarray(x), rtol=0, atol=0)
ok("ring_all_gather")

# --- ring_all_gather_matmul == AG(x) @ w ------------------------------------
x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(8, 10)).astype(np.float32))
got = smap(lambda a, b: ring_all_gather_matmul(a, b, "x"),
           mesh1d, (P("x", None), P(None, None)), P(None, None))(x, w)
np.testing.assert_allclose(got, np.asarray(x) @ np.asarray(w), rtol=2e-5, atol=2e-5)
ok("ring_all_gather_matmul")

# --- ring_matmul_reduce_scatter == psum_scatter(x @ w) ----------------------
x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))  # feature-sharded
w = jnp.asarray(rng.normal(size=(32, 6)).astype(np.float32))
got = smap(lambda a, b: ring_matmul_reduce_scatter(a, b, "x"),
           mesh1d, (P(None, "x"), P("x", None)), P("x", None))(x, w)
np.testing.assert_allclose(got, np.asarray(x) @ np.asarray(w), rtol=2e-4, atol=2e-4)
ok("ring_matmul_reduce_scatter")

# --- partitioned_all_to_all == all_to_all (+ early consume) -----------------
# global (E=8, C_total=16, d=5), capacity sharded -> local (8, 2, 5) per device
x = jnp.asarray(rng.normal(size=(8, 16, 5)).astype(np.float32))  # (E, C, d)
want = smap(lambda a: lax.all_to_all(a, "x", split_axis=0, concat_axis=0, tiled=True),
            mesh1d, P(None, "x", None), P(None, "x", None))(x)
for n_parts in (1, 2, 5):  # 5 does not divide 12 -> padding path
    got = smap(
        lambda a, n=n_parts: partitioned_all_to_all(
            a, "x", split_axis=0, concat_axis=0, n_parts=n, chunk_axis=1),
        mesh1d, P(None, "x", None), P(None, "x", None))(x)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
# early-consume equivalence: consume(a2a(x)) == a2a-with-consume
consume = lambda c: jax.nn.gelu(c) * 2.0
got = smap(
    lambda a: partitioned_all_to_all(
        a, "x", split_axis=0, concat_axis=0, n_parts=3, chunk_axis=1,
        consume_fn=consume),
    mesh1d, P(None, "x", None), P(None, "x", None))(x)
np.testing.assert_allclose(got, consume(want), rtol=1e-6, atol=1e-6)
ok("partitioned_all_to_all (+early consume, padding)")

# --- partitioned psum / psum_scatter ----------------------------------------
g = jnp.asarray(rng.normal(size=(8, 24)).astype(np.float32))
want = smap(lambda a: lax.psum(a, "x"), mesh1d, P("x", None), P(None, None))(g)
got = smap(lambda a: partitioned_psum(a, "x", n_parts=4, chunk_axis=1),
           mesh1d, P("x", None), P(None, None))(g)
np.testing.assert_allclose(got[:1], want[:1], rtol=1e-6)
got2 = smap(lambda a: partitioned_psum_scatter(a, "x", scatter_axis=1, n_parts=3,
                                               chunk_axis=0),
            mesh1d, P(None, None), P(None, "x"))(
    jnp.asarray(rng.normal(size=(6, 16)).astype(np.float32)))
ok("partitioned_psum / psum_scatter")

# --- halo exchange vs np.roll oracle (2-D mesh, all strategies) -------------
H = 1
ny, nx = 32, 16  # global interior
interior = rng.normal(size=(ny, nx)).astype(np.float32)


def ghosted_global(a):
    """Oracle: per-shard blocks with ghost rims filled from periodic neighbors."""
    padded = np.pad(a, H, mode="wrap")
    return padded


glob = interior
# build sharded array with ghost rims: each shard (ny/4+2, nx/2+2)
blocks = []
for r in range(4):
    row = []
    for c in range(2):
        blk = np.zeros((ny // 4 + 2 * H, nx // 2 + 2 * H), np.float32)
        blk[H:-H, H:-H] = glob[r * 8:(r + 1) * 8, c * 8:(c + 1) * 8]
        row.append(blk)
    blocks.append(row)
local = np.concatenate([np.concatenate(r, axis=1) for r in blocks], axis=0)
x_sharded = jax.device_put(
    jnp.asarray(local), NamedSharding(mesh2d, P("r", "c"))
)

padded = ghosted_global(glob)
want_blocks = []
for r in range(4):
    row = []
    for c in range(2):
        row.append(padded[r * 8:r * 8 + 8 + 2 * H, c * 8:c * 8 + 8 + 2 * H])
    want_blocks.append(row)
want_full = np.concatenate([np.concatenate(r, axis=1) for r in want_blocks], axis=0)

for strategy, n_parts in (("standard", 1), ("persistent", 1), ("partitioned", 3)):
    spec = HaloSpec(mesh_axes=("r", "c"), array_axes=(0, 1), halo=H,
                    periodic=True, strategy=strategy, n_parts=n_parts)
    step = build_exchange_step(mesh2d, spec, ndim=2)
    got = np.asarray(step(x_sharded))
    np.testing.assert_allclose(got, want_full, rtol=0, atol=0, err_msg=strategy)
ok("halo exchange 2-D == np.roll oracle (3 strategies)")

# --- ring attention vs full attention oracle --------------------------------
def full_attn(q, k, v, causal):
    s = np.einsum("bqhd,bkhd->bhqk", q, np.repeat(k, q.shape[2] // k.shape[2], 2),
                  ).astype(np.float64) * (q.shape[-1] ** -0.5)
    if causal:
        iq = np.arange(s.shape[2])[:, None]
        ik = np.arange(s.shape[3])[None, :]
        s = np.where(iq >= ik, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, np.repeat(v, q.shape[2] // v.shape[2], 2))


B, S, Hq, Hkv, Dh = 2, 32, 4, 2, 8
q = rng.normal(size=(B, S, Hq, Dh)).astype(np.float32)
k = rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32)
v = rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32)
for causal in (True, False):
    for n_parts in (1, 2):
        got = smap(
            lambda a, b, c, cz=causal, n=n_parts: ring_attention(
                a, b, c, "x", causal=cz, n_parts=n),
            mesh1d, (P(None, "x", None, None),) * 3, P(None, "x", None, None),
        )(q, k, v)
        want = full_attn(q, k, v, causal)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3,
                                   err_msg=f"causal={causal} parts={n_parts}")
ok("ring_attention == full attention (causal/bidir, GQA, partitioned)")

# --- state passing (ring & tree) vs sequential oracle ------------------------
C = rng.normal(size=(8, 3, 4)).astype(np.float32)  # per-device contribution
D = rng.uniform(0.5, 0.99, size=(8, 3, 1)).astype(np.float32)
want_in = np.zeros_like(C)
s = np.zeros((3, 4), np.float32)
for i in range(8):
    want_in[i] = s
    s = D[i] * s + C[i]
for method in ("ring", "tree"):
    got = smap(
        lambda c, d, m=method: state_passing(c[0], d[0], "x", method=m)[None],
        mesh1d, (P("x", None, None), P("x", None, None)), P("x", None, None),
    )(jnp.asarray(C), jnp.asarray(D))
    np.testing.assert_allclose(got, want_in, rtol=1e-5, atol=1e-5, err_msg=method)
ok("state_passing ring/tree == sequential oracle")

# --- bucketed gradient all-reduce == per-leaf psum ---------------------------
from repro.core import bucketed_psum_tree

tree = {
    "w1": jnp.asarray(rng.normal(size=(8, 6, 4)).astype(np.float32)),
    "w2": jnp.asarray(rng.normal(size=(8, 10)).astype(np.float32)),
    "b": jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32)),
}
want = smap(lambda t: jax.tree.map(lambda g: lax.psum(g, "x"), t),
            mesh1d, (P("x"),), P(None))(tree)
for nb in (1, 2, 3):
    got = smap(lambda t, n=nb: bucketed_psum_tree(t, "x", n),
               mesh1d, (P("x"),), P(None))(tree)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a)[:1], np.asarray(b)[:1],
                                   rtol=1e-6)
ok("bucketed_psum_tree == per-leaf psum (1/2/3 buckets)")

# --- seq_left_halo ------------------------------------------------------------
xs = rng.normal(size=(2, 64, 4)).astype(np.float32)  # (B, S, d) seq-sharded
W = 3
got = smap(lambda a: seq_left_halo(a, "x", W, seq_axis=1),
           mesh1d, P(None, "x", None), P(None, "x", None))(jnp.asarray(xs))
got = np.asarray(got).reshape(2, 8, 8 + W, 4)
shard = xs.reshape(2, 8, 8, 4)
for i in range(8):
    exp_halo = np.zeros((2, W, 4), np.float32) if i == 0 else shard[:, i - 1, -W:]
    np.testing.assert_allclose(got[:, i, :W], exp_halo, err_msg=f"shard {i}")
    np.testing.assert_allclose(got[:, i, W:], shard[:, i])
ok("seq_left_halo")

print(f"ALL {len(PASS)} CORE CHECKS PASSED")
