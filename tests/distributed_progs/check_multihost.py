"""2-process multihost checks: REAL ``jax.distributed`` transport on CPU.

Unlike the sibling check programs (one process, 8 fake devices), this one
boots an actual 2-rank process grid (2 virtual CPU devices per rank, gloo
collectives) through :mod:`repro.launch.stencil` and runs every registered
exchange strategy through the ``multihost`` transport on meshes that span
the process boundary:

* 1-axis mesh (4 devices across 2 ranks): every strategy x the exact
  packers, each rank's addressable shards held to **bitwise** equality with
  the single-process reference roll;
* 2-axis mesh ((2, 2), the first axis crossing ranks): the fused schedule's
  edge/corner hop chains cross a real process boundary;
* wire-compressed packers (bf16, scaled-int8) held to their documented
  tolerances end-to-end across ranks.

Dual-mode like the launcher CLI: with no grid env vars this file *spawns*
the 2-rank grid of itself and forwards rank 0's report; inside the grid it
joins via ``maybe_initialize_from_env`` and runs the checks SPMD.
"""

import os
import sys

if os.environ.get("REPRO_COORDINATOR") is None:
    # launcher mode: no jax here — just boot the 2-rank grid of this file
    from repro.launch.stencil import launch_grid

    out = launch_grid(
        [sys.executable, os.path.abspath(__file__)],
        processes=2, local_devices=2, timeout=1200.0,
    )
    print(out, end="")
    sys.exit(0)

from repro.launch.stencil import maybe_initialize_from_env

RANK = maybe_initialize_from_env()

import jax

from repro.core.compat import make_mesh
from repro.launch.stencil import verify_strategy_cell
from repro.stencil.domain import Domain
from repro.stencil.strategies import available_strategies

PASS = []


def ok(name):
    if RANK == 0:
        print(f"OK {name}")
    PASS.append(name)


assert jax.process_count() == 2, jax.process_count()
assert len(jax.devices()) == 4, jax.devices()
assert len(jax.local_devices()) == 2, jax.local_devices()
ok("2-rank grid up: 4 global devices, 2 per rank")

# --- every registered strategy, exact packers, bitwise vs the reference ----
mesh = make_mesh((4,), ("px",), devices=jax.devices())
dom = Domain(mesh, global_interior=(16, 8), mesh_axes=("px", None))
for strategy in available_strategies():
    for packer in ("slice", "pallas"):
        verify_strategy_cell(
            dom, strategy=strategy, packer=packer, transport="multihost",
            n_parts=3,
        )
ok(f"{len(available_strategies())} strategies x slice/pallas bitwise == "
   f"reference roll across ranks")

# --- 2-axis mesh: fused corner hops cross the process boundary -------------
mesh2 = make_mesh((2, 2), ("px", "py"), devices=jax.devices())
dom2 = Domain(mesh2, global_interior=(8, 6), mesh_axes=("px", "py"))
for strategy in available_strategies():
    verify_strategy_cell(
        dom2, strategy=strategy, packer="slice", transport="multihost",
        n_parts=2,
    )
ok("2-axis mesh (px crosses ranks): all strategies incl. fused corners")

# --- both coalesce modes cross the process boundary ------------------------
# (the default above is the coalesced path: composed joint-axis collectives;
# this pins the per-message baseline to the same bitwise oracle, so the
# coalesce knob can never silently change what crosses the wire)
for coalesce in (True, False):
    verify_strategy_cell(
        dom2, strategy="fused", packer="slice", transport="multihost",
        n_parts=1, coalesce=coalesce,
    )
    verify_strategy_cell(
        dom2, strategy="partitioned", packer="slice", transport="multihost",
        n_parts=3, coalesce=coalesce,
    )
ok("coalesced AND uncoalesced fused/partitioned bitwise across ranks")

# --- wire-compressed packers within documented tolerance -------------------
for packer in ("bf16", "scaled-int8"):
    verify_strategy_cell(
        dom, strategy="persistent", packer=packer, transport="multihost",
        n_parts=1,
    )
    verify_strategy_cell(
        dom, strategy="partitioned", packer=packer, transport="multihost",
        n_parts=3,
    )
ok("compressed packers (bf16, scaled-int8) within wire tolerance "
   "across ranks")

# --- the base ppermute name is equally usable on a process-spanning mesh ---
# (multihost shares ppermute's hop primitive today — a dedicated backend
# overriding Transport.permute would make this a real cross-validation)
verify_strategy_cell(
    dom, strategy="persistent", packer="slice", transport="ppermute",
    n_parts=1,
)
ok("ppermute transport also verifies bitwise on the process-spanning mesh")

if RANK == 0:
    print(f"ALL {len(PASS)} MULTIHOST CHECKS PASSED")
