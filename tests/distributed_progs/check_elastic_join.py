"""Rank-JOIN chaos check over the REAL membership wire.

The CI leg for mid-run growth: a run starts on 2 devices, a joining rank
registers with the live localhost TCP coordinator at step 3, and the
mesh grows to 4 devices — with NO checkpoint anywhere (``ckpt_dir=None``,
``checkpoint_every=0``), so bitwise equality to the 1-device oracle
proves the grown topology computed on the survivors' LIVE iterate moved
through ``reshard_state``, not on anything restored from disk.  Also
asserted: the JOIN bumps the membership epoch per registered member, the
founding members never go cold, and ``join_us`` lands in the BENCH row.
"""

import os

# 8 virtual host devices, pinned BEFORE jax initializes (standalone
# program: the repo conftest does this for pytest, not for us)
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import json
import sys

import jax
import numpy as np

from repro.launch.elastic import ElasticConfig, ElasticStencilRunner
from repro.launch.membership import (
    MembershipClient,
    MembershipServer,
    MembershipService,
)

BENCH_VAR = "REPRO_ELASTIC_BENCH"
JOIN_STEP = 3

PASS = []


def ok(name):
    print(f"OK {name}")
    PASS.append(name)


cfg = ElasticConfig(
    global_interior=(16, 8), n_steps=6, checkpoint_every=0,
    recovery_mode="in-grid", heartbeat_timeout=30.0,
)

svc = MembershipService(heartbeat_timeout=cfg.heartbeat_timeout)
with MembershipServer(svc) as srv:
    cli = MembershipClient(srv.address, timeout=10.0)
    runner = ElasticStencilRunner(
        cfg, None,  # NO checkpoint directory: nothing to restore from
        devices=jax.devices()[:2],
        joins=[(JOIN_STEP, jax.devices()[2:4])],
        membership=cli,  # every membership op crosses the TCP wire
    )
    result = runner.run()
    view = cli.view()
    # two joining devices = two registrations = two "join" epoch bumps,
    # visible on the coordinator over the wire
    assert view.epoch == 2 and view.cause == "join", view
    assert len(view.members) == 4, view

assert result.replans == 0, result.replans  # growth, not failure recovery
assert [e.cause for e in result.events] == ["initial", "join"], result.events
assert (result.events[0].n_devices, result.events[1].n_devices) == (2, 4)
assert result.final_epoch == 2, result.final_epoch
ok("rank JOIN grew the mesh 2 -> 4 mid-run over the TCP wire "
   "(epoch 0 -> 2, one bump per registered member)")

assert result.warm_ranks == 2, result.warm_ranks
assert result.join_us > 0.0, result.join_us
assert result.checkpoint_step is None, result.checkpoint_step
ok("survivors stayed warm and no checkpoint was ever written or "
   "restored — the JOIN moved live state")

oracle = ElasticStencilRunner(
    dataclasses.replace(cfg, recovery_mode="relaunch"), None,
    devices=jax.devices()[:1],
).run()
assert np.array_equal(result.final_interior, oracle.final_interior), (
    "grown-topology run diverged from the single-device oracle"
)
ok("joined topology's trajectory bitwise == 1-device oracle")

bench_path = os.environ.get(BENCH_VAR, "BENCH_elastic_join.json")
rec = dict(result.bench_record(), mode="join")
assert rec["join_us"] > 0.0, rec
with open(bench_path, "w") as f:
    json.dump(rec, f, indent=1)
    f.write("\n")
ok(f"BENCH row written to {bench_path} (join_us={rec['join_us']:.0f})")

print(f"ALL {len(PASS)} ELASTIC-JOIN CHECKS PASSED")
sys.exit(0)
