"""Runs the multi-device check programs in subprocesses with 8 fake devices.

Each program is a full application (model build + multi-strategy training or
exchange) too heavy to share the pytest process; the subprocess also pins its
own ``XLA_FLAGS`` so the programs stay runnable standalone.  (Light
multi-device tests run in-process instead: the repo-level conftest forces
8 virtual devices before jax init — see tests/stencil/.)  Each program
prints ``ALL <n> ... PASSED`` on success and exits nonzero on failure.
"""

import os
import subprocess
import sys

import pytest

PROGS = [
    ("check_core.py", "CORE"),
    ("check_stencil.py", "STENCIL"),
    ("check_models_dist.py", "MODEL-DIST"),
    ("check_elastic.py", "ELASTIC"),
    # dual-mode: spawns its own 2-rank jax.distributed grid (2 CPU devices
    # per rank) and forwards rank 0's report — the 8-device env the driver
    # exports below is stripped by the grid's worker_env.
    ("check_multihost.py", "MULTIHOST"),
    # chaos: boots a 2-rank grid that is EXPECTED to die (injected
    # mid-exchange rank loss), then relaunches on the survivor topology
    # and holds the resumed run to the single-device oracle bitwise.
    ("check_elastic_stencil.py", "ELASTIC-STENCIL"),
]

_DIR = os.path.join(os.path.dirname(__file__), "distributed_progs")
_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
@pytest.mark.parametrize("prog,tag", PROGS, ids=[p for p, _ in PROGS])
def test_distributed_program(prog, tag):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.join(_DIR, prog)],
        env=env, capture_output=True, text=True, timeout=1500,
    )
    if out.returncode != 0:
        sys.stderr.write(out.stdout[-4000:])
        sys.stderr.write(out.stderr[-4000:])
    assert out.returncode == 0, f"{prog} failed"
    assert f"CHECKS PASSED" in out.stdout, out.stdout[-2000:]
