"""WKV chunk-scan kernel vs the validated chunked-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels.wkv import wkv, wkv_chunked, wkv_chunked_ref


def _mk(bh, T, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(bh, T, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, T, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, T, hd)), dtype)
    lw = jnp.asarray(-np.abs(rng.normal(size=(bh, T, hd))) - 0.05, dtype)
    u = jnp.asarray(rng.normal(size=(bh, 1, hd)) * 0.3, dtype)
    return r, k, v, lw, u


@pytest.mark.parametrize("bh,T,hd,chunk", [
    (2, 32, 8, 8),
    (4, 16, 16, 16),   # single chunk
    (1, 64, 8, 4),     # many chunks
    (3, 48, 32, 16),
])
def test_wkv_kernel_matches_ref(bh, T, hd, chunk):
    r, k, v, lw, u = _mk(bh, T, hd)
    got = wkv_chunked(r, k, v, lw, u, chunk=chunk, interpret=True)
    want = wkv_chunked_ref(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_wkv_kernel_bf16():
    r, k, v, lw, u = _mk(2, 32, 16, seed=1, dtype=jnp.bfloat16)
    got = wkv_chunked(r, k, v, lw, u, chunk=8, interpret=True)
    want = wkv_chunked_ref(r, k, v, lw, u, chunk=8)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_model_layout_wrapper():
    rng = np.random.default_rng(2)
    B, T, H, hd = 2, 16, 3, 8
    mk = lambda: jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    lw = jnp.asarray(-np.abs(rng.normal(size=(B, T, H, hd))) - 0.05, jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, hd)) * 0.3, jnp.float32)
    got = wkv(r, k, v, lw, u, chunk=8, force_kernel=True, interpret=True)
    from repro.models.rwkv import wkv_scan

    want, _ = wkv_scan(r, k, v, lw, u, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**16))
def test_wkv_kernel_property(chunk, seed):
    r, k, v, lw, u = _mk(2, 16, 8, seed=seed)
    got = wkv_chunked(r, k, v, lw, u, chunk=chunk, interpret=True)
    want = wkv_chunked_ref(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_strong_decay_stable():
    r, k, v, lw, u = _mk(1, 32, 8, seed=3)
    lw = jnp.full_like(lw, -12.0)
    out = wkv_chunked(r, k, v, lw, u, chunk=8, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
