"""Pack/unpack kernel vs pure-jnp oracle + roundtrip properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels.pack import (
    pack_2d, pack_2d_ref, pack_face, unpack_face,
)


@pytest.mark.parametrize("dtype_in,dtype_out", [
    (jnp.float32, jnp.float32),
    (jnp.float32, jnp.bfloat16),
    (jnp.bfloat16, jnp.bfloat16),
])
@pytest.mark.parametrize("shape,blocks", [
    ((64, 128), (32, 64)),
    ((17, 130), (16, 64)),   # padding path
    ((1, 256), (8, 128)),
    ((300, 7), (64, 8)),
])
def test_pack_2d_matches_ref(dtype_in, dtype_out, shape, blocks):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype_in)
    got = pack_2d(x, out_dtype=dtype_out, block_lead=blocks[0],
                  block_lane=blocks[1], interpret=True)
    want = pack_2d_ref(x, out_dtype=dtype_out)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got, np.float32), np.asarray(want, np.float32))


def test_pack_2d_scale():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 64)), jnp.float32)
    got = pack_2d(x, out_dtype=jnp.bfloat16, scale=8.0, interpret=True)
    want = pack_2d_ref(x, out_dtype=jnp.bfloat16, scale=8.0)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32))


@pytest.mark.parametrize("axis", [0, 1, 2])
@pytest.mark.parametrize("side", ["low", "high"])
def test_pack_unpack_face_roundtrip(axis, side):
    """pack one block's face, unpack into the neighbor's ghost: values match."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(10, 12, 14)), jnp.float32)
    halo = 1
    buf = pack_face(x, axis, side, halo, force_kernel=True, interpret=True)
    # unpack into the *opposite* ghost of a neighbor block
    other = jnp.zeros_like(x)
    ghost_side = "high" if side == "low" else "low"
    filled = unpack_face(other, buf, axis, ghost_side, halo,
                         force_kernel=True, interpret=True)
    size = x.shape[axis]
    if side == "low":
        want = jax.lax.slice_in_dim(x, halo, 2 * halo, axis=axis)
        got = jax.lax.slice_in_dim(filled, size - halo, size, axis=axis)
    else:
        want = jax.lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=axis)
        got = jax.lax.slice_in_dim(filled, 0, halo, axis=axis)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    lead=st.integers(1, 80),
    lane=st.integers(1, 200),
    bl=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 64, 128]),
)
def test_pack_property_arbitrary_shapes(lead, lane, bl, bn):
    """Property: tiled pack == straight copy for any slab shape (padding rule)."""
    rng = np.random.default_rng(lead * 1000 + lane)
    x = jnp.asarray(rng.normal(size=(lead, lane)), jnp.float32)
    got = pack_2d(x, block_lead=bl, block_lane=bn, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_wire_compression_halves_bytes():
    """bf16 wire format: pack halves bytes; unpack restores within bf16 eps."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    buf = pack_2d(x, out_dtype=jnp.bfloat16, interpret=True)
    assert buf.dtype == jnp.bfloat16 and buf.size == x.size
    back = np.asarray(buf, np.float32)
    np.testing.assert_allclose(back, np.asarray(x), rtol=1e-2, atol=1e-2)
