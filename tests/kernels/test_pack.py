"""Pack/unpack kernel vs pure-jnp oracle + roundtrip properties.

Beyond the historical 2-D face coverage, the slab-level wrappers
(``pack_slab``/``unpack_slab`` — what the transport layer's ``pallas``
packer stages every message through) are held to kernel-vs-oracle parity on
the exact N-D slab shapes the halo schedules emit: sequential full-extent
faces, the fused pass's ``3^D - 1`` face/edge/corner blocks, and clipped
partition windows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels.pack import (
    pack_2d, pack_2d_ref, pack_face, unpack_face,
    pack_slab, pack_slab_ref, unpack_slab, unpack_slab_ref,
)


@pytest.mark.parametrize("dtype_in,dtype_out", [
    (jnp.float32, jnp.float32),
    (jnp.float32, jnp.bfloat16),
    (jnp.bfloat16, jnp.bfloat16),
])
@pytest.mark.parametrize("shape,blocks", [
    ((64, 128), (32, 64)),
    ((17, 130), (16, 64)),   # padding path
    ((1, 256), (8, 128)),
    ((300, 7), (64, 8)),
])
def test_pack_2d_matches_ref(dtype_in, dtype_out, shape, blocks):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype_in)
    got = pack_2d(x, out_dtype=dtype_out, block_lead=blocks[0],
                  block_lane=blocks[1], interpret=True)
    want = pack_2d_ref(x, out_dtype=dtype_out)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got, np.float32), np.asarray(want, np.float32))


def test_pack_2d_scale():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 64)), jnp.float32)
    got = pack_2d(x, out_dtype=jnp.bfloat16, scale=8.0, interpret=True)
    want = pack_2d_ref(x, out_dtype=jnp.bfloat16, scale=8.0)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32))


@pytest.mark.parametrize("axis", [0, 1, 2])
@pytest.mark.parametrize("side", ["low", "high"])
def test_pack_unpack_face_roundtrip(axis, side):
    """pack one block's face, unpack into the neighbor's ghost: values match."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(10, 12, 14)), jnp.float32)
    halo = 1
    buf = pack_face(x, axis, side, halo, force_kernel=True, interpret=True)
    # unpack into the *opposite* ghost of a neighbor block
    other = jnp.zeros_like(x)
    ghost_side = "high" if side == "low" else "low"
    filled = unpack_face(other, buf, axis, ghost_side, halo,
                         force_kernel=True, interpret=True)
    size = x.shape[axis]
    if side == "low":
        want = jax.lax.slice_in_dim(x, halo, 2 * halo, axis=axis)
        got = jax.lax.slice_in_dim(filled, size - halo, size, axis=axis)
    else:
        want = jax.lax.slice_in_dim(x, size - 2 * halo, size - halo, axis=axis)
        got = jax.lax.slice_in_dim(filled, 0, halo, axis=axis)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    lead=st.integers(1, 80),
    lane=st.integers(1, 200),
    bl=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 64, 128]),
)
def test_pack_property_arbitrary_shapes(lead, lane, bl, bn):
    """Property: tiled pack == straight copy for any slab shape (padding rule)."""
    rng = np.random.default_rng(lead * 1000 + lane)
    x = jnp.asarray(rng.normal(size=(lead, lane)), jnp.float32)
    got = pack_2d(x, block_lead=bl, block_lane=bn, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_wire_compression_halves_bytes():
    """bf16 wire format: pack halves bytes; unpack restores within bf16 eps."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    buf = pack_2d(x, out_dtype=jnp.bfloat16, interpret=True)
    assert buf.dtype == jnp.bfloat16 and buf.size == x.size
    back = np.asarray(buf, np.float32)
    np.testing.assert_allclose(back, np.asarray(x), rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# slab-level parity: the shapes the halo schedules actually emit
# ---------------------------------------------------------------------------

#: ghosted local blocks the tier-1 stencil lane runs (halo=1 unless noted)
HALO_BLOCKS = [
    ((6,), ("px",), 1),           # 1-D block
    ((6, 10), ("px",), 1),        # 2-D, one decomposed axis
    ((8, 6), ("px", "py"), 2),    # 2-D, both axes, halo 2
    ((6, 6, 5), ("px", "py"), 1),  # 3-D, two decomposed axes
]


def _halo_slab_shapes(shape, names, halo):
    """Every slab shape the sequential + fused schedules pack for a block."""
    from repro.core.halo import HaloSpec, fused_slab_table

    spec = HaloSpec(
        mesh_axes=tuple(names), array_axes=tuple(range(len(names))),
        halo=halo,
    )
    shapes = set()
    for a in spec.array_axes:  # sequential full-extent faces
        s = list(shape)
        s[a] = halo
        shapes.add(tuple(s))
    for slab in fused_slab_table(shape, spec):  # fused faces/edges/corners
        shapes.add(slab.shape)
    return sorted(shapes)


@pytest.mark.parametrize("shape,names,halo", HALO_BLOCKS)
def test_pack_slab_kernel_matches_ref_on_halo_shapes(shape, names, halo):
    """Kernel (interpreter) == jnp oracle on every emitted slab shape."""
    rng = np.random.default_rng(11)
    for slab_shape in _halo_slab_shapes(shape, names, halo):
        slab = jnp.asarray(rng.normal(size=slab_shape), jnp.float32)
        got = pack_slab(slab, force_kernel=True, interpret=True)
        want = pack_slab_ref(slab)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        back = unpack_slab(got, slab_shape, force_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(slab))
        np.testing.assert_array_equal(
            np.asarray(unpack_slab_ref(want, slab_shape)), np.asarray(slab)
        )


def test_pack_slab_partition_windows_roundtrip():
    """Clipped partition windows (equal-size grid tails) survive the
    kernel pack/unpack — incl. the width-1 tail a non-dividing split makes."""
    from repro.core.transport import Message

    msg = Message((1, 0, 0), (5, 0, 0), (1, 7, 5), n_parts=3, part_axis=1)
    rng = np.random.default_rng(12)
    for part in msg.partitions():
        slab = jnp.asarray(rng.normal(size=part.shape), jnp.float32)
        buf = pack_slab(slab, force_kernel=True, interpret=True)
        back = unpack_slab(buf, part.shape, force_kernel=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(slab))


def test_gather_pack_kernel_matches_ref_on_fused_tables():
    """The fused gather-pack (interpreter) == jnp oracle on a whole fused
    slab table coalesced into one buffer (the 3^D - 1 windows of a block)."""
    from repro.core.halo import HaloSpec, fused_slab_table
    from repro.kernels.pack import gather_pack, gather_pack_ref

    shape, halo = (8, 6, 5), 1
    spec = HaloSpec(mesh_axes=("px", "py", "pz"), array_axes=(0, 1, 2),
                    halo=halo)
    segments, offset = [], 0
    for slab in fused_slab_table(shape, spec):
        n = int(np.prod(slab.shape))
        segments.append((offset, slab.src_start, slab.shape))
        offset += n
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    got = gather_pack(x, segments, total=offset, force_kernel=True,
                      interpret=True)
    want = gather_pack_ref(x, segments, total=offset)
    assert got.shape == (offset,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # with the bf16 wire conversion fused into the same launch
    got16 = gather_pack(x, segments, total=offset, out_dtype=jnp.bfloat16,
                        force_kernel=True, interpret=True)
    assert got16.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got16),
        np.asarray(gather_pack_ref(x, segments, total=offset,
                                   out_dtype=jnp.bfloat16)),
    )


def test_gather_pack_cpu_fallback_is_oracle():
    from repro.kernels.pack import gather_pack, gather_pack_ref

    x = jnp.arange(24.0).reshape(4, 6)
    segments = ((0, (0, 0), (1, 6)), (6, (2, 1), (2, 3)))
    np.testing.assert_array_equal(
        np.asarray(gather_pack(x, segments, total=12)),
        np.asarray(gather_pack_ref(x, segments, total=12)),
    )


def test_pack_slab_wire_compression_roundtrip():
    """bf16 wire format on an N-D slab: bytes halve, values within bf16 eps."""
    rng = np.random.default_rng(13)
    slab = jnp.asarray(rng.normal(size=(2, 12, 7)), jnp.float32)
    buf = pack_slab(slab, out_dtype=jnp.bfloat16, force_kernel=True,
                    interpret=True)
    assert buf.dtype == jnp.bfloat16 and buf.size == slab.size
    back = unpack_slab(buf, slab.shape, out_dtype=jnp.float32,
                       force_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(slab),
                               rtol=1e-2, atol=1e-2)


def test_registered_compressed_packers_roundtrip_halo_slabs():
    """The registered wire-compressed packers (bf16 via the slab kernel
    wrappers, scaled-int8 quantization) round-trip every slab shape the
    halo schedules emit, within each packer's documented tolerance, and
    restore the block dtype exactly."""
    import jax.numpy as jnp

    from repro.core.transport import get_packer

    rng = np.random.default_rng(23)
    for packer_name in ("bf16", "scaled-int8"):
        p = get_packer(packer_name)
        rtol, atol = p.wire_tolerance(jnp.float32)
        for shape, names, halo in HALO_BLOCKS:
            block = jnp.asarray(rng.normal(size=shape), jnp.float32)
            for slab_shape in _halo_slab_shapes(shape, names, halo):
                start = (0,) * len(shape)
                buf = p.pack(block, start, slab_shape)
                out = p.unpack(jnp.zeros_like(block), buf, start, slab_shape)
                assert out.dtype == block.dtype, packer_name
                window = tuple(slice(0, n) for n in slab_shape)
                np.testing.assert_allclose(
                    np.asarray(out)[window], np.asarray(block)[window],
                    rtol=rtol, atol=atol,
                    err_msg=f"{packer_name} slab={slab_shape}",
                )


def test_bf16_packer_wire_matches_slab_kernel():
    """Bf16Packer's wire buffer IS pack_slab's bf16 wire format — the
    compressed packer rides the same kernel path as `pallas`."""
    from repro.core.transport import get_packer

    rng = np.random.default_rng(24)
    block = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    buf = get_packer("bf16").pack(block, (1, 2), (2, 7))
    want = pack_slab(
        jax.lax.slice(block, (1, 2), (3, 9)), out_dtype=jnp.bfloat16
    )
    assert buf.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(buf, np.float32), np.asarray(want, np.float32)
    )


def test_pack_slab_cpu_fallback_is_oracle():
    """Off-TPU (no force_kernel) the wrapper IS the oracle — the pallas
    packer's CPU fallback the equivalence matrix relies on."""
    assert jax.default_backend() != "tpu", "test assumes CPU/virtual devices"
    rng = np.random.default_rng(14)
    slab = jnp.asarray(rng.normal(size=(3, 9, 4)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(pack_slab(slab)), np.asarray(pack_slab_ref(slab))
    )
