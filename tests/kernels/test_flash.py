"""Flash attention kernel vs pure-jnp oracle (interpret mode, shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels.flash_attention import attention, attention_ref, flash_attention

jax.config.update("jax_enable_x64", False)


def _mk(b, hq, hkv, sq, skv, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,bq,bkv",
    [
        (1, 2, 2, 32, 32, 16, 16, 16),  # MHA square
        (2, 4, 2, 64, 64, 32, 32, 16),  # GQA
        (1, 8, 1, 32, 64, 16, 16, 32),  # MQA, rectangular
        (1, 2, 2, 16, 16, 8, 16, 16),  # single block
        (2, 2, 2, 48, 96, 16, 16, 32),  # non-pow2 q blocks
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(dtype, b, hq, hkv, sq, skv, d, bq, bkv, causal):
    q, k, v = _mk(b, hq, hkv, sq, skv, d, dtype)
    got = flash_attention(
        q, k, v, causal=causal, block_q=bq, block_kv=bkv, interpret=True
    )
    want = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_ops_wrapper_layout():
    """ops.attention takes (B,S,H,D) and matches the oracle."""
    q, k, v = _mk(2, 4, 2, 32, 32, 16, jnp.float32)
    qs, ks, vs = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    got = attention(qs, ks, vs, causal=True, force_kernel=True, interpret=True,
                    block_q=16, block_kv=16)
    want = jnp.swapaxes(attention_ref(q, k, v, causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_fully_masked_rows_are_finite():
    """Cross-block causal boundaries must not produce NaNs (masked-block guard)."""
    q, k, v = _mk(1, 1, 1, 64, 64, 16, jnp.float32, seed=3)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_kv=32, interpret=True)
    assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=10, deadline=None)
@given(
    sq_blocks=st.integers(1, 3),
    skv_blocks=st.integers(1, 3),
    d=st.sampled_from([8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_flash_property(sq_blocks, skv_blocks, d, causal, seed):
    """Property: kernel == oracle for arbitrary block-multiple shapes."""
    bq = bkv = 16
    q, k, v = _mk(1, 2, 1, sq_blocks * bq, skv_blocks * bkv, d, jnp.float32, seed)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_kv=bkv,
                          interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
