"""27-point stencil kernel vs pure-jnp oracle + conservation properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels.stencil27 import jacobi_weights, stencil27, stencil27_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,tile", [
    ((8, 8, 16), (4, 4, 8)),
    ((6, 10, 12), (2, 5, 6)),
    ((4, 4, 4), (4, 4, 4)),   # single tile
    ((16, 8, 32), (8, 8, 8)),
])
def test_stencil_matches_ref(dtype, shape, tile):
    rng = np.random.default_rng(0)
    ghosted = tuple(s + 2 for s in shape)
    x = jnp.asarray(rng.normal(size=ghosted), dtype)
    w = jnp.asarray(rng.normal(size=(3, 3, 3)), jnp.float32)
    got = stencil27(x, w, tile=tile, interpret=True)
    want = stencil27_ref(x, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_jacobi_constant_field_is_fixed_point():
    """Normalized box weights: a constant field maps to itself."""
    x = jnp.full((10, 10, 10), 3.25, jnp.float32)
    out = stencil27(x, jacobi_weights(), tile=(8, 8, 8), interpret=True)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-6)


def test_identity_weights():
    """Center-only weights: stencil is the identity on the interior."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 8, 8)), jnp.float32)
    w = jnp.zeros((3, 3, 3), jnp.float32).at[1, 1, 1].set(1.0)
    out = stencil27(x, w, tile=(2, 2, 2), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x[1:-1, 1:-1, 1:-1]),
                               rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    z=st.sampled_from([2, 4]), y=st.sampled_from([2, 4, 6]),
    x=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16),
)
def test_stencil_property(z, y, x, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(z + 2, y + 2, x + 2)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3)), jnp.float32)
    got = stencil27(g, w, tile=(2, 2, 2), interpret=True)
    want = stencil27_ref(g, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
