"""Coalesced wire-buffer transport: layouts, composed routes, plan keys.

The coalescing layer's contracts: static :class:`WireLayout` offset tables
round-trip mixed slab shapes through one buffer, partitioned rounds stay
pipelined and clipped (non-dividing ``n_parts``), compressed packers lay the
buffer out at their ``wire_itemsize``, backends resolve exactly once per
schedule, coalesced vs. uncoalesced plans never share a cache entry, and —
the headline — a coalesced fused 3-D step compiles to exactly ONE
collective per distinct hop chain where the uncoalesced step launches one
per hop of every message.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compat
from repro.core.transport import (
    Message,
    Packer,
    PallasPacker,
    PpermuteTransport,
    SlicePacker,
    WireLayout,
    WireSegment,
    coalesced_layout,
    coalesced_rounds,
    composed_hop,
    deliver,
    exchange_messages,
    get_packer,
    schedule_layouts,
    scheduled_collective_count,
)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 virtual devices (conftest)"
)


# ---------------------------------------------------------------------------
# offset tables
# ---------------------------------------------------------------------------


def _chain(axis_name="px", k=4, shift=1):
    return ((axis_name, tuple((i, (i + shift) % k) for i in range(k))),)


def test_layout_offsets_tile_mixed_slab_shapes():
    """Mixed face/edge/corner-shaped slabs lay end-to-end: offsets are the
    running element sum, total covers the buffer exactly."""
    hops = _chain()
    msgs = [
        Message((1, 0, 0), (5, 0, 0), (1, 6, 4), hops),   # face: 24 elems
        Message((1, 1, 0), (5, 5, 0), (1, 1, 4), hops),   # edge: 4
        Message((1, 1, 1), (5, 5, 5), (1, 1, 1), hops),   # corner: 1
    ]
    layout = coalesced_layout(msgs, hops, get_packer("slice"), jnp.float32)
    assert [s.offset for s in layout.segments] == [0, 24, 28]
    assert [s.numel for s in layout.segments] == [24, 4, 1]
    assert layout.total == 29
    assert layout.wire_itemsize == 4 and layout.wire_bytes == 116


@pytest.mark.parametrize("packer,itemsize", [
    ("slice", 4), ("pallas", 4), ("bf16", 2), ("scaled-int8", 1),
])
def test_layout_wire_itemsize_tracks_packer(packer, itemsize):
    """The offset table is wire_itemsize-aware: element offsets are shared,
    byte footprints shrink under the compressed packers."""
    hops = _chain()
    msgs = [Message((0, 0), (0, 0), (2, 8), hops)]
    layout = coalesced_layout(msgs, hops, get_packer(packer), jnp.float32)
    assert layout.wire_itemsize == itemsize
    assert layout.wire_bytes == 16 * itemsize


def test_layout_rejects_foreign_chains_and_partitioned_messages():
    hops = _chain()
    with pytest.raises(AssertionError):
        coalesced_layout(
            [Message((0,), (0,), (4,), _chain(shift=-1))], hops,
            get_packer("slice"), jnp.float32,
        )
    with pytest.raises(AssertionError):
        coalesced_layout(
            [Message((0, 0), (0, 0), (2, 8), hops, n_parts=2, part_axis=1)],
            hops, get_packer("slice"), jnp.float32,
        )


def test_coalesced_rounds_pipeline_clipped_partitions():
    """Non-dividing n_parts: round r holds every message's r-th clipped
    partition; all-padding tails vanish, so late rounds thin out."""
    hops = _chain()
    msgs = [
        # extent 10 over 4 parts: widths 3,3,3,1
        Message((0, 0), (8, 0), (1, 10), hops, n_parts=4, part_axis=1),
        # extent 2 over 4 parts: widths 1,1 then all-padding tails
        Message((1, 0), (9, 0), (1, 2), hops, n_parts=4, part_axis=1),
    ]
    rounds = coalesced_rounds(msgs)
    assert len(rounds) == 4
    widths = [
        [p.shape[1] for _, parts in chains for p in parts]
        for chains in rounds
    ]
    assert widths == [[3, 1], [3, 1], [3], [1]]
    # each round is one chain here -> one collective per round
    assert scheduled_collective_count([msgs], coalesce=True) == 4
    assert scheduled_collective_count([msgs], coalesce=False) == 6


def test_scheduled_count_merges_shared_chains_and_skips_self_copies():
    to_peer = _chain()
    local = Message((0,), (4,), (2,))  # hop-free self-copy
    a = Message((0, 0), (6, 0), (1, 4), to_peer)
    b = Message((1, 0), (7, 0), (1, 4), to_peer)
    # coalesced: a+b share one chain (1 collective); the self-copy is free
    assert scheduled_collective_count([(local, a, b)], coalesce=True) == 1
    assert scheduled_collective_count([(local, a, b)], coalesce=False) == 2


def test_schedule_layouts_enumerate_delivery_order():
    hops = _chain()
    msgs = [
        Message((0, 0), (6, 0), (1, 6), hops, n_parts=2, part_axis=1),
        Message((1, 0), (7, 0), (1, 6), hops, n_parts=2, part_axis=1),
    ]
    layouts = schedule_layouts([msgs], "bf16", jnp.float32)
    assert len(layouts) == 2  # one buffer per partition round
    for layout in layouts:
        assert isinstance(layout, WireLayout)
        assert len(layout.segments) == 2  # both messages share the chain
        assert layout.total == 6 and layout.wire_itemsize == 2


# ---------------------------------------------------------------------------
# composed hops
# ---------------------------------------------------------------------------


def test_composed_hop_identities():
    assert composed_hop(()) is None
    single = _chain()[0]
    assert composed_hop((single,)) == single


def test_composed_hop_joint_permutation_on_mesh():
    """Inside shard_map a 2-hop chain composes to the row-major joint
    table, dropping sources either per-axis table clips away."""
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((2, 2), ("px", "py"), devices=jax.devices()[:4])
    seen = {}

    def probe(xl):
        hop_x = ("px", ((0, 1), (1, 0)))
        hop_y = ("py", ((0, 1),))  # clipped: source 1 has no hop
        seen["hop"] = composed_hop((hop_x, hop_y))
        return xl

    compat.shard_map(
        probe, mesh=mesh, in_specs=P("px", "py"), out_specs=P("px", "py")
    )(jnp.zeros((2, 2)))
    names, pairs = seen["hop"]
    assert names == ("px", "py")
    # (i,j) -> (1-i, 1) for j == 0 only; linearized row-major over (2, 2)
    assert sorted(pairs) == [(0, 3), (2, 1)]


# ---------------------------------------------------------------------------
# coalesced delivery on a mesh
# ---------------------------------------------------------------------------


def _ring_messages(shape, axis_name, k, halo=1):
    size = shape[0]
    to_left = tuple((i, (i - 1) % k) for i in range(k))
    to_right = tuple((i, (i + 1) % k) for i in range(k))

    def w(src_edge, dst_edge):
        src, dst, sz = [0] * len(shape), [0] * len(shape), list(shape)
        src[0], dst[0], sz[0] = src_edge, dst_edge, halo
        return tuple(src), tuple(dst), tuple(sz)

    left = Message(*w(halo, size - halo), ((axis_name, to_left),))
    right = Message(*w(size - 2 * halo, 0), ((axis_name, to_right),))
    return (left, right)


@pytest.mark.parametrize("packer", ["slice", "pallas", "bf16", "scaled-int8"])
@pytest.mark.parametrize("n_parts", [1, 3, 7])
def test_coalesced_delivery_matches_uncoalesced(packer, n_parts):
    """The oracle across packers and non-dividing partition counts: the
    coalesced pipeline moves exactly the cells the per-message one moves
    (within the packer's wire tolerance; both paths quantize identically,
    so the comparison is bitwise even for lossy packers)."""
    from jax.sharding import PartitionSpec as P

    k = 4
    mesh = compat.make_mesh((k,), ("px",), devices=jax.devices()[:k])
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(k * 4, 5)), jnp.float32)
    msgs = tuple(
        dataclasses.replace(m, n_parts=n_parts,
                            part_axis=1 if n_parts > 1 else None)
        for m in _ring_messages((4, 5), "px", k)
    )

    def run(coalesce):
        def step(xl):
            return deliver(xl, msgs, packer=packer, coalesce=coalesce)

        return np.asarray(
            compat.shard_map(
                step, mesh=mesh, in_specs=P("px", None),
                out_specs=P("px", None),
            )(x)
        )

    np.testing.assert_array_equal(run(True), run(False))


def test_coalesced_multi_hop_route_reaches_diagonal_neighbor():
    """A 2-hop corner message coalesces into ONE joint-permutation
    collective and still lands on the diagonal peer."""
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((2, 2), ("px", "py"), devices=jax.devices()[:4])
    x = jnp.arange(16.0).reshape(4, 4)
    hop = tuple((i, (i + 1) % 2) for i in range(2))
    msg = Message((0, 0), (1, 1), (1, 1), (("px", hop), ("py", hop)))

    def step(xl):
        return exchange_messages(xl, ((msg,),), coalesce=True)

    got = np.asarray(
        compat.shard_map(
            step, mesh=mesh, in_specs=P("px", "py"), out_specs=P("px", "py")
        )(x)
    )
    xg = np.asarray(x)
    for i in range(2):
        for j in range(2):
            want = xg[2 * ((i + 1) % 2), 2 * ((j + 1) % 2)]
            assert got[2 * i + 1, 2 * j + 1] == want, (i, j)


def test_coalesced_backends_observe_one_buffer_per_chain():
    """Counting backends: two messages sharing a chain cross the packer as
    ONE coalesced buffer and the transport as ONE collective; the pallas
    packer's gather-pack fuses the fill into one launch."""
    from jax.sharding import PartitionSpec as P

    calls = {"pack_coalesced": 0, "unpack": 0, "permute": 0}

    @dataclasses.dataclass(frozen=True)
    class CountingPacker(SlicePacker):
        name: str = "counting-coal-test"

        def pack_coalesced(self, x, layout):
            calls["pack_coalesced"] += 1
            return super().pack_coalesced(x, layout)

        def unpack(self, x, buf, dst_start, shape):
            calls["unpack"] += 1
            return super().unpack(x, buf, dst_start, shape)

    @dataclasses.dataclass(frozen=True)
    class CountingTransport(PpermuteTransport):
        name: str = "counting-coal-test"

        def permute(self, buf, axis_name, perm):
            calls["permute"] += 1
            return super().permute(buf, axis_name, perm)

    k = 4
    mesh = compat.make_mesh((k,), ("px",), devices=jax.devices()[:k])
    x = jnp.arange(k * 4 * 6, dtype=jnp.float32).reshape(k * 4, 6)
    chain = _chain(k=k)
    msgs = (
        Message((1, 0), (13, 0), (1, 6), chain),
        Message((2, 0), (14, 0), (1, 6), chain),
    )

    def step(xl):
        return deliver(xl, msgs, packer=CountingPacker(),
                       transport=CountingTransport(), coalesce=True)

    compat.shard_map(
        step, mesh=mesh, in_specs=P("px", None), out_specs=P("px", None)
    )(x)
    # 2 messages, ONE chain: one coalesced pack, one collective, two
    # scatter-unpacks into the disjoint ghost windows
    assert calls == {"pack_coalesced": 1, "permute": 1, "unpack": 2}


def test_pallas_gather_pack_fills_buffer_in_one_launch():
    """The fused gather-pack kernel (interpreter-pinned) produces the same
    coalesced buffer as the per-slab reference concatenation."""
    p = PallasPacker(name="pallas-gather-test", force_kernel=True,
                     interpret=True)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(8, 6, 4)), jnp.float32)
    hops = _chain()
    msgs = [  # mixed slab shapes, disjoint dst ghost windows
        Message((1, 0, 0), (7, 0, 0), (1, 6, 4), hops),
        Message((1, 1, 1), (0, 4, 2), (1, 2, 2), hops),
        Message((2, 2, 0), (1, 2, 0), (3, 1, 4), hops),
    ]
    layout = coalesced_layout(msgs, hops, p, x.dtype)
    got = p.pack_coalesced(x, layout)
    want = SlicePacker().pack_coalesced(x, layout)
    assert got.shape == (layout.total,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the scatter-unpack inverse restores every window
    ghost = jnp.zeros_like(x)
    out = p.unpack_coalesced(ghost, got, layout)
    for s in layout.segments:
        window = tuple(slice(b, b + n) for b, n in zip(s.src_start, s.shape))
        dst = tuple(slice(b, b + n) for b, n in zip(s.dst_start, s.shape))
        np.testing.assert_array_equal(np.asarray(out[dst]),
                                      np.asarray(x[window]))


def test_bf16_coalesced_buffer_ships_compressed_wire():
    """The bf16 packer's coalesced buffer is bfloat16 end-to-end (half the
    wire bytes) and unpacks within the documented tolerance."""
    p = get_packer("bf16")
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    hops = _chain()
    msgs = [Message((1, 0), (5, 0), (1, 8), hops),
            Message((0, 2), (0, 6), (4, 2), hops)]
    layout = coalesced_layout(msgs, hops, p, x.dtype)
    buf = p.pack_coalesced(x, layout)
    assert buf.dtype == jnp.bfloat16 and buf.shape == (layout.total,)
    assert layout.wire_bytes == layout.total * 2
    out = p.unpack_coalesced(jnp.zeros_like(x), buf, layout)
    assert out.dtype == x.dtype
    rtol, atol = p.wire_tolerance(x.dtype)
    np.testing.assert_allclose(np.asarray(out)[5, :8], np.asarray(x)[1, :8],
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(out)[:4, 6:8],
                               np.asarray(x)[:4, 2:4], rtol=rtol, atol=atol)


def test_scaled_int8_coalesced_buffer_is_one_byte_per_element():
    p = get_packer("scaled-int8")
    x = jnp.asarray([[0.5, -0.25, 1.0, 2.0]], jnp.float32)
    hops = _chain()
    msgs = [Message((0, 0), (0, 0), (1, 2), hops),
            Message((0, 2), (0, 2), (1, 2), hops)]
    layout = coalesced_layout(msgs, hops, p, x.dtype)
    buf = p.pack_coalesced(x, layout)
    assert buf.dtype == jnp.int8 and layout.wire_bytes == 4
    out = p.unpack_coalesced(jnp.zeros_like(x), buf, layout)
    rtol, atol = p.wire_tolerance(x.dtype)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# backends resolve once per schedule (the hoisted resolve_* fix)
# ---------------------------------------------------------------------------


def test_exchange_messages_validates_transport_once_per_schedule():
    """A multi-group schedule must resolve/validate the transport exactly
    once — not once per group (the historical per-deliver re-validation)."""
    from jax.sharding import PartitionSpec as P
    from repro.core import transport as T

    validations = []

    @dataclasses.dataclass(frozen=True)
    class ValidatingTransport(PpermuteTransport):
        name: str = "validating-test"

        def validate(self):
            validations.append(1)

    T.register_transport(ValidatingTransport())
    try:
        k = 4
        mesh = compat.make_mesh((k,), ("px",), devices=jax.devices()[:k])
        x = jnp.arange(k * 4 * 3, dtype=jnp.float32).reshape(k * 4, 3)
        group = _ring_messages((4, 3), "px", k)

        def step(xl):
            return exchange_messages(
                xl, (group, group, group), transport="validating-test",
            )

        compat.shard_map(
            step, mesh=mesh, in_specs=P("px", None), out_specs=P("px", None)
        )(x)
        assert sum(validations) == 1, "validate must run once per schedule"
    finally:
        del T._TRANSPORTS["validating-test"]


# ---------------------------------------------------------------------------
# plan identity: coalesce mode is part of the compiled schedule's key
# ---------------------------------------------------------------------------


def test_coalesced_and_uncoalesced_plans_get_distinct_keys():
    """A shared PlanCache must MISS when only the coalesce mode differs
    (the wire choreography is baked into the executable) and HIT on a
    true repeat; the coalesced plan records its offset tables."""
    from repro.core.plan import PlanCache
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import StrategyConfig, make_driver

    mesh = compat.make_mesh((4,), ("px",), devices=jax.devices()[:4])
    domain = Domain(mesh, global_interior=(16, 8), mesh_axes=("px", None))
    cache = PlanCache()

    def drive(coalesce):
        drv = make_driver(
            StrategyConfig(name="persistent", coalesce=coalesce,
                           plan_cache=cache),
            domain.mesh, domain.halo_spec, ndim=2,
        )
        drv.wait(drv.step(domain.random(0)))
        plan = drv._plan
        drv.free()
        return plan

    coalesced = drive(True)
    uncoalesced = drive(False)
    assert len(cache) == 2, "coalesce change must not hit the cached plan"
    assert cache.stats.inits == 2 and cache.stats.cache_hits == 0
    drive(True)  # identical geometry AND coalesce mode: amortized
    assert len(cache) == 2 and cache.stats.cache_hits == 1
    # the schedule identity and static offset tables ride on the plan
    assert coalesced.schedule.coalesce is True
    assert coalesced.name.endswith("@slice")  # plan name unchanged
    assert coalesced.wire_layouts and all(
        isinstance(l, WireLayout) for l in coalesced.wire_layouts
    )
    assert uncoalesced.schedule.coalesce is False
    assert uncoalesced.wire_layouts == ()
    cache.free_all()


# ---------------------------------------------------------------------------
# the headline: one collective per distinct hop chain in compiled HLO
# ---------------------------------------------------------------------------


def _fused_driver(domain, coalesce, n_parts=1, strategy="fused"):
    from repro.stencil.strategies import StrategyConfig, make_driver

    return make_driver(
        StrategyConfig(name=strategy, coalesce=coalesce, n_parts=n_parts),
        domain.mesh, domain.halo_spec,
        ndim=len(domain.global_interior),
    )


def test_fused_3d_coalesced_step_is_one_collective_per_hop_chain():
    """hlo_analysis acceptance: on a 2x2x2 torus a fused 3-D step has 26
    neighbor messages; coalesced they compile to exactly one
    collective-permute per DISTINCT hop chain (7 here — the +-1 hops of a
    2-wide periodic axis share one neighbor table, so chains merge), while
    the uncoalesced step launches one per hop of every message (54)."""
    from repro.core.halo import fused_message_group
    from repro.core.hlo_analysis import parse_collectives
    from repro.stencil.domain import Domain

    mesh = compat.make_mesh((2, 2, 2), ("px", "py", "pz"),
                            devices=jax.devices()[:8])
    domain = Domain(mesh, global_interior=(8, 6, 4),
                    mesh_axes=("px", "py", "pz"))
    x = domain.random(0)

    spec = domain.halo_spec()
    local_shape = tuple(
        g // mesh.shape[name] + 2 for g, name in
        zip(domain.global_interior, ("px", "py", "pz"))
    )
    group = fused_message_group(
        local_shape, spec, {n: 2 for n in ("px", "py", "pz")}
    )
    assert len(group) == 26  # 3^3 - 1 neighbor messages
    distinct_chains = {m.hops for m in group}

    counts = {}
    for coalesce in (True, False):
        drv = _fused_driver(domain, coalesce)
        stats = parse_collectives(drv.compiled_text(x))
        counts[coalesce] = stats.by_op_counts.get("collective-permute", 0)
        assert counts[coalesce] == drv.scheduled_collectives(x)
        drv.free()
    assert counts[True] == len(distinct_chains) == 7
    assert counts[False] == sum(len(m.hops) for m in group) == 54


def test_wide_mesh_fused_chains_compile_per_distinct_chain():
    """On a (4, 2) mesh the 4-wide axis keeps left/right chains distinct
    while the 2-wide axis merges its +-1 chains, leaving 5 distinct chains
    for the 8 fused 2-D messages: the coalesced step compiles to exactly
    those 5 collectives (vs 12 per-hop uncoalesced)."""
    from repro.core.halo import fused_message_group
    from repro.core.hlo_analysis import parse_collectives
    from repro.stencil.domain import Domain

    mesh = compat.make_mesh((4, 2), ("px", "py"), devices=jax.devices()[:8])
    domain = Domain(mesh, global_interior=(16, 8), mesh_axes=("px", "py"))
    x = domain.random(0)
    group = fused_message_group(
        (6, 6), domain.halo_spec(), {"px": 4, "py": 2}
    )
    assert len(group) == 8
    distinct_chains = {m.hops for m in group}
    assert len(distinct_chains) == 5
    for coalesce, want in ((True, 5), (False, 12)):
        drv = _fused_driver(domain, coalesce)
        stats = parse_collectives(drv.compiled_text(x))
        assert stats.by_op_counts.get("collective-permute", 0) == want
        assert drv.scheduled_collectives(x) == want
        drv.free()


def test_partitioned_coalesced_keeps_per_partition_collectives():
    """Partitions stay pipelined under coalescing: each partition round is
    its own collective (the early-arrival semantics), so a 2-part
    sequential exchange halves its collectives only through the shared
    2-wide-axis chains, never by merging rounds."""
    from repro.core.hlo_analysis import parse_collectives
    from repro.stencil.domain import Domain

    mesh = compat.make_mesh((2, 2), ("px", "py"), devices=jax.devices()[:4])
    domain = Domain(mesh, global_interior=(8, 8), mesh_axes=("px", "py"))
    x = domain.random(0)
    for coalesce, want in ((True, 4), (False, 8)):
        drv = _fused_driver(domain, coalesce, n_parts=2,
                            strategy="partitioned")
        stats = parse_collectives(drv.compiled_text(x))
        # 2 axes x 2 rounds x (1 merged chain if coalesced else 2 messages)
        assert stats.by_op_counts.get("collective-permute", 0) == want
        assert drv.scheduled_collectives(x) == want
        drv.free()
