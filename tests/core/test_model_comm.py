"""Analytic comm model: paper-claim directions + hypothesis invariants."""

import pytest
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

from repro.configs.comb_paper import QUARTZ
from repro.core.model_comm import (
    MachineModel, StencilWorkload, _near_cubic_grid, simulate, speedup,
)


def _trio(wl, n, rpn=32, threads=2, parts=None):
    b = simulate("standard", QUARTZ, wl, nprocs=n, ranks_per_node=rpn,
                 threads=threads)
    p = simulate("persistent", QUARTZ, wl, nprocs=n, ranks_per_node=rpn,
                 threads=threads)
    q = simulate("partitioned", QUARTZ, wl, nprocs=n, ranks_per_node=rpn,
                 threads=threads, n_parts=parts)
    return b, p, q


def test_c1_persistent_never_slower():
    """C1: persistent >= baseline at every tested scale."""
    for n in (64, 256, 1024, 4096):
        wl = StencilWorkload.from_face_doubles(524_288)
        b, p, _ = _trio(wl, n)
        assert speedup(b, p) > 0, n


def test_c3_partitioned_loses_small_messages():
    wl = StencilWorkload.from_face_doubles(768)
    b, _, q = _trio(wl, 4096)
    assert speedup(b, q) < -20


def test_c4_crossover_with_message_size():
    small = StencilWorkload.from_face_doubles(768)
    large = StencilWorkload.from_face_doubles(196_608)
    _, _, q_small = _trio(small, 4096)
    b_small, _, _ = _trio(small, 4096)
    b_large, _, q_large = _trio(large, 4096)
    assert speedup(b_small, q_small) < 0 < speedup(b_large, q_large)


def test_c5_partition_count_cliff():
    """C5: partitioned loses at 1 rank/node (64 threads), wins at 32 rpn."""
    wl = StencilWorkload.from_global_mesh((2048, 4096, 4096), 64)
    b1, _, q1 = _trio(wl, 64, rpn=1, threads=64)
    wl32 = StencilWorkload.from_global_mesh((2048, 4096, 4096), 2048)
    b32, _, q32 = _trio(wl32, 2048, rpn=32, threads=2)
    assert speedup(b1, q1) < 0 < speedup(b32, q32)


def test_c6_weak_scaling_rises():
    wl = StencilWorkload.from_face_doubles(524_288)
    b64, _, _ = _trio(wl, 64)
    b4096, _, _ = _trio(wl, 4096)
    assert b4096.total > b64.total


def test_workload_messages():
    wl = StencilWorkload((64, 64, 64), vars_per_cell=3)
    msgs = wl.messages()
    assert len(msgs) == 26  # 6 faces + 12 edges + 8 corners
    assert msgs[0] == 64 * 64 * 3 * 8
    assert msgs[-1] == 3 * 8


def test_near_cubic_grid():
    assert _near_cubic_grid(64) == (4, 4, 4)
    a, b, c = _near_cubic_grid(128)
    assert a * b * c == 128 and max(a, b, c) / min(a, b, c) <= 2


@settings(max_examples=20, deadline=None)
@given(
    doubles=st.sampled_from([768, 12288, 196_608, 524_288]),
    n=st.sampled_from([64, 512, 4096]),
    threads=st.sampled_from([1, 2, 8]),
)
def test_times_positive_and_finite(doubles, n, threads):
    wl = StencilWorkload.from_face_doubles(doubles)
    for strategy in ("standard", "persistent", "partitioned"):
        tb = simulate(strategy, QUARTZ, wl, nprocs=n, ranks_per_node=32,
                      threads=threads)
        assert 0 < tb.total < 10.0, (strategy, tb)


@settings(max_examples=15, deadline=None)
@given(doubles=st.integers(256, 1_000_000))
def test_monotone_in_message_size(doubles):
    """Bigger messages never get cheaper (fixed everything else)."""
    wl1 = StencilWorkload.from_face_doubles(doubles)
    wl2 = StencilWorkload.from_face_doubles(doubles * 2)
    for strategy in ("standard", "persistent"):
        t1 = simulate(strategy, QUARTZ, wl1, nprocs=1024, threads=2).total
        t2 = simulate(strategy, QUARTZ, wl2, nprocs=1024, threads=2).total
        assert t2 >= t1 * 0.99


def test_persistent_init_amortization():
    wl = StencilWorkload.from_face_doubles(12288)
    t1 = simulate("persistent", QUARTZ, wl, nprocs=256, threads=2, iters=1)
    t1000 = simulate("persistent", QUARTZ, wl, nprocs=256, threads=2, iters=1000)
    assert t1.init_amortized > 100 * t1000.init_amortized
