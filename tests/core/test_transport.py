"""The unified pack/transport layer: registries, Message windows, delivery.

Covers the contracts every exchange path now leans on: packer/transport
registration and lookup errors, the partition policy's clipped equal-size
windows, schedule identity tags, and on-mesh delivery — a hand-built
Message table must move the exact cells ``repro.core.halo`` moves, under
both registered packers and through multi-hop (corner) routes.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compat
from repro.core.transport import (
    Bf16Packer,
    Message,
    MultiHostTransport,
    Packer,
    PallasPacker,
    Partitioner,
    PpermuteTransport,
    ScaledInt8Packer,
    ScheduleInfo,
    SlicePacker,
    Transport,
    available_packers,
    available_transports,
    deliver,
    exchange_messages,
    get_packer,
    get_transport,
    register_packer,
    register_transport,
    resolve_packer,
    resolve_transport,
)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs >=4 virtual devices (conftest)"
)


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------


def test_builtin_backends_registered():
    assert set(available_packers()) >= {
        "slice", "pallas", "bf16", "scaled-int8",
    }
    assert set(available_transports()) >= {"ppermute", "multihost"}
    assert isinstance(get_packer("slice"), SlicePacker)
    assert isinstance(get_packer("pallas"), PallasPacker)
    assert isinstance(get_packer("bf16"), Bf16Packer)
    assert isinstance(get_packer("scaled-int8"), ScaledInt8Packer)
    assert isinstance(get_transport("ppermute"), PpermuteTransport)


def test_unknown_names_list_registered():
    with pytest.raises(KeyError, match="slice.*pallas"):
        get_packer("zstd")
    with pytest.raises(KeyError, match="ppermute.*multihost"):
        get_transport("nccl")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_packer(SlicePacker())
    with pytest.raises(ValueError, match="already registered"):
        register_transport(PpermuteTransport())


def test_register_configured_instance_under_new_name():
    """Packer instances carry their registry key, so a configured variant
    (e.g. the interpreter-pinned Pallas path) registers under its own name."""
    from repro.core import transport as T

    p = PallasPacker(name="pallas-interp-test", force_kernel=True,
                     interpret=True)
    register_packer(p)
    try:
        assert get_packer("pallas-interp-test") is p
        assert resolve_packer("pallas-interp-test") is p
        assert resolve_packer(p) is p
    finally:
        del T._PACKERS["pallas-interp-test"]


def test_resolve_accepts_instances():
    t = PpermuteTransport()
    assert resolve_transport(t) is t
    assert resolve_transport("ppermute") is get_transport("ppermute")


def test_schedule_info_tag_records_backends():
    info = ScheduleInfo("fused", ("px", "py"), packer="pallas",
                        transport="multihost")
    assert info.tag() == "fused[pxxpy]@pallas/multihost"
    coalesced = ScheduleInfo("fused", ("px", "py"), packer="pallas",
                             transport="multihost", coalesce=True)
    assert coalesced.tag() == "fused[pxxpy]@pallas/multihost+coalesced"


# ---------------------------------------------------------------------------
# Message windows and the partition policy
# ---------------------------------------------------------------------------


def test_message_partitions_clip_to_equal_size_grid():
    msg = Message(
        src_start=(1, 0), dst_start=(7, 0), shape=(1, 10),
        hops=(("px", ((0, 1), (1, 0))),), n_parts=4, part_axis=1,
    )
    parts = msg.partitions()
    # ceil(10/4) = 3 -> offsets 0,3,6,9 with the tail clipped to width 1
    assert [(p.src_start[1], p.shape[1]) for p in parts] == [
        (0, 3), (3, 3), (6, 3), (9, 1),
    ]
    assert all(p.n_parts == 1 and p.hops == msg.hops for p in parts)
    assert all(p.dst_start[0] == 7 for p in parts)


def test_message_all_padding_tails_elided():
    msg = Message((0,), (0,), (4,), n_parts=8, part_axis=0)
    # part size 1 -> windows at 0..3 valid, 4..7 pure padding.  The padding
    # tails never reach the wire: an arrival nobody consumes is dead code
    # under XLA (as it was for the historical inline path), so surplus
    # partitions are a model_comm cost, not a measurable one.
    assert len(msg.partitions()) == 4


def test_unpartitioned_message_expands_to_itself():
    msg = Message((0, 0), (0, 0), (2, 2))
    assert msg.partitions() == (msg,)


def test_partitioned_message_requires_axis():
    with pytest.raises(AssertionError, match="axis"):
        Message((0,), (0,), (4,), n_parts=2)


def test_partitioner_matches_legacy_split():
    """slices() offsets must agree with the padded split()+merge windows."""
    part = Partitioner(3, 0)
    x = jnp.arange(8.0)
    chunks = part.split(x)
    assert all(c.shape == (3,) for c in chunks)  # padded equal-size
    np.testing.assert_array_equal(np.asarray(part.merge(chunks, 8)), np.asarray(x))
    assert part.slices(8) == [(0, 3), (3, 3), (6, 2)]


# ---------------------------------------------------------------------------
# delivery on a mesh (inside shard_map)
# ---------------------------------------------------------------------------


def _ring_messages(shape, axis_name, k, halo=1):
    """Hand-built left/right ghost messages of a 1-axis exchange."""
    size = shape[0]
    to_left = tuple((i, (i - 1) % k) for i in range(k))
    to_right = tuple((i, (i + 1) % k) for i in range(k))

    def w(src_edge, dst_edge):
        src, dst, sz = [0] * len(shape), [0] * len(shape), list(shape)
        src[0], dst[0], sz[0] = src_edge, dst_edge, halo
        return tuple(src), tuple(dst), tuple(sz)

    left = Message(*w(halo, size - halo), ((axis_name, to_left),))
    right = Message(*w(size - 2 * halo, 0), ((axis_name, to_right),))
    return (left, right)


@pytest.mark.parametrize("packer", ["slice", "pallas"])
def test_deliver_moves_ghosts_like_halo(packer):
    """A hand-built Message table delivers the same ghosts under either
    packer (pallas falls back to its oracle on CPU: bit-identical)."""
    from repro.core.compat import make_mesh
    from jax.sharding import PartitionSpec as P

    k = 4
    mesh = make_mesh((k,), ("px",), devices=jax.devices()[:k])
    blk = 4  # ghosted block: [ghost | 2 interior | ghost]
    x = jnp.arange(k * blk * 3, dtype=jnp.float32).reshape(k * blk, 3)

    def step(xl):
        return deliver(
            xl, _ring_messages(xl.shape, "px", k),
            packer=packer, transport="ppermute",
        )

    got = np.asarray(
        compat.shard_map(
            step, mesh=mesh, in_specs=P("px", None), out_specs=P("px", None)
        )(x)
    )
    want = np.asarray(x).copy()
    blocks = want.reshape(k, blk, 3)
    for i in range(k):
        blocks[i, 0] = np.asarray(x).reshape(k, blk, 3)[(i - 1) % k, 2]
        blocks[i, 3] = np.asarray(x).reshape(k, blk, 3)[(i + 1) % k, 1]
    np.testing.assert_array_equal(got, want.reshape(k * blk, 3))


def test_multi_hop_route_reaches_diagonal_neighbor():
    """A 2-hop message (corner route) lands on the diagonal peer."""
    from repro.core.compat import make_mesh
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((2, 2), ("px", "py"), devices=jax.devices()[:4])
    x = jnp.arange(16.0).reshape(4, 4)

    hop_x = tuple((i, (i + 1) % 2) for i in range(2))
    hop_y = tuple((i, (i + 1) % 2) for i in range(2))
    msg = Message(
        src_start=(0, 0), dst_start=(1, 1), shape=(1, 1),
        hops=(("px", hop_x), ("py", hop_y)),
    )

    def step(xl):
        return exchange_messages(xl, ((msg,),))

    got = np.asarray(
        compat.shard_map(
            step, mesh=mesh, in_specs=P("px", "py"), out_specs=P("px", "py")
        )(x)
    )
    # every shard's [1,1] now holds its diagonal neighbor's [0,0]: shard
    # (i,j) owns the global 2x2 block at (2i, 2j)
    xg = np.asarray(x)
    for i in range(2):
        for j in range(2):
            want = xg[2 * ((i + 1) % 2), 2 * ((j + 1) % 2)]
            assert got[2 * i + 1, 2 * j + 1] == want, (i, j)


def test_partitioned_delivery_equals_whole_message():
    """n_parts on the Message: same ghosts, chunked wire."""
    from repro.core.compat import make_mesh
    from jax.sharding import PartitionSpec as P

    k = 4
    mesh = make_mesh((k,), ("px",), devices=jax.devices()[:k])
    x = jnp.arange(k * 4 * 5, dtype=jnp.float32).reshape(k * 4, 5)

    def run(n_parts):
        msgs = tuple(
            dataclasses.replace(m, n_parts=n_parts,
                                part_axis=1 if n_parts > 1 else None)
            for m in _ring_messages((4, 5), "px", k)
        )

        def step(xl):
            return deliver(xl, msgs)

        return np.asarray(
            compat.shard_map(
                step, mesh=mesh, in_specs=P("px", None),
                out_specs=P("px", None),
            )(x)
        )

    np.testing.assert_array_equal(run(1), run(3))
    np.testing.assert_array_equal(run(1), run(7))  # parts > extent


# ---------------------------------------------------------------------------
# custom backends flow through delivery
# ---------------------------------------------------------------------------


def test_custom_packer_and_transport_are_exercised():
    """deliver() must stage through the *resolved* backends — a counting
    packer and transport observe every partition of every message."""
    from repro.core.compat import make_mesh
    from jax.sharding import PartitionSpec as P

    calls = {"pack": 0, "unpack": 0, "permute": 0}

    @dataclasses.dataclass(frozen=True)
    class CountingPacker(SlicePacker):
        name: str = "counting-test"

        def pack(self, x, start, shape):
            calls["pack"] += 1
            return super().pack(x, start, shape)

        def unpack(self, x, buf, dst_start, shape):
            calls["unpack"] += 1
            return super().unpack(x, buf, dst_start, shape)

    @dataclasses.dataclass(frozen=True)
    class CountingTransport(PpermuteTransport):
        name: str = "counting-test"

        def permute(self, buf, axis_name, perm):
            calls["permute"] += 1
            return super().permute(buf, axis_name, perm)

    k = 4
    mesh = make_mesh((k,), ("px",), devices=jax.devices()[:k])
    x = jnp.arange(k * 4 * 6, dtype=jnp.float32).reshape(k * 4, 6)
    msgs = tuple(
        dataclasses.replace(m, n_parts=3, part_axis=1)
        for m in _ring_messages((4, 6), "px", k)
    )

    def step(xl):
        return deliver(
            xl, msgs, packer=CountingPacker(), transport=CountingTransport()
        )

    compat.shard_map(
        step, mesh=mesh, in_specs=P("px", None), out_specs=P("px", None)
    )(x)
    # 2 messages x 3 partitions, one hop each (traced once per shard program)
    assert calls == {"pack": 6, "unpack": 6, "permute": 6}

    # n_parts beyond the partition extent: only the 6 valid windows per
    # message are staged — all-padding tails never reach the backends
    calls.update(pack=0, unpack=0, permute=0)
    over = tuple(
        dataclasses.replace(m, n_parts=8, part_axis=1)
        for m in _ring_messages((4, 6), "px", k)
    )  # extent 6, part size 1 -> 6 valid + 2 elided padding tails each

    def step_over(xl):
        return deliver(
            xl, over, packer=CountingPacker(), transport=CountingTransport()
        )

    compat.shard_map(
        step_over, mesh=mesh, in_specs=P("px", None), out_specs=P("px", None)
    )(x)
    assert calls == {"pack": 12, "unpack": 12, "permute": 12}


# ---------------------------------------------------------------------------
# wire-compressed packers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packer,wire_dtype,itemsize", [
    ("bf16", jnp.bfloat16, 2),
    ("scaled-int8", jnp.int8, 1),
])
def test_compressed_packer_roundtrip_within_documented_tolerance(
    packer, wire_dtype, itemsize
):
    """pack -> unpack restores the window within wire_tolerance, restores
    the block dtype EXACTLY, and ships the advertised wire dtype/bytes."""
    p = get_packer(packer)
    rng = np.random.default_rng(21)
    x = jnp.asarray(rng.normal(size=(6, 10, 4)), jnp.float32)
    start, shape = (1, 2, 0), (2, 7, 4)
    buf = p.pack(x, start, shape)
    assert buf.dtype == wire_dtype
    assert p.wire_itemsize(jnp.float32) == itemsize
    ghost = jnp.zeros_like(x)
    out = p.unpack(ghost, buf, start, shape)
    assert out.dtype == x.dtype  # exact dtype restoration
    rtol, atol = p.wire_tolerance(jnp.float32)
    assert rtol > 0 or atol > 0  # lossy packers must document a bound
    window = np.asarray(x)[1:3, 2:9, :]
    np.testing.assert_allclose(
        np.asarray(out)[1:3, 2:9, :], window, rtol=rtol, atol=atol
    )
    # untouched cells stay untouched
    np.testing.assert_array_equal(np.asarray(out)[0], 0.0)


def test_exact_packers_declare_bit_exact_wire():
    for name in ("slice", "pallas"):
        p = get_packer(name)
        assert p.wire_tolerance(jnp.float32) == (0.0, 0.0)
        assert p.wire_itemsize(jnp.float32) == 4


def test_bf16_wire_is_exact_for_bf16_blocks():
    p = get_packer("bf16")
    assert p.wire_tolerance(jnp.bfloat16) == (0.0, 0.0)
    assert p.wire_itemsize(jnp.bfloat16) == 2
    x = jnp.asarray(np.random.default_rng(4).normal(size=(3, 5)), jnp.bfloat16)
    out = p.unpack(jnp.zeros_like(x), p.pack(x, (0, 0), (3, 5)), (0, 0), (3, 5))
    np.testing.assert_array_equal(
        np.asarray(out, np.float32), np.asarray(x, np.float32)
    )


def test_scaled_int8_saturates_beyond_amax():
    p = ScaledInt8Packer(name="int8-sat-test", amax=1.0)
    x = jnp.asarray([[0.5, 2.0, -3.0]], jnp.float32)
    buf = p.pack(x, (0, 0), (1, 3))
    np.testing.assert_array_equal(np.asarray(buf), [[64, 127, -127]])


@pytest.mark.parametrize("packer", ["bf16", "scaled-int8"])
def test_deliver_through_compressed_packer_within_tolerance(packer):
    """The same ring-ghost delivery as the exact-packer test, held to the
    packer's wire tolerance instead of bitwise equality."""
    from repro.core.compat import make_mesh
    from jax.sharding import PartitionSpec as P

    k = 4
    mesh = make_mesh((k,), ("px",), devices=jax.devices()[:k])
    blk = 4
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(k * blk, 3)), jnp.float32)

    def step(xl):
        return deliver(
            xl, _ring_messages(xl.shape, "px", k),
            packer=packer, transport="ppermute",
        )

    got = np.asarray(
        compat.shard_map(
            step, mesh=mesh, in_specs=P("px", None), out_specs=P("px", None)
        )(x)
    )
    want = np.asarray(x).copy().reshape(k, blk, 3)
    src = np.asarray(x).reshape(k, blk, 3)
    for i in range(k):
        want[i, 0] = src[(i - 1) % k, 2]
        want[i, 3] = src[(i + 1) % k, 1]
    rtol, atol = get_packer(packer).wire_tolerance(jnp.float32)
    np.testing.assert_allclose(got, want.reshape(k * blk, 3),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# plan identity: the packer is part of the compiled schedule's key
# ---------------------------------------------------------------------------


def test_same_geometry_under_two_packers_is_two_plans():
    """A shared PlanCache must MISS when only the packer differs (the wire
    pipeline is baked into the executable) and HIT on a true repeat."""
    from repro.core.plan import PlanCache
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import StrategyConfig, make_driver
    from repro.core.compat import make_mesh

    mesh = make_mesh((4,), ("px",), devices=jax.devices()[:4])
    domain = Domain(mesh, global_interior=(16, 8), mesh_axes=("px", None))
    cache = PlanCache()

    def drive(packer):
        drv = make_driver(
            StrategyConfig(name="persistent", packer=packer,
                           plan_cache=cache),
            domain.mesh, domain.halo_spec, ndim=2,
        )
        drv.wait(drv.step(domain.random(0)))
        drv.free()

    drive("slice")
    drive("bf16")
    assert len(cache) == 2, "packer change must not hit the cached plan"
    assert cache.stats.inits == 2 and cache.stats.cache_hits == 0
    drive("bf16")  # identical geometry AND packer: amortized
    assert len(cache) == 2
    assert cache.stats.cache_hits == 1
    cache.free_all()


# ---------------------------------------------------------------------------
# multihost transport: single-process selection warns once
# ---------------------------------------------------------------------------


def test_multihost_single_process_warns_once_outside_tests(monkeypatch):
    """Selecting `multihost` while jax.process_count() == 1 must warn (the
    schedule silently degenerates to in-process ppermute) — once per
    process, and never under pytest/the explicit escape hatch."""
    assert jax.process_count() == 1  # this suite never runs in a grid
    monkeypatch.delenv("PYTEST_CURRENT_TEST", raising=False)
    monkeypatch.delenv("REPRO_ALLOW_SINGLE_PROCESS_MULTIHOST", raising=False)
    monkeypatch.setattr(MultiHostTransport, "_warned_single_process", False)
    with pytest.warns(RuntimeWarning, match="process_count\\(\\) == 1"):
        resolve_transport("multihost")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second resolve: already warned
        resolve_transport("multihost")


def test_multihost_warning_suppressed_under_pytest(monkeypatch):
    monkeypatch.setattr(MultiHostTransport, "_warned_single_process", False)
    assert "PYTEST_CURRENT_TEST" in __import__("os").environ
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        resolve_transport("multihost")
    assert not MultiHostTransport._warned_single_process


def test_multihost_escape_hatch_env(monkeypatch):
    monkeypatch.delenv("PYTEST_CURRENT_TEST", raising=False)
    monkeypatch.setenv("REPRO_ALLOW_SINGLE_PROCESS_MULTIHOST", "1")
    monkeypatch.setattr(MultiHostTransport, "_warned_single_process", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        resolve_transport("multihost")
    assert not MultiHostTransport._warned_single_process
