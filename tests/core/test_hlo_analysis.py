"""The loop-aware HLO analyzer vs XLA's own cost analysis.

On loop-free programs the two must agree (flops near-exactly for dot-dominated
programs); on scanned programs ours must scale with trip count while XLA's
stays flat (the very gap the analyzer exists to close).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compat import cost_analysis_dict
from repro.core.hlo_analysis import analyze_hlo, roofline


def _compile(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    return compiled


def _xla_cost(compiled) -> dict:
    # jax 0.4.x returns [{...}], newer jax a dict — normalize via the shim so
    # the assertions below test the analyzer, not the cost_analysis() shape.
    return cost_analysis_dict(compiled)


def test_matmul_flops_match_cost_analysis():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    compiled = _compile(lambda x, y: x @ y, a, b)
    got = analyze_hlo(compiled.as_text())
    want = _xla_cost(compiled)["flops"]
    assert want > 0
    np.testing.assert_allclose(got.flops, want, rtol=0.01)
    # 2*M*N*K exactly
    np.testing.assert_allclose(got.flops, 2 * 128 * 64 * 256, rtol=0.01)


def test_chained_matmuls_and_elementwise():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        y = jnp.tanh(x @ x)
        return y @ x

    compiled = _compile(f, a)
    got = analyze_hlo(compiled.as_text())
    want = _xla_cost(compiled)["flops"]
    # dots dominate; tanh etc. are not counted by our analyzer
    assert got.flops >= 2 * 2 * 64**3 * 0.99
    assert got.flops <= want * 1.05


def test_scan_scales_with_trip_count_xla_does_not():
    a = jnp.zeros((32, 32), jnp.float32)
    w = jnp.zeros((8, 32, 32), jnp.float32)

    def f(x, ws):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    compiled = _compile(f, a, w)
    got = analyze_hlo(compiled.as_text())
    xla = _xla_cost(compiled)["flops"]
    per_layer = 2 * 32 * 32 * 32
    # ours: 8 iterations
    np.testing.assert_allclose(got.flops, 8 * per_layer, rtol=0.05)
    # XLA: body counted once (the bug we correct); if XLA ever fixes this,
    # the analyzer's correction becomes a no-op and this assert flags it.
    assert xla < 3 * per_layer
    assert got.n_loops == 1 and got.trip_counts == [8]


def test_nested_scans():
    a = jnp.zeros((16, 16), jnp.float32)
    w = jnp.zeros((4, 3, 16, 16), jnp.float32)

    def f(x, ws):
        def outer(c, wg):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wg)
            return c2, None
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    compiled = _compile(f, a, w)
    got = analyze_hlo(compiled.as_text())
    np.testing.assert_allclose(got.flops, 12 * 2 * 16**3, rtol=0.05)


def test_bytes_roughly_match_cost_analysis():
    a = jnp.zeros((256, 256), jnp.float32)
    compiled = _compile(lambda x: (x @ x) + 1.0, a)
    got = analyze_hlo(compiled.as_text())
    want = _xla_cost(compiled)["bytes accessed"]
    assert 0.3 * want <= got.bytes <= 3.0 * want


def test_roofline_terms_and_bottleneck():
    t = roofline(hlo_flops_per_device=197e12, hlo_bytes_per_device=819e9 / 2,
                 wire_bytes_per_device=50e9 / 4,
                 model_flops_global=197e12 * 256 * 0.5, n_chips=256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 0.5) < 1e-9
    assert abs(t.collective_s - 0.25) < 1e-9
    assert t.bottleneck == "compute"
    assert abs(t.useful_flops_ratio - 0.5) < 1e-9
    assert abs(t.mfu_bound - 0.5) < 1e-9
