"""Property tests: static re-planning is a pure function of the topology.

The elastic-resume contract (repro.launch.elastic) rests on
``ExchangeStrategy.replan_tables`` being deterministic: after a rank loss
the survivors re-derive their ``Message`` tables and ``WireLayout`` offset
tables from scratch, and every survivor must derive the *same* schedule or
the exchange deadlocks.  These properties pin that down: repeated
derivations are equal, fresh drivers derive equal tables, and the result
depends only on (mesh axis sizes, spec, block shape) — never on device
identity or ordering.
"""

import jax
import numpy as np
import pytest

from repro.core.compat import make_mesh
from repro.core.transport import schedule_layouts
from repro.stencil.domain import Domain
from repro.stencil.strategies import StrategyConfig, make_driver
from repro.testing import given, settings, st  # hypothesis or deterministic fallback

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 virtual devices (conftest)"
)

STRATEGIES = ("standard", "persistent", "partitioned", "fused", "overlap")
#: axis-0 extent 24 divides every device count and keeps local >= 3*halo
SIZE = (24, 6)


def _driver_and_example(devices, *, strategy, n_parts, packer, coalesce,
                        mapping="row-major"):
    mesh = make_mesh((len(devices),), ("px",), devices=list(devices))
    dom = Domain(mesh, global_interior=SIZE, mesh_axes=("px", None), halo=1)
    drv = make_driver(
        StrategyConfig(name=strategy, n_parts=n_parts, packer=packer,
                       coalesce=coalesce, mapping=mapping),
        mesh, dom.halo_spec, ndim=2,
    )
    example = jax.ShapeDtypeStruct(dom.stored_global, np.dtype(dom.dtype))
    return drv, example


def _tables(devices, **kw):
    drv, example = _driver_and_example(devices, **kw)
    return drv.replan_tables(example)


@settings(max_examples=12, deadline=None)
@given(
    strategy=st.sampled_from(STRATEGIES),
    n_devices=st.sampled_from((2, 4, 8)),
    n_parts=st.integers(1, 3),
    packer=st.sampled_from(("slice", "bf16")),
    coalesce=st.booleans(),
)
def test_replan_tables_is_pure(strategy, n_devices, n_parts, packer, coalesce):
    """Same topology in, same tables out — on one driver and across
    independently constructed drivers."""
    if strategy != "partitioned":
        n_parts = 1
    kw = dict(strategy=strategy, n_parts=n_parts, packer=packer,
              coalesce=coalesce)
    devices = jax.devices()[:n_devices]
    drv, example = _driver_and_example(devices, **kw)
    first = drv.replan_tables(example)
    assert first == drv.replan_tables(example)
    # a fresh driver (fresh spec, fresh tables) derives the same schedule
    assert first == _tables(devices, **kw)


@settings(max_examples=12, deadline=None)
@given(
    strategy=st.sampled_from(STRATEGIES),
    n_devices=st.sampled_from((2, 4, 8)),
    n_parts=st.integers(1, 3),
    seed=st.integers(0, 1_000_000),
)
def test_replan_tables_ignores_device_permutation(
    strategy, n_devices, n_parts, seed
):
    """Rank permutations must not change the derived schedule: the tables
    are a function of the mesh *shape*, not of which physical device holds
    which coordinate (the survivors of a rank loss are an arbitrary
    subset/reordering of the original devices)."""
    if strategy != "partitioned":
        n_parts = 1
    kw = dict(strategy=strategy, n_parts=n_parts, packer="slice",
              coalesce=True)
    devices = list(jax.devices()[:n_devices])
    permuted = list(devices)
    np.random.default_rng(seed).shuffle(permuted)
    assert _tables(devices, **kw) == _tables(permuted, **kw)
    # ...and a *different* subset of the same cardinality (survivor choice)
    tail = list(jax.devices()[-n_devices:])
    assert _tables(devices, **kw) == _tables(tail, **kw)


@settings(max_examples=15, deadline=None)
@given(
    strategy=st.sampled_from(STRATEGIES),
    n_devices=st.sampled_from((4, 8)),
    mapping=st.sampled_from(
        ("row-major", "blocked", "recursive-bisection", "rb")
    ),
)
def test_replan_tables_ignore_mapping(strategy, n_devices, mapping):
    """The mapping seam's purity half: a registered process-to-node mapping
    permutes which DEVICE holds each coordinate (and stamps plan keys), but
    the derived Message/WireLayout tables — pure functions of the mesh
    shape — must be identical under every mapping, for every strategy.
    This is what lets every rank of a mapped grid derive the same schedule
    independently."""
    from repro.launch.mapping import default_node_size, get_mapping

    kw = dict(strategy=strategy,
              n_parts=2 if strategy == "partitioned" else 1,
              packer="slice", coalesce=True)
    devices = list(jax.devices()[:n_devices])
    placed = get_mapping(mapping).permute_devices(
        devices, (n_devices,), default_node_size(n_devices)
    )
    assert _tables(devices, **kw) == _tables(placed, mapping=mapping, **kw)


@settings(max_examples=8, deadline=None)
@given(
    n_devices=st.sampled_from((2, 4)),
    n_parts=st.integers(1, 3),
    packer=st.sampled_from(("slice", "bf16", "scaled-int8")),
)
def test_schedule_layouts_is_pure(n_devices, n_parts, packer):
    """The WireLayout offset tables are a pure function of
    (message groups, packer, dtype)."""
    drv, example = _driver_and_example(
        jax.devices()[:n_devices], strategy="partitioned", n_parts=n_parts,
        packer=packer, coalesce=True,
    )
    groups, layouts = drv.replan_tables(example)
    assert layouts == schedule_layouts(groups, packer, np.float32)
    assert schedule_layouts(groups, packer, np.float32) == schedule_layouts(
        groups, packer, np.float32
    )
