"""The autotune selection layer: cost model, trace oracle, calibration.

Satellite coverage for the PR's tentpole:

* cost-model properties — predictions monotone in ``wire_bytes`` and in
  ``inter_node_sends``, fitted inter-node per-send cost >= the intra-node
  one (all enforced structurally by ``_fit_nonneg`` + the feature vector);
* the trace backend fitted on the committed ``BENCH_stencil_sweep.json``
  reproduces each cell's recorded winner and never picks a cell worse than
  the ``standard`` baseline;
* calibration probes can never poison the caller's :class:`PlanCache`
  (the PR 6 insert-only-after-successful-init invariant), and verdicts
  memoize in the persistent :class:`AutotuneCache` so a second process
  skips every probe.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.core.autotune import (
    AutotuneCache,
    CACHE_ENV,
    Candidate,
    CellFeatures,
    TRACE_ENV,
    TraceCostModel,
    Tuner,
    _fit_nonneg,
    cell_key,
    choose_mapping,
    default_candidates,
    default_tuner,
    record_features,
    reset_default_tuners,
)
from repro.testing import given, settings, st

BASELINE = os.path.join(os.path.dirname(__file__), "..", "..",
                        "BENCH_stencil_sweep.json")


def _rec(strategy, us, *, wire=64, coll=4, intra=2, inter=2,
         message_bytes=None, **extra):
    """A minimal record carrying exactly what the model/tuner read."""
    r = {
        "strategy": strategy,
        "us_per_cycle": float(us),
        "message_bytes": wire if message_bytes is None else message_bytes,
        "wire_bytes": wire,
        "collective_count": coll,
        "intra_node_sends": intra,
        "inter_node_sends": inter,
        "n_parts": 1,
        "packer": "slice",
        "coalesce": True,
        "mapping": "row-major",
        "transport": "ppermute",
        "mesh_shape": [2, 2],
        "node_size": 2,
    }
    r.update(extra)
    return r


def _cell(**overrides):
    cell = {
        "mesh_shape": (2, 2),
        "shape": (10, 6),
        "dtype": "float32",
        "halo": 1,
        "mapping": "row-major",
        "transport": "ppermute",
        "node_size": 2,
        "message_bytes": 64,
    }
    cell.update(overrides)
    return cell


# ---------------------------------------------------------------------------
# cost-model properties
# ---------------------------------------------------------------------------


def test_fit_nonneg_clamps_negative_coefficients():
    # y DECREASES with the second feature: plain lstsq would go negative
    rows = np.array([[1.0, 1.0], [1.0, 2.0], [1.0, 3.0]])
    y = np.array([3.0, 2.0, 1.0])
    coef = _fit_nonneg(rows, y)
    assert coef[1] >= 0.0
    # and with every column hostile, it degrades to the intercept-only mean
    assert coef[0] == pytest.approx(np.mean(y)) or coef[1] > 0


def _fitted_model(seed: int) -> TraceCostModel:
    rng = np.random.default_rng(seed)
    records = []
    for _ in range(12):
        wire = int(rng.integers(8, 4096))
        coll = int(rng.integers(1, 12))
        intra = int(rng.integers(0, 8))
        inter = int(rng.integers(0, 8))
        us = float(rng.uniform(1.0, 500.0))
        records.append(_rec("s", us, wire=wire, coll=coll,
                            intra=intra, inter=inter))
    return TraceCostModel.fit(records)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), wire=st.integers(8, 2048),
       bump=st.integers(1, 2048))
def test_prediction_monotone_in_wire_bytes(seed, wire, bump):
    model = _fitted_model(seed)
    lo = CellFeatures(wire, 4, 2, 2)
    hi = CellFeatures(wire + bump, 4, 2, 2)
    assert model.predict("s", hi) >= model.predict("s", lo)
    assert model.predict("s", lo) >= 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), inter=st.integers(0, 8),
       bump=st.integers(1, 8))
def test_prediction_monotone_in_inter_node_sends(seed, inter, bump):
    """Moving a send across the node boundary (same total) never gets
    cheaper, and the fitted per-send costs honor inter >= intra >= 0."""
    model = _fitted_model(seed)
    total = inter + bump + 4
    near = CellFeatures(64, 4, total - inter, inter)
    far = CellFeatures(64, 4, total - inter - bump, inter + bump)
    assert model.predict("s", far) >= model.predict("s", near)
    alpha, beta = model.locality_costs("s")
    assert beta >= alpha >= 0.0


def test_record_features_tolerates_pre_schema_records():
    assert record_features({"message_bytes": 64}) is None
    assert record_features(_rec("s", 1.0)) == CellFeatures(64, 4, 2, 2)


# ---------------------------------------------------------------------------
# trace backend: the committed baseline is the oracle
# ---------------------------------------------------------------------------


def _baseline_cells():
    from repro.stencil.sweep import read_bench_json

    records, _config = read_bench_json(BASELINE)
    static = [r for r in records if not r.get("selected_by")]
    cells = {}
    for r in static:
        key = (r["mapping"], r["n_devices"], tuple(r["global_interior"]))
        cells.setdefault(key, []).append(r)
    return static, cells


def test_trace_selection_matches_per_cell_oracle_on_committed_baseline():
    """Acceptance: fitted on the committed 96-record baseline, the tuner
    picks each cell's best static record (>= 80% of cells) and never lands
    on a cell slower than the standard baseline."""
    static, cells = _baseline_cells()
    tuner = Tuner(static)
    assert cells, "committed baseline has no cells"
    matches = 0
    for (mapping, _n, _size), rows in cells.items():
        candidates, features, recorded = {}, {}, {}
        for r in rows:
            cand = Candidate(r["strategy"], r.get("packer", "slice"),
                             bool(r.get("coalesce", False)),
                             int(r.get("n_parts", 1)))
            feats = record_features(r)
            assert feats is not None, "baseline predates the model schema"
            candidates[cand] = True
            features[cand] = feats
            recorded[cand] = min(r["us_per_cycle"],
                                 recorded.get(cand, float("inf")))
        cell = _cell(
            mapping=mapping, transport=rows[0]["transport"],
            mesh_shape=tuple(rows[0]["mesh_shape"]),
            node_size=rows[0]["node_size"],
            message_bytes=rows[0]["message_bytes"],
        )
        verdict = tuner.choose(tuple(candidates), features, cell)
        assert verdict is not None and verdict.selected_by == "trace"
        best_us = min(recorded.values())
        if recorded[verdict.candidate] == pytest.approx(best_us):
            matches += 1
        standard_us = min(
            us for c, us in recorded.items() if c.strategy == "standard"
        )
        assert recorded[verdict.candidate] <= standard_us, (
            verdict.candidate, recorded[verdict.candidate], standard_us
        )
    assert matches / len(cells) >= 0.8, (matches, len(cells))


def test_trace_tier_outranks_model_extrapolation():
    """A measured (slow) candidate beats a modeled (fast) one: selection
    happens within the best available tier, never across tiers."""
    target = _cell(message_bytes=64)
    records = [
        _rec("measured", 100.0, wire=64),
        # "other" was only ever measured on a DIFFERENT topology, so in the
        # target cell it has model support only — even though the model
        # scores it far cheaper
        _rec("other", 1.0, wire=64, mesh_shape=[4]),
        _rec("other", 1.5, wire=128, mesh_shape=[4]),
    ]
    tuner = Tuner(records)
    cands = (Candidate("measured", "slice", True),
             Candidate("other", "slice", True))
    feats = {c: CellFeatures(64, 4, 2, 2) for c in cands}
    verdict = tuner.choose(cands, feats, target)
    assert verdict.candidate.strategy == "measured"
    assert verdict.selected_by == "trace"
    assert verdict.predicted_us == pytest.approx(100.0)


def test_trace_nearest_interpolates_unswept_sizes():
    records = [
        _rec("s", 10.0, wire=32, message_bytes=32),
        _rec("s", 40.0, wire=512, message_bytes=512),
    ]
    tuner = Tuner(records)
    cand = Candidate("s", "slice", True)
    feats = {cand: CellFeatures(64, 4, 2, 2)}
    verdict = tuner.choose((cand,), feats, _cell(message_bytes=64))
    assert verdict.selected_by == "trace-nearest"
    assert verdict.predicted_us >= 0.0
    # an exact size hit stays in the "trace" tier
    exact = tuner.choose((cand,), feats, _cell(message_bytes=32))
    assert exact.selected_by == "trace"
    assert exact.predicted_us == pytest.approx(10.0)


def test_autotuned_records_are_not_trace_ground_truth():
    """A selection outcome re-fed as trace would amplify itself; only
    static measurements count."""
    tuner = Tuner([_rec("s", 1.0, selected_by="calibration")])
    assert tuner.trace == [] and tuner.model is None
    assert tuner.choose(
        (Candidate("s", "slice", True),),
        {Candidate("s", "slice", True): CellFeatures(64, 4, 2, 2)},
        _cell(),
    ) is None


# ---------------------------------------------------------------------------
# calibration: probe safety + persistent memoization
# ---------------------------------------------------------------------------


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 virtual devices (conftest)")
def test_failed_probe_never_poisons_the_plan_cache():
    """A candidate whose plan build dies mid-assembly (chaos at the
    delivery seam) is skipped by calibration AND leaves no entry in the
    shared PlanCache — get_or_init inserts only after a successful init."""
    from repro.core.compat import make_mesh
    from repro.core.plan import PlanCache
    from repro.core.transport import chaos_scope
    from repro.stencil.domain import Domain
    from repro.stencil.strategies import StrategyConfig, make_driver

    mesh = make_mesh((2, 2), ("px", "py"))
    dom = Domain(mesh, global_interior=(8, 8), mesh_axes=("px", "py"))
    cache = PlanCache()
    probed = []

    def boom(point):
        raise RuntimeError(f"chaos at {point}")

    def probe(cand):
        probed.append(cand.strategy)
        drv = make_driver(
            StrategyConfig(name=cand.strategy, plan_cache=cache),
            dom.mesh, dom.halo_spec, ndim=2,
        )
        x = dom.random(0)
        try:
            if cand.strategy == "persistent":
                with chaos_scope(boom):
                    drv.init(x)  # chaos fires at trace time -> raises
            drv.init(x)
            x = drv.step(x)
            drv.wait(x)
        finally:
            drv.free()
        return {"persistent": 1.0, "fused": 2.0}[cand.strategy]

    verdict = Tuner().calibrate(
        (Candidate("persistent", "slice", True),
         Candidate("fused", "slice", True)),
        _cell(), probe,
    )
    assert probed == ["persistent", "fused"]
    # the chaos-killed persistent probe lost despite its better time, and
    # its aborted plan build inserted NOTHING
    assert verdict.candidate.strategy == "fused"
    assert verdict.selected_by == "calibration"
    assert cache.stats.inits == 1 and len(cache) == 1
    cache.free_all()


def test_calibration_raises_when_every_probe_fails():
    def probe(cand):
        raise ValueError("unbuildable here")

    with pytest.raises(RuntimeError, match="every candidate probe failed"):
        Tuner().calibrate((Candidate("s", "slice", True),), _cell(), probe)


def test_calibration_verdict_memoized_across_processes(tmp_path):
    """Acceptance: the second run (a fresh Tuner on the same cache path —
    a stand-in for the next process) resolves from the persistent cache
    with ZERO probes, and its plan stamp matches the calibrated one so
    plan keys stay identical across runs."""
    path = str(tmp_path / "autotune.json")
    cands = (Candidate("a", "slice", True), Candidate("b", "slice", True))
    calls = []

    def probe(cand):
        calls.append(cand.strategy)
        return {"a": 5.0, "b": 2.0}[cand.strategy]

    v1 = Tuner(cache=AutotuneCache(path)).calibrate(cands, _cell(), probe)
    assert v1.selected_by == "calibration"
    assert v1.candidate.strategy == "b" and v1.calibration_us > 0
    assert calls == ["a", "b"]

    v2 = Tuner(cache=AutotuneCache(path)).calibrate(cands, _cell(), probe)
    assert calls == ["a", "b"], "cache hit must not re-probe"
    assert v2.selected_by == "cache" and v2.candidate == v1.candidate
    assert v2.calibration_us == 0.0
    assert v2.plan_stamp() == v1.plan_stamp() == "calibration"

    # a different candidate grid is a different selection problem
    v3 = Tuner(cache=AutotuneCache(path)).calibrate(
        cands[:1], _cell(), probe
    )
    assert calls == ["a", "b", "a"] and v3.selected_by == "calibration"


def test_autotune_cache_tolerates_corruption(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    cache = AutotuneCache(str(path))
    assert cache.get("k") is None and len(cache) == 0
    cache.put("k", {"strategy": "s"})
    assert AutotuneCache(str(path)).get("k") == {"strategy": "s"}
    assert json.loads(path.read_text()) == {"k": {"strategy": "s"}}


def test_cell_key_is_candidate_order_invariant():
    a = Candidate("a", "slice", True)
    b = Candidate("b", "pallas", False, 2)
    assert cell_key(_cell(), (a, b)) == cell_key(_cell(), (b, a))
    assert cell_key(_cell(), (a,)) != cell_key(_cell(), (a, b))
    assert cell_key(_cell(), (a,)) != cell_key(_cell(node_size=4), (a,))


def test_default_tuner_memoizes_per_env(tmp_path, monkeypatch):
    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "c.json"))
    monkeypatch.delenv(TRACE_ENV, raising=False)
    reset_default_tuners()
    try:
        t = default_tuner()
        assert t is default_tuner()
        assert t.cache.path == str(tmp_path / "c.json")
        assert t.trace == []
    finally:
        reset_default_tuners()


# ---------------------------------------------------------------------------
# candidate grid + mapping selection
# ---------------------------------------------------------------------------


def test_default_candidates_exclude_lossy_packers():
    cands = default_candidates()
    packers = {c.packer for c in cands}
    assert "bf16" not in packers and "scaled-int8" not in packers
    assert {"slice", "pallas"} <= packers
    # partitioning strategies range over the part grid; the rest stay p=1
    assert {c.n_parts for c in cands if c.strategy == "partitioned"} == {
        1, 2, 4,
    }
    assert {c.n_parts for c in cands if c.strategy == "standard"} == {1}
    # lossy packers remain available by explicit pin
    pinned = default_candidates(packers=("bf16",), strategies=("standard",))
    assert {c.packer for c in pinned} == {"bf16"}
    with pytest.raises(KeyError):
        default_candidates(packers=("nope",))


def test_choose_mapping_prefers_identity_on_ties():
    # one device per node and all-devices-one-node: every mapping ties on
    # inter-node sends, so registration order (row-major) wins
    assert choose_mapping((4,), 1) == "row-major"
    assert choose_mapping((4,), 4) == "row-major"
    from repro.launch.mapping import available_mappings

    for shape, node_size in (((2, 2), 2), ((4, 2), 4), ((8,), 2)):
        assert choose_mapping(shape, node_size) in available_mappings()


def test_choose_mapping_minimizes_inter_node_traffic():
    """On a (2, 4) torus with 4-rank nodes, the row-major identity puts
    each full row on one node, so EVERY first-axis halo hop crosses the
    node boundary; a block placement keeps 2x2 sub-tori on one node.
    'auto' must find a strictly better placement than the identity."""
    import itertools

    from repro.launch.mapping import get_mapping

    shape, node_size = (2, 4), 4
    chosen = choose_mapping(shape, node_size)

    def inter(name):
        node_of = get_mapping(name).node_of(shape, node_size)
        count = 0
        for coords in itertools.product(*map(range, shape)):
            for a, k in enumerate(shape):
                for d in (-1, 1):
                    dst = list(coords)
                    dst[a] = (coords[a] + d) % k
                    src_i = coords[0] * shape[1] + coords[1]
                    dst_i = dst[0] * shape[1] + dst[1]
                    count += node_of[src_i] != node_of[dst_i]
        return count

    assert chosen != "row-major"
    assert inter(chosen) < inter("row-major")
